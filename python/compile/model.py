"""L2: the GCN training step over fixed-shape padded subgraph batches.

A 2-layer GraphSAGE-mean GCN (the paper trains "a GCN model for mini-batch
training" with 2-hop / fanout-(40,20) sampling; GraphSAGE-mean is the
standard sampled-neighborhood formulation of that setup):

    agg2[b,i] = masked_mean(x_h2[b,i,:,:], m_h2[b,i,:])        # hop-2 → hop-1
    h1[b,i]   = relu(x_h1[b,i] @ Ws1 + agg2[b,i] @ Wn1 + b1)   # layer 1
    s1[b]     = relu(x_seed[b] @ Ws1 + masked_mean(x_h1, m_h1)[b] @ Wn1 + b1)
    aggh[b]   = masked_mean(h1, m_h1)[b]                        # hop-1 → seed
    logits[b] = s1[b] @ Ws2 + aggh[b] @ Wn2 + b2                # layer 2
    loss      = mean softmax-CE(logits, y)

The aggregations and the fused layer-1 are the L1 Pallas kernels; setting
``use_kernels=False`` swaps in the pure-jnp references (tested equal).

Batch tensor layout (all f32 except y: i32) — the contract with the rust
runtime (`rust/src/train/batch.rs`), recorded in artifacts/meta.json:

    x_seed [B, D]   x_h1 [B, F1, D]   x_h2 [B, F1, F2, D]
    m_h1   [B, F1]  m_h2 [B, F1, F2]  y    [B]

Parameter order (everywhere: artifacts, rust ParamStore, AllReduce):

    ws1 [D,H], wn1 [D,H], b1 [H], ws2 [H,C], wn2 [H,C], b2 [C]
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.aggregate import masked_mean
from .kernels.fused_gcn import sage_layer

PARAM_NAMES: List[str] = ["ws1", "wn1", "b1", "ws2", "wn2", "b2"]
BATCH_NAMES: List[str] = ["x_seed", "x_h1", "x_h2", "m_h1", "m_h2", "y"]


@dataclasses.dataclass(frozen=True)
class Spec:
    """Static shape specification for one compiled artifact set."""

    batch: int = 32
    f1: int = 10
    f2: int = 5
    dim: int = 32
    hidden: int = 64
    classes: int = 8

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return {
            "ws1": (self.dim, self.hidden),
            "wn1": (self.dim, self.hidden),
            "b1": (self.hidden,),
            "ws2": (self.hidden, self.classes),
            "wn2": (self.hidden, self.classes),
            "b2": (self.classes,),
        }

    def batch_shapes(self) -> Dict[str, Tuple[int, ...]]:
        b, f1, f2, d = self.batch, self.f1, self.f2, self.dim
        return {
            "x_seed": (b, d),
            "x_h1": (b, f1, d),
            "x_h2": (b, f1, f2, d),
            "m_h1": (b, f1),
            "m_h2": (b, f1, f2),
            "y": (b,),
        }

    @staticmethod
    def parse(s: str) -> "Spec":
        """Parse ``"b=32,f1=10,f2=5,d=32,h=64,c=8"`` (all keys optional)."""
        kv = {}
        for part in filter(None, s.split(",")):
            k, v = part.split("=")
            kv[k.strip()] = int(v)
        return Spec(
            batch=kv.get("b", 32),
            f1=kv.get("f1", 10),
            f2=kv.get("f2", 5),
            dim=kv.get("d", 32),
            hidden=kv.get("h", 64),
            classes=kv.get("c", 8),
        )


def init_params(spec: Spec, key: jax.Array) -> List[jax.Array]:
    """Glorot-uniform weights, zero biases. Order = PARAM_NAMES."""
    shapes = spec.param_shapes()
    out = []
    for name in PARAM_NAMES:
        shape = shapes[name]
        if len(shape) == 1:
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            out.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
    return out


def forward(params, batch, *, use_kernels: bool = True) -> jax.Array:
    """Logits ``[B, C]`` for a padded subgraph batch.

    Args:
      params: list in PARAM_NAMES order.
      batch: list/tuple in BATCH_NAMES order (y may be None for inference).
    """
    ws1, wn1, b1, ws2, wn2, b2 = params
    x_seed, x_h1, x_h2, m_h1, m_h2 = batch[:5]
    B, F1, F2, D = x_h2.shape
    mm = masked_mean if use_kernels else ref.masked_mean_ref
    layer = sage_layer if use_kernels else ref.sage_layer_ref

    # Hop-2 → hop-1 aggregation: [B*F1, F2, D] → [B*F1, D].
    agg2 = mm(x_h2.reshape(B * F1, F2, D), m_h2.reshape(B * F1, F2))
    # Layer 1 on hop-1 nodes (fused kernel): [B*F1, H].
    h1 = layer(x_h1.reshape(B * F1, D), agg2, ws1, wn1, b1)
    h1 = h1.reshape(B, F1, -1)
    # Layer-1 representation of the seed itself.
    agg1_raw = mm(x_h1, m_h1)  # [B, D]
    s1 = layer(x_seed, agg1_raw, ws1, wn1, b1)  # [B, H]
    # Hop-1 → seed aggregation of layer-1 states, then layer 2.
    aggh = mm(h1, m_h1)  # [B, H]
    return s1 @ ws2 + aggh @ wn2 + b2


def loss_and_acc(params, batch, *, use_kernels: bool = True):
    """(mean CE loss, #correct) — both f32 scalars."""
    logits = forward(params, batch, use_kernels=use_kernels)
    y = batch[5]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct


def grad_step(params, batch, *, use_kernels: bool = True):
    """One gradient computation: returns ``(loss, correct, *grads)``.

    This is the function AOT-compiled to ``gcn_grad.hlo.txt``; the rust
    coordinator AllReduce-averages the grads across workers and feeds them
    to :func:`apply_step`.
    """

    def loss_fn(ps):
        return loss_and_acc(ps, batch, use_kernels=use_kernels)

    (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return (loss, correct, *grads)


def apply_step(params, grads, lr):
    """SGD update: ``p - lr * g`` for every parameter (order preserved).

    AOT-compiled to ``gcn_apply.hlo.txt``; `lr` is a scalar input so the
    schedule stays under the coordinator's control without recompilation.
    """
    return tuple(p - lr * g for p, g in zip(params, grads))


def example_batch(spec: Spec, key: jax.Array, *, learnable: bool = True):
    """Synthetic batch in BATCH_NAMES order (for tests and AOT tracing).

    With ``learnable=True``, features carry a per-class signal so a few
    training steps measurably reduce the loss.
    """
    ks = jax.random.split(key, 8)
    b, f1, f2, d, c = spec.batch, spec.f1, spec.f2, spec.dim, spec.classes
    y = jax.random.randint(ks[0], (b,), 0, c)
    centroids = jax.random.normal(ks[1], (c, d)) * 2.0
    noise = lambda k, shape: jax.random.normal(k, shape) * 1.0

    if learnable:
        x_seed = centroids[y] + noise(ks[2], (b, d))
        x_h1 = centroids[y][:, None, :] + noise(ks[3], (b, f1, d))
        x_h2 = centroids[y][:, None, None, :] + noise(ks[4], (b, f1, f2, d))
    else:
        x_seed = noise(ks[2], (b, d))
        x_h1 = noise(ks[3], (b, f1, d))
        x_h2 = noise(ks[4], (b, f1, f2, d))
    m_h1 = (jax.random.uniform(ks[5], (b, f1)) < 0.8).astype(jnp.float32)
    m_h2 = (jax.random.uniform(ks[6], (b, f1, f2)) < 0.8).astype(jnp.float32)
    m_h2 = m_h2 * m_h1[..., None]  # invalid hop-1 ⇒ invalid hop-2 subtree
    return [
        x_seed.astype(jnp.float32),
        x_h1.astype(jnp.float32),
        x_h2.astype(jnp.float32),
        m_h1,
        m_h2,
        y.astype(jnp.int32),
    ]
