"""AOT lowering: jax → HLO *text* artifacts for the rust PJRT runtime.

Emits, per `make artifacts` (spec via --spec / GG_SPEC):

    artifacts/gcn_grad.hlo.txt     (params..6, batch..6) → (loss, correct, grads..6)
    artifacts/gcn_apply.hlo.txt    (params..6, grads..6, lr) → params..6
    artifacts/gcn_forward.hlo.txt  (params..6, batch..5) → (logits,)
    artifacts/meta.json            shapes + argument order contract

HLO **text** is the interchange format, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that this image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import Spec


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract(shapes, dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]


def lower_all(spec: Spec):
    """Lower grad/apply/forward for `spec`; returns {name: hlo_text}."""
    pshapes = [spec.param_shapes()[n] for n in model.PARAM_NAMES]
    bshapes = spec.batch_shapes()
    params_av = _abstract(pshapes)
    feats_av = _abstract([bshapes[n] for n in model.BATCH_NAMES[:5]])
    y_av = jax.ShapeDtypeStruct(bshapes["y"], jnp.int32)

    def grad_fn(*flat):
        params = list(flat[:6])
        batch = list(flat[6:])
        return model.grad_step(params, batch)

    def apply_fn(*flat):
        params = list(flat[:6])
        grads = list(flat[6:12])
        lr = flat[12]
        return model.apply_step(params, grads, lr)

    def forward_fn(*flat):
        params = list(flat[:6])
        batch = list(flat[6:]) + [None]
        return (model.forward(params, batch),)

    lr_av = jax.ShapeDtypeStruct((), jnp.float32)
    out = {}
    out["gcn_grad"] = to_hlo_text(
        jax.jit(grad_fn).lower(*params_av, *feats_av, y_av)
    )
    out["gcn_apply"] = to_hlo_text(
        jax.jit(apply_fn).lower(*params_av, *params_av, lr_av)
    )
    out["gcn_forward"] = to_hlo_text(
        jax.jit(forward_fn).lower(*params_av, *feats_av)
    )
    return out


def build_meta(spec: Spec) -> dict:
    """The argument-order contract consumed by rust/src/train/runtime.rs."""
    return {
        "spec": {
            "batch": spec.batch,
            "f1": spec.f1,
            "f2": spec.f2,
            "dim": spec.dim,
            "hidden": spec.hidden,
            "classes": spec.classes,
        },
        "param_names": model.PARAM_NAMES,
        "param_shapes": [list(spec.param_shapes()[n]) for n in model.PARAM_NAMES],
        "batch_names": model.BATCH_NAMES,
        "batch_shapes": [list(spec.batch_shapes()[n]) for n in model.BATCH_NAMES],
        "artifacts": {
            "grad": {
                "file": "gcn_grad.hlo.txt",
                "inputs": model.PARAM_NAMES + model.BATCH_NAMES,
                "outputs": ["loss", "correct"] + [f"g_{n}" for n in model.PARAM_NAMES],
            },
            "apply": {
                "file": "gcn_apply.hlo.txt",
                "inputs": model.PARAM_NAMES + [f"g_{n}" for n in model.PARAM_NAMES] + ["lr"],
                "outputs": model.PARAM_NAMES,
            },
            "forward": {
                "file": "gcn_forward.hlo.txt",
                "inputs": model.PARAM_NAMES + model.BATCH_NAMES[:5],
                "outputs": ["logits"],
            },
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--spec",
        default=os.environ.get("GG_SPEC", ""),
        help='e.g. "b=32,f1=10,f2=5,d=32,h=64,c=8"',
    )
    args = parser.parse_args()
    spec = Spec.parse(args.spec)
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    artifacts = lower_all(spec)
    for name, text in artifacts.items():
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
    meta = build_meta(spec)
    (out_dir / "meta.json").write_text(json.dumps(meta, indent=2))
    print(f"wrote {out_dir / 'meta.json'} (spec={spec})")


if __name__ == "__main__":
    main()
