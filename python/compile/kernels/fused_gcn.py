"""L1 Pallas kernel: fused GraphSAGE-mean layer.

Computes ``relu(x_self @ Ws + x_agg @ Wn + b)`` in one kernel so the two
matmuls, bias add and activation share a single VMEM round trip.

TPU mapping (DESIGN.md §3): the grid blocks over N; per step one
``[bN, D]`` self tile and one ``[bN, D]`` aggregate tile are loaded, the
weight tiles ``[D, H]`` are replicated to every grid step (they fit VMEM
comfortably at these dims), and both ``[bN, D] x [D, H]`` products land on
the MXU (``preferred_element_type`` pins f32 accumulation; layout is
bf16-ready). This is the threadblock→BlockSpec rethink of the CUDA-style
fused GNN layer: tile residency in VMEM replaces shared-memory staging.

Like `aggregate.masked_mean`, the kernel carries a custom VJP with a plain
dense backward (Pallas calls have no transpose rule).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _sage_kernel(xs_ref, xa_ref, ws_ref, wn_ref, b_ref, o_ref):
    xs = xs_ref[...]  # [bN, D]
    xa = xa_ref[...]  # [bN, D]
    ws = ws_ref[...]  # [D, H]
    wn = wn_ref[...]  # [D, H]
    b = b_ref[...]  # [1, H]
    z = (
        jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        + jnp.dot(xa, wn, preferred_element_type=jnp.float32)
        + b
    )
    o_ref[...] = jnp.maximum(z, 0.0).astype(o_ref.dtype)


def _sage_pallas(xs, xa, ws, wn, b, block_n):
    n, d = xs.shape
    h = ws.shape[1]
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    b2 = b.reshape(1, h)
    return pl.pallas_call(
        _sage_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),  # weights: whole array
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), xs.dtype),
        interpret=True,
    )(xs, xa, ws, wn, b2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def sage_layer(xs, xa, ws, wn, b, block_n: int = BLOCK_N):
    """Fused ``relu(xs @ ws + xa @ wn + b)``.

    Semantics defined by :func:`..ref.sage_layer_ref`.
    ``xs, xa: [N, D]``; ``ws, wn: [D, H]``; ``b: [H]`` → ``[N, H]``.
    """
    return _sage_pallas(xs, xa, ws, wn, b, block_n)


def _sage_fwd(xs, xa, ws, wn, b, block_n):
    out = _sage_pallas(xs, xa, ws, wn, b, block_n)
    return out, (xs, xa, ws, wn, out)


def _sage_bwd(block_n, res, g):
    del block_n
    xs, xa, ws, wn, out = res
    dz = g * (out > 0).astype(g.dtype)  # relu gate
    dxs = dz @ ws.T
    dxa = dz @ wn.T
    dws = xs.T @ dz
    dwn = xa.T @ dz
    db = jnp.sum(dz, axis=0)
    return dxs, dxa, dws, dwn, db


sage_layer.defvjp(_sage_fwd, _sage_bwd)
