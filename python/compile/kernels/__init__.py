"""L1: Pallas kernels (build-time only) and their pure-jnp references."""

from . import aggregate, fused_gcn, ref  # noqa: F401
