"""L1 Pallas kernel: masked neighbor aggregation (masked mean over K).

The GNN sampling hot-spot: for every node, average the features of its
(padded) sampled neighbors. Fixed fanout sampling gives static ``[N, K, D]``
shapes, so the whole aggregation is dense + masked — no dynamic gather on
the hot path (DESIGN.md §3, hardware adaptation).

TPU mapping: the grid blocks over N; one ``[bN, K, D]`` feature tile and a
``[bN, K]`` mask tile live in VMEM per step; the reduction over K runs on
the VPU. ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO with identical
numerics (see /opt/xla-example/README.md).

The kernel carries a ``jax.custom_vjp``: Pallas calls have no transpose
rule, and the backward pass is cheap dense math that XLA fuses well. The
mask cotangent is defined as zero — masks are data, never parameters, so
no gradient ever flows through them in the model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Grid block over N. 128 rows keeps the VMEM tile small (see DESIGN.md §7)
# while amortizing grid overhead.
BLOCK_N = 128


def _masked_mean_kernel(x_ref, m_ref, o_ref):
    x = x_ref[...]  # [bN, K, D]
    m = m_ref[...].astype(x.dtype)  # [bN, K]
    s = jnp.sum(x * m[..., None], axis=1)  # [bN, D]
    cnt = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)
    o_ref[...] = s / cnt


def _masked_mean_pallas(x: jax.Array, m: jax.Array, block_n: int) -> jax.Array:
    n, k, d = x.shape
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    return pl.pallas_call(
        _masked_mean_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def masked_mean(x: jax.Array, m: jax.Array, block_n: int = BLOCK_N) -> jax.Array:
    """Masked mean over axis 1. ``x: [N, K, D]``, ``m: [N, K]`` → ``[N, D]``.

    Semantics defined by :func:`..ref.masked_mean_ref`.
    """
    return _masked_mean_pallas(x, m, block_n)


def _masked_mean_fwd(x, m, block_n):
    out = _masked_mean_pallas(x, m, block_n)
    return out, (m,)


def _masked_mean_bwd(block_n, res, g):
    (m,) = res
    del block_n
    mf = m.astype(g.dtype)
    cnt = jnp.maximum(jnp.sum(mf, axis=1, keepdims=True), 1.0)  # [N, 1]
    # d/dx[n,k,d] = g[n,d] * m[n,k] / cnt[n]
    dx = g[:, None, :] * mf[..., None] / cnt[..., None]
    # Masks are data (0/1 pads), never parameters: zero cotangent.
    dm = jnp.zeros_like(m)
    return dx, dm


masked_mean.defvjp(_masked_mean_fwd, _masked_mean_bwd)
