"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has its semantics defined *here*; the
pytest suite asserts `assert_allclose(kernel(x), ref(x))` across a
hypothesis-driven sweep of shapes and dtypes. The L2 model can also be run
entirely on these references (`model.forward(..., use_kernels=False)`),
which is how kernel-vs-reference equivalence is checked end-to-end.
"""

import jax
import jax.numpy as jnp


def masked_mean_ref(x: jax.Array, m: jax.Array) -> jax.Array:
    """Masked mean over the K axis.

    Args:
      x: ``[N, K, D]`` neighbor features.
      m: ``[N, K]`` validity mask (0/1 floats).

    Returns:
      ``[N, D]`` — ``sum_k x[:, k] * m[:, k] / max(sum_k m[:, k], 1)``.
      Rows with no valid neighbors yield zeros.
    """
    s = jnp.einsum("nkd,nk->nd", x, m.astype(x.dtype))
    cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0).astype(x.dtype)
    return s / cnt


def sage_layer_ref(
    x_self: jax.Array,
    x_agg: jax.Array,
    w_self: jax.Array,
    w_neigh: jax.Array,
    b: jax.Array,
) -> jax.Array:
    """GraphSAGE-mean layer: ``relu(x_self @ Ws + x_agg @ Wn + b)``.

    Args:
      x_self:  ``[N, D]`` node's own features.
      x_agg:   ``[N, D]`` aggregated neighbor features.
      w_self:  ``[D, H]``; w_neigh: ``[D, H]``; b: ``[H]``.

    Returns:
      ``[N, H]``.
    """
    return jax.nn.relu(x_self @ w_self + x_agg @ w_neigh + b)
