"""L2 correctness: GCN model shapes, kernel/reference parity, gradient
checks against finite differences, and a learnability smoke test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import Spec

jax.config.update("jax_platform_name", "cpu")

SMALL = Spec(batch=8, f1=4, f2=3, dim=8, hidden=16, classes=4)


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(SMALL, jax.random.PRNGKey(0))
    batch = model.example_batch(SMALL, jax.random.PRNGKey(1))
    return params, batch


class TestForward:
    def test_logits_shape(self, setup):
        params, batch = setup
        logits = model.forward(params, batch)
        assert logits.shape == (SMALL.batch, SMALL.classes)
        assert logits.dtype == jnp.float32

    def test_kernels_equal_reference(self, setup):
        params, batch = setup
        with_k = model.forward(params, batch, use_kernels=True)
        without = model.forward(params, batch, use_kernels=False)
        np.testing.assert_allclose(np.array(with_k), np.array(without), rtol=2e-5, atol=2e-5)

    def test_masked_neighbors_do_not_leak(self, setup):
        """Changing features of masked-out neighbors must not change logits."""
        params, batch = setup
        x_seed, x_h1, x_h2, m_h1, m_h2, y = batch
        logits0 = model.forward(params, batch)
        # Poison every masked position with huge values.
        x_h1_p = x_h1 + (1.0 - m_h1)[..., None] * 1e6
        x_h2_p = x_h2 + (1.0 - m_h2)[..., None] * 1e6
        logits1 = model.forward(params, [x_seed, x_h1_p, x_h2_p, m_h1, m_h2, y])
        np.testing.assert_allclose(np.array(logits0), np.array(logits1), rtol=1e-4, atol=1e-3)

    def test_batch_independence(self, setup):
        """Row b of the logits depends only on row b of the batch."""
        params, batch = setup
        logits = model.forward(params, batch)
        # Zero out everything except row 0.
        cut = [
            jnp.concatenate([t[:1], jnp.zeros_like(t[1:])], axis=0) for t in batch[:5]
        ] + [batch[5]]
        logits_cut = model.forward(params, cut)
        np.testing.assert_allclose(
            np.array(logits[0]), np.array(logits_cut[0]), rtol=1e-4, atol=1e-4
        )


class TestLossAndGrad:
    def test_loss_is_finite_positive(self, setup):
        params, batch = setup
        loss, correct = model.loss_and_acc(params, batch)
        assert np.isfinite(float(loss)) and float(loss) > 0
        assert 0 <= float(correct) <= SMALL.batch

    def test_grad_step_output_arity(self, setup):
        params, batch = setup
        out = model.grad_step(params, batch)
        assert len(out) == 2 + len(model.PARAM_NAMES)
        for g, p in zip(out[2:], params):
            assert g.shape == p.shape

    def test_grads_match_finite_differences(self, setup):
        params, batch = setup
        out = model.grad_step(params, batch)
        grads = out[2:]
        # Check a few random coordinates of each parameter.
        rng = np.random.RandomState(0)
        eps = 1e-3
        for pi in range(len(params)):
            p = np.array(params[pi])
            flat_idx = rng.choice(p.size, size=min(3, p.size), replace=False)
            for fi in flat_idx:
                idx = np.unravel_index(fi, p.shape)
                bump = np.zeros_like(p)
                bump[idx] = eps
                pp = [
                    jnp.array(np.array(q) + (bump if qi == pi else 0))
                    for qi, q in enumerate(params)
                ]
                pm = [
                    jnp.array(np.array(q) - (bump if qi == pi else 0))
                    for qi, q in enumerate(params)
                ]
                lp, _ = model.loss_and_acc(pp, batch, use_kernels=False)
                lm, _ = model.loss_and_acc(pm, batch, use_kernels=False)
                fd = (float(lp) - float(lm)) / (2 * eps)
                an = float(np.array(grads[pi])[idx])
                assert abs(fd - an) < 5e-3 + 0.05 * abs(an), (
                    f"param {model.PARAM_NAMES[pi]} idx {idx}: fd={fd} an={an}"
                )

    def test_kernel_grads_equal_reference_grads(self, setup):
        params, batch = setup
        with_k = model.grad_step(params, batch, use_kernels=True)
        without = model.grad_step(params, batch, use_kernels=False)
        for a, b in zip(with_k, without):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-5)


class TestTraining:
    def test_loss_decreases_on_learnable_data(self):
        spec = SMALL
        params = list(model.init_params(spec, jax.random.PRNGKey(2)))
        losses = []
        for step in range(60):
            batch = model.example_batch(spec, jax.random.PRNGKey(100 + step % 8))
            out = model.grad_step(params, batch)
            losses.append(float(out[0]))
            params = list(model.apply_step(params, out[2:], 0.05))
        assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5]), losses[::10]

    def test_apply_step_is_sgd(self, setup):
        params, _ = setup
        grads = [jnp.ones_like(p) for p in params]
        new = model.apply_step(params, grads, 0.5)
        for p, n in zip(params, new):
            np.testing.assert_allclose(np.array(n), np.array(p) - 0.5, rtol=1e-6)


class TestSpec:
    def test_parse_roundtrip(self):
        s = Spec.parse("b=16,f1=7,f2=2,d=12,h=24,c=3")
        assert (s.batch, s.f1, s.f2, s.dim, s.hidden, s.classes) == (16, 7, 2, 12, 24, 3)

    def test_parse_defaults(self):
        s = Spec.parse("")
        assert s == Spec()
        s2 = Spec.parse("b=4")
        assert s2.batch == 4 and s2.f1 == Spec().f1

    def test_shapes_consistent(self):
        s = Spec()
        assert s.batch_shapes()["x_h2"] == (s.batch, s.f1, s.f2, s.dim)
        assert s.param_shapes()["ws2"] == (s.hidden, s.classes)
