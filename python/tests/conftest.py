import pathlib
import sys

# Make `compile` importable regardless of pytest's rootdir.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
