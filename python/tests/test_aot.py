"""AOT pipeline: lowered HLO text artifacts + meta.json contract."""

import json

import jax
import pytest

from compile import aot, model
from compile.model import Spec

jax.config.update("jax_platform_name", "cpu")

TINY = Spec(batch=4, f1=3, f2=2, dim=6, hidden=8, classes=3)


@pytest.fixture(scope="module")
def artifacts():
    return aot.lower_all(TINY)


class TestLowering:
    def test_emits_all_three(self, artifacts):
        assert set(artifacts) == {"gcn_grad", "gcn_apply", "gcn_forward"}

    def test_hlo_text_is_parseable_header(self, artifacts):
        for name, text in artifacts.items():
            assert text.startswith("HloModule"), name
            assert "ROOT" in text, name

    def test_grad_signature_arity(self, artifacts):
        # 6 params + 5 feature tensors + labels = 12 inputs.
        header = artifacts["gcn_grad"].splitlines()[0]
        assert header.count("f32[") + header.count("s32[") >= 12

    def test_no_custom_calls(self, artifacts):
        """interpret=True Pallas must lower to plain HLO (a Mosaic
        custom-call would be unloadable by the CPU PJRT client)."""
        for name, text in artifacts.items():
            assert "custom-call" not in text, f"{name} contains custom-call"

    def test_apply_is_pure_elementwise(self, artifacts):
        # SGD: subtract/multiply only — no dot ops.
        assert "dot(" not in artifacts["gcn_apply"]


class TestMeta:
    def test_meta_matches_spec(self):
        meta = aot.build_meta(TINY)
        assert meta["spec"]["batch"] == 4
        assert meta["param_names"] == model.PARAM_NAMES
        assert meta["batch_shapes"][1] == [4, 3, 6]  # x_h1 [B, F1, D]
        assert meta["artifacts"]["grad"]["outputs"][0] == "loss"
        # json-serializable
        json.dumps(meta)

    def test_meta_input_order_is_params_then_batch(self):
        meta = aot.build_meta(TINY)
        inputs = meta["artifacts"]["grad"]["inputs"]
        assert inputs[:6] == model.PARAM_NAMES
        assert inputs[6:] == model.BATCH_NAMES


class TestEndToEndWrite(object):
    def test_main_writes_files(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            "sys.argv",
            ["aot", "--out-dir", str(tmp_path), "--spec", "b=4,f1=3,f2=2,d=6,h=8,c=3"],
        )
        aot.main()
        for f in ["gcn_grad.hlo.txt", "gcn_apply.hlo.txt", "gcn_forward.hlo.txt", "meta.json"]:
            assert (tmp_path / f).exists(), f
        meta = json.loads((tmp_path / "meta.json").read_text())
        assert meta["spec"]["classes"] == 3
