"""L1 correctness: Pallas kernels vs. pure-jnp references.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.py — the core correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.aggregate import masked_mean
from compile.kernels.fused_gcn import sage_layer

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=17)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestMaskedMean:
    @settings(max_examples=25, deadline=None)
    @given(n=dims, k=dims, d=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, k, d, seed):
        k1, k2 = keys(seed, 2)
        x = rand(k1, (n, k, d), jnp.float32)
        m = (jax.random.uniform(k2, (n, k)) < 0.7).astype(jnp.float32)
        got = masked_mean(x, m)
        want = ref.masked_mean_ref(x, m)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(n=dims, k=dims, d=dims, seed=st.integers(0, 2**31 - 1))
    def test_bfloat16_matches_ref(self, n, k, d, seed):
        k1, k2 = keys(seed, 2)
        x = rand(k1, (n, k, d), jnp.bfloat16)
        m = (jax.random.uniform(k2, (n, k)) < 0.7).astype(jnp.bfloat16)
        got = masked_mean(x, m)
        want = ref.masked_mean_ref(x, m)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.array(got, np.float32), np.array(want, np.float32), rtol=3e-2, atol=3e-2
        )

    def test_all_masked_row_is_zero(self):
        x = jnp.ones((3, 4, 5))
        m = jnp.zeros((3, 4))
        out = masked_mean(x, m)
        np.testing.assert_array_equal(np.array(out), np.zeros((3, 5)))

    def test_full_mask_is_plain_mean(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (6, 7, 8))
        m = jnp.ones((6, 7))
        np.testing.assert_allclose(
            np.array(masked_mean(x, m)), np.array(x.mean(axis=1)), rtol=1e-5, atol=1e-6
        )

    def test_blocking_boundary_cases(self):
        # n not divisible by the block, n == 1, n == block exactly.
        for n in [1, 127, 128, 129, 300]:
            x = jax.random.normal(jax.random.PRNGKey(n), (n, 3, 4))
            m = jnp.ones((n, 3))
            got = masked_mean(x, m)
            want = ref.masked_mean_ref(x, m)
            np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(n=dims, k=dims, d=dims, seed=st.integers(0, 2**31 - 1))
    def test_gradient_matches_ref(self, n, k, d, seed):
        """The custom VJP must agree with jnp autodiff of the reference."""
        k1, k2, k3 = keys(seed, 3)
        x = rand(k1, (n, k, d), jnp.float32)
        m = (jax.random.uniform(k2, (n, k)) < 0.7).astype(jnp.float32)
        w = rand(k3, (d,), jnp.float32)

        def f_kernel(x):
            return jnp.sum(masked_mean(x, m) * w)

        def f_ref(x):
            return jnp.sum(ref.masked_mean_ref(x, m) * w)

        gk = jax.grad(f_kernel)(x)
        gr = jax.grad(f_ref)(x)
        np.testing.assert_allclose(np.array(gk), np.array(gr), rtol=1e-4, atol=1e-5)


class TestSageLayer:
    @settings(max_examples=25, deadline=None)
    @given(n=dims, d=dims, h=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, d, h, seed):
        k1, k2, k3, k4, k5 = keys(seed, 5)
        xs = rand(k1, (n, d), jnp.float32)
        xa = rand(k2, (n, d), jnp.float32)
        ws = rand(k3, (d, h), jnp.float32)
        wn = rand(k4, (d, h), jnp.float32)
        b = rand(k5, (h,), jnp.float32)
        got = sage_layer(xs, xa, ws, wn, b)
        want = ref.sage_layer_ref(xs, xa, ws, wn, b)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)

    def test_relu_clamps(self):
        xs = -jnp.ones((4, 3)) * 100.0
        xa = jnp.zeros((4, 3))
        ws = jnp.eye(3)
        wn = jnp.zeros((3, 3))
        b = jnp.zeros((3,))
        out = sage_layer(xs, xa, ws, wn, b)
        np.testing.assert_array_equal(np.array(out), np.zeros((4, 3)))

    @settings(max_examples=10, deadline=None)
    @given(n=dims, d=dims, h=dims, seed=st.integers(0, 2**31 - 1))
    def test_gradients_match_ref(self, n, d, h, seed):
        k1, k2, k3, k4, k5 = keys(seed, 5)
        xs = rand(k1, (n, d), jnp.float32)
        xa = rand(k2, (n, d), jnp.float32)
        ws = rand(k3, (d, h), jnp.float32)
        wn = rand(k4, (d, h), jnp.float32)
        b = rand(k5, (h,), jnp.float32)

        def f_kernel(ws, wn, b, xs, xa):
            return jnp.sum(sage_layer(xs, xa, ws, wn, b) ** 2)

        def f_ref(ws, wn, b, xs, xa):
            return jnp.sum(ref.sage_layer_ref(xs, xa, ws, wn, b) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3, 4))(ws, wn, b, xs, xa)
        gr = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(ws, wn, b, xs, xa)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(np.array(a), np.array(r), rtol=1e-3, atol=1e-4)

    def test_block_boundaries(self):
        for n in [1, 127, 128, 129]:
            key = jax.random.PRNGKey(n)
            xs = jax.random.normal(key, (n, 5))
            out = sage_layer(xs, xs, jnp.eye(5), jnp.eye(5), jnp.zeros((5,)))
            want = ref.sage_layer_ref(xs, xs, jnp.eye(5), jnp.eye(5), jnp.zeros((5,)))
            np.testing.assert_allclose(np.array(out), np.array(want), rtol=1e-5, atol=1e-6)


class TestKernelsInsideJit:
    def test_kernels_compose_under_jit(self):
        @jax.jit
        def f(x, m, ws, wn, b):
            agg = masked_mean(x, m)
            return sage_layer(agg, agg, ws, wn, b)

        x = jax.random.normal(jax.random.PRNGKey(0), (9, 4, 6))
        m = jnp.ones((9, 4))
        ws = jax.random.normal(jax.random.PRNGKey(1), (6, 3))
        wn = jax.random.normal(jax.random.PRNGKey(2), (6, 3))
        b = jnp.zeros((3,))
        got = f(x, m, ws, wn, b)
        agg = ref.masked_mean_ref(x, m)
        want = ref.sage_layer_ref(agg, agg, ws, wn, b)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)
