#!/usr/bin/env python3
"""Validate and summarize a Chrome trace-event timeline (--trace-out).

Usage: trace_summary.py <trace.json> [--min-tracks N]

Checks the document is well-formed trace-event JSON (Object Format:
{"traceEvents": [...]}) that Perfetto / chrome://tracing will load:
every event carries pid/tid, a known phase (X duration span, i instant,
M metadata), non-negative timestamps and durations. Then prints one row
per track (thread_name metadata → label) with its span/instant counts
and busy fraction (union of span intervals over the trace's time
extent, so overlapping or nested spans are not double-counted).

--min-tracks N fails (exit 1) unless at least N tracks recorded at
least one span or instant — the CI smoke bar that proves the tracer is
actually threaded through every concurrency layer, not just compiled
in. Malformed input also exits 1; usage errors exit 2.
"""

import json
import sys

KNOWN_PHASES = {"X", "i", "M"}


def fail(msg):
    print(f"trace summary: ERROR: {msg}")
    return 1


def merged_busy_us(intervals):
    """Total length of the union of [start, end) intervals."""
    busy = 0
    end = None
    for s, e in sorted(intervals):
        if end is None or s > end:
            busy += e - s
            end = e
        elif e > end:
            busy += e - end
            end = e
    return busy


def validate_event(i, ev):
    """One malformed-event description, or None if the event is fine."""
    if not isinstance(ev, dict):
        return f"event {i} is not an object"
    ph = ev.get("ph")
    if ph not in KNOWN_PHASES:
        return f"event {i} has unknown phase {ph!r}"
    for key in ("pid", "tid"):
        if not isinstance(ev.get(key), (int, float)):
            return f"event {i} ({ev.get('name')!r}) lacks numeric {key!r}"
    if ph == "M":
        return None
    ts = ev.get("ts")
    if not isinstance(ts, (int, float)) or ts < 0:
        return f"event {i} ({ev.get('name')!r}) has bad ts {ts!r}"
    if ph == "X":
        dur = ev.get("dur")
        if not isinstance(dur, (int, float)) or dur < 0:
            return f"event {i} ({ev.get('name')!r}) has bad dur {dur!r}"
    return None


def main() -> int:
    args = sys.argv[1:]
    min_tracks = 0
    if "--min-tracks" in args:
        at = args.index("--min-tracks")
        try:
            min_tracks = int(args[at + 1])
        except (IndexError, ValueError):
            print(__doc__)
            return 2
        del args[at : at + 2]
    if len(args) != 1:
        print(__doc__)
        return 2
    path = args[0]

    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        return fail(f"cannot read {path} ({e.strerror})")
    except json.JSONDecodeError as e:
        return fail(f"{path} is not valid JSON ({e})")

    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        return fail(f"{path} has no traceEvents array")

    names = {}  # tid -> thread_name label
    spans = {}  # tid -> [(start, end)]
    instants = {}  # tid -> count
    t_min, t_max = None, None
    for i, ev in enumerate(events):
        problem = validate_event(i, ev)
        if problem is not None:
            return fail(problem)
        tid = ev["tid"]
        ph = ev["ph"]
        if ph == "M":
            if ev.get("name") == "thread_name":
                names[tid] = ev.get("args", {}).get("name", "?")
            continue
        ts = ev["ts"]
        end = ts + ev["dur"] if ph == "X" else ts
        t_min = ts if t_min is None else min(t_min, ts)
        t_max = end if t_max is None else max(t_max, end)
        if ph == "X":
            spans.setdefault(tid, []).append((ts, end))
        else:
            instants[tid] = instants.get(tid, 0) + 1

    tids = sorted(set(spans) | set(instants))
    extent_us = (t_max - t_min) if tids else 0
    print(f"{path}: {len(events)} events, {len(tids)} active tracks, "
          f"extent {extent_us / 1e6:.3f}s, "
          f"dropped {doc.get('otherData', {}).get('dropped_events', 0):g}")
    print(f"{'track':<24} {'spans':>8} {'instants':>8} {'busy':>9} {'bubble':>9}")
    for tid in tids:
        track_spans = spans.get(tid, [])
        busy = merged_busy_us(track_spans)
        frac = busy / extent_us if extent_us else 0.0
        bubble = (1.0 - frac) if track_spans else 0.0
        print(f"{names.get(tid, f'tid-{tid:g}'):<24} {len(track_spans):>8} "
              f"{instants.get(tid, 0):>8} {frac:>8.1%} {bubble:>8.1%}")

    if len(tids) < min_tracks:
        return fail(f"only {len(tids)} active tracks, need >= {min_tracks} "
                    f"(is the tracer threaded through every layer?)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
