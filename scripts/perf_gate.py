#!/usr/bin/env python3
"""CI perf gate over the E1 trajectory files.

Usage: perf_gate.py <previous BENCH_e1.json> <current BENCH_e1.json>

Compares graphgen+ generation throughput (nodes/sec, 1-core wall) against
the previous main run's artifact and fails on a regression larger than
THRESHOLD. Missing/unreadable previous data skips the gate (first run,
expired artifact) rather than failing it.
"""

import json
import sys

THRESHOLD = 0.20  # fail on >20% nodes/sec regression
ENGINES = ("graphgen+",)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    prev_path, cur_path = sys.argv[1], sys.argv[2]
    try:
        with open(prev_path) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate: no usable previous trajectory ({e}); skipping")
        return 0
    with open(cur_path) as f:
        cur = json.load(f)

    failures = []
    for engine in ENGINES:
        p = prev.get("engines", {}).get(engine, {}).get("nodes_per_sec_wall")
        c = cur.get("engines", {}).get(engine, {}).get("nodes_per_sec_wall")
        if not p or not c:
            print(f"perf gate: missing nodes_per_sec_wall for {engine}; skipping")
            continue
        ratio = c / p
        print(f"perf gate: {engine} nodes/sec {p:,.0f} -> {c:,.0f} ({ratio:.2f}x)")
        if ratio < 1.0 - THRESHOLD:
            failures.append(
                f"{engine} regressed {(1.0 - ratio) * 100:.0f}% "
                f"(threshold {THRESHOLD * 100:.0f}%)"
            )
    for f_ in failures:
        print(f"PERF REGRESSION: {f_}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
