#!/usr/bin/env python3
"""CI perf gate over the E1/E6/E7/E2/E5 trajectory files.

Usage: perf_gate.py <prev BENCH_e1.json> <cur BENCH_e1.json> \
                    [<prev BENCH_e6.json> <cur BENCH_e6.json> \
                     [<prev BENCH_e7.json> <cur BENCH_e7.json> \
                      [<prev BENCH_e2.json> <cur BENCH_e2.json> \
                       [<prev BENCH_e5.json> <cur BENCH_e5.json>]]]]

Compares graphgen+ generation throughput (nodes/sec, 1-core wall), —
when the e6 pair is given — end-to-end pipeline iterations/sec, — when
the e7 pair is given — per-batch feature-gather latency, and — when the
e2 pair is given — the parallel large-scale graph build time (chained
prefix scans; lower is better) against the previous main run's
artifacts, failing on a regression larger than THRESHOLD.
Missing/unreadable previous data skips that gate (first run, expired
artifact) rather than failing it.

The e7 and e5 trajectories also carry the tiered-memory out-of-core
scale points: their paged-vs-resident throughput ratios
("iters_per_sec_ratio", higher is better) are gated both against the
previous run and against the absolute floor TIER_MIN_RATIO. Baselines
written before the tier existed simply lack the keys and skip.
"""

import json
import sys

THRESHOLD = 0.20  # fail on >20% regression
ENGINES = ("graphgen+",)
# e1 also carries a measured multi-process cluster point ("dist":
# coordinator + real gg-worker processes; cluster_time_ms, lower is
# better) since the distributed runtime landed. Process spawn + socket
# transport are noisy on shared CI runners, so its threshold is looser.
# Pre-distributed baselines simply lack the key and skip.
DIST_METRIC = "cluster_time_ms"
DIST_THRESHOLD = 0.50
# Since the recovery subsystem landed, e1 also measures the same cluster
# run with durable checkpoints enabled ("dist_ckpt"): its cluster time is
# gated with the same loose threshold so checkpoint overhead cannot
# quietly grow into the steady state.
# e6 gate metric, in preference order: the full concurrent pipeline's
# iterations/sec when artifacts were available, else the generation-only
# trajectory's waves/sec (both recorded as "iters_per_sec").
E6_MODES = ("concurrent", "pipelined")
# e7 gate metric: measured wall + modeled transfer per batch of the
# steady-state sharded+batched+cache variant (lower is better).
E7_VARIANT = "sharded + batched fetch + cache"
E7_METRIC = "total_per_batch_s"
# e2 gate metric: parallel CSR build time at the largest bench scale —
# the decoupled-lookback scan spine's end-to-end cost (lower is better).
E2_SCALE = "large"
E2_METRIC = "csr_build_ms_parallel"
# Tiered-memory out-of-core points (e7 "tier", e5 "out_of_core"): the
# paged side must retain at least this fraction of resident throughput
# no matter what the baseline says — a hard floor on paging overhead.
TIER_MIN_RATIO = 0.02
TIER_METRIC = "iters_per_sec_ratio"


def load(path):
    """Baseline loader: a missing or unreadable *previous* trajectory is
    normal (first run, expired artifact) and skips that gate cleanly."""
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"perf gate: no previous trajectory at {path} ({e.strerror}); skipping")
        return None
    except json.JSONDecodeError as e:
        print(f"perf gate: previous trajectory at {path} is not valid JSON ({e}); skipping")
        return None


def load_current(path, label):
    """Current-run loader: every bench is expected to emit its trajectory
    on every run (fallback paths included), so a missing or malformed
    *current* file means the bench itself broke — fail the gate with a
    readable message instead of a traceback, and never silently skip."""
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"perf gate: ERROR: current {label} trajectory missing at {path} ({e.strerror})")
    except json.JSONDecodeError as e:
        print(f"perf gate: ERROR: current {label} trajectory at {path} is not valid JSON ({e})")
    return None


def e6_iters_per_sec(data):
    """(mode, iters_per_sec) from an e6 trajectory, or (None, None)."""
    modes = data.get("modes", {})
    for mode in E6_MODES:
        v = modes.get(mode, {}).get("iters_per_sec")
        if v:
            return mode, v
    return None, None


def check(label, prev, cur, failures, lower_is_better=False, threshold=THRESHOLD):
    if not prev or not cur:
        print(f"perf gate: missing {label}; skipping")
        return
    ratio = cur / prev
    print(f"perf gate: {label} {prev:,.6f} -> {cur:,.6f} ({ratio:.2f}x)")
    regressed = ratio > 1.0 + threshold if lower_is_better else ratio < 1.0 - threshold
    if regressed:
        moved = (ratio - 1.0) if lower_is_better else (1.0 - ratio)
        failures.append(
            f"{label} regressed {moved * 100:.0f}% "
            f"(threshold {threshold * 100:.0f}%)"
        )


def check_tier_ratio(label, prev_tier, cur_tier, failures):
    """Gate one out-of-core scale point (a "tier"/"out_of_core" sub-dict
    holding TIER_METRIC, higher is better): relative regression vs the
    previous run when it recorded the point, plus the absolute floor on
    the current value. Pre-tier baselines lack the key and skip the
    relative check; a current run missing it means the bench broke."""
    c = (cur_tier or {}).get(TIER_METRIC)
    if c is None:
        failures.append(f"{label}: current trajectory lacks {TIER_METRIC}")
        return
    if c < TIER_MIN_RATIO:
        failures.append(
            f"{label} {TIER_METRIC} {c:.4f} below absolute floor {TIER_MIN_RATIO}"
        )
    p = (prev_tier or {}).get(TIER_METRIC)
    if p is None:
        print(f"perf gate: no previous {label} {TIER_METRIC}; floor-only")
        return
    check(f"{label} {TIER_METRIC}", p, c, failures)


def main() -> int:
    if len(sys.argv) not in (3, 5, 7, 9, 11):
        print(__doc__)
        return 2
    failures = []

    prev = load(sys.argv[1])
    cur = load_current(sys.argv[2], "e1")
    if cur is None:
        return 1
    if prev is not None:
        for engine in ENGINES:
            p = prev.get("engines", {}).get(engine, {}).get("nodes_per_sec_wall")
            c = cur.get("engines", {}).get(engine, {}).get("nodes_per_sec_wall")
            check(f"e1 {engine} nodes/sec", p, c, failures)
        for key in ("dist", "dist_ckpt"):
            p = prev.get(key, {}).get(DIST_METRIC)
            c = cur.get(key, {}).get(DIST_METRIC)
            if p is None or c is None:
                print(f"perf gate: no e1 {key} {DIST_METRIC} pair; skipping")
            else:
                check(
                    f"e1 {key} {DIST_METRIC}",
                    p,
                    c,
                    failures,
                    lower_is_better=True,
                    threshold=DIST_THRESHOLD,
                )

    if len(sys.argv) >= 5:
        prev6 = load(sys.argv[3])
        cur6 = load_current(sys.argv[4], "e6")
        if cur6 is None:
            return 1
        if prev6 is not None:
            pmode, p = e6_iters_per_sec(prev6)
            cmode, c = e6_iters_per_sec(cur6)
            if pmode != cmode:
                # Artifact availability changed between runs; the metrics
                # aren't comparable (training vs generation-only rates).
                print(
                    f"perf gate: e6 mode changed ({pmode} -> {cmode}); skipping"
                )
            else:
                check(f"e6 {cmode} iters/sec", p, c, failures)

    if len(sys.argv) >= 7:
        prev7 = load(sys.argv[5])
        cur7 = load_current(sys.argv[6], "e7")
        if cur7 is None:
            return 1
        if prev7 is not None:
            p = prev7.get("variants", {}).get(E7_VARIANT, {}).get(E7_METRIC)
            c = cur7.get("variants", {}).get(E7_VARIANT, {}).get(E7_METRIC)
            check(
                f"e7 {E7_VARIANT} {E7_METRIC}",
                p,
                c,
                failures,
                lower_is_better=True,
            )
        check_tier_ratio(
            "e7 tier",
            (prev7 or {}).get("tier"),
            cur7.get("tier"),
            failures,
        )

    if len(sys.argv) >= 9:
        prev2 = load(sys.argv[7])
        cur2 = load_current(sys.argv[8], "e2")
        if cur2 is None:
            return 1
        if prev2 is not None:
            p = prev2.get("build", {}).get(E2_SCALE, {}).get(E2_METRIC)
            c = cur2.get("build", {}).get(E2_SCALE, {}).get(E2_METRIC)
            check(
                f"e2 build.{E2_SCALE}.{E2_METRIC}",
                p,
                c,
                failures,
                lower_is_better=True,
            )

    if len(sys.argv) == 11:
        prev5 = load(sys.argv[9])
        cur5 = load_current(sys.argv[10], "e5")
        if cur5 is None:
            return 1
        check_tier_ratio(
            "e5 out_of_core",
            (prev5 or {}).get("out_of_core"),
            cur5.get("out_of_core"),
            failures,
        )

    for f_ in failures:
        print(f"PERF REGRESSION: {f_}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
