#!/usr/bin/env bash
# Deterministic chaos soak for the distributed layer.
#
# For each seed in a fixed list, runs a real coordinator + 2 gg-worker
# processes under a seeded chaos schedule (--chaos: worker aborts
# mid-wave, CRC-corrupted result frames, heartbeat freezes, wave
# stalls), SIGKILLs the coordinator once its first durable checkpoint
# lands, relaunches the identical command with --resume, and requires
# the final subgraph dump to be byte-identical to the single-process
# oracle. Afterwards it asserts that, across the soak, every recovery
# counter (checkpoints written, coordinator resumes, worker respawns,
# corrupted frames) actually fired — a soak that never exercised the
# machinery would pass vacuously otherwise.
#
# Usage: chaos_soak.sh [path-to-graphgen-plus-binary]
# Expected to run under an outer hard `timeout` in CI.
set -euo pipefail

BIN="${1:-./target/release/graphgen-plus}"
SEEDS=(1 2 3 4 5 6 7 8)
COMMON=(--graph rmat:n=4096,e=32768 --num-seeds 512 --wave-size 16
        --workers 4 --threads 2)

work="$(mktemp -d "${TMPDIR:-/tmp}/gg-chaos-soak.XXXXXX")"
trap 'rm -rf "$work"' EXIT

echo "== oracle (single process) =="
timeout 120 "$BIN" generate "${COMMON[@]}" \
  --subgraph-bytes-out "$work/oracle.bin" >/dev/null

for seed in "${SEEDS[@]}"; do
  dir="$work/chaos-$seed"
  out="$work/chaos-$seed.bin"
  run=("$BIN" generate "${COMMON[@]}" --processes 2
       --heartbeat-ms 50 --lease-ms 500 --checkpoint-waves 4
       --respawn-budget 8 --chaos "$seed"
       --run-dir "$dir" --subgraph-bytes-out "$out")

  echo "== seed $seed: first incarnation (coordinator will be SIGKILLed) =="
  # Slow waves stretch the run so the kill lands mid-flight; the fault
  # env is deliberately not part of the config hash, so the resume run
  # can drop it.
  GG_FAULT_SLOW_WAVE_MS=100 "${run[@]}" >/dev/null 2>&1 &
  pid=$!
  for _ in $(seq 1 600); do
    [ -f "$dir/checkpoint.bin" ] && break
    kill -0 "$pid" 2>/dev/null || break
    sleep 0.05
  done
  kill -9 "$pid" 2>/dev/null && echo "   coordinator SIGKILLed" || true
  wait "$pid" 2>/dev/null || true
  [ -f "$dir/checkpoint.bin" ] || { echo "seed $seed: no checkpoint"; exit 1; }

  echo "== seed $seed: resume =="
  timeout 300 "${run[@]}" --resume >/dev/null
  cmp "$work/oracle.bin" "$out" || { echo "seed $seed: bytes diverged"; exit 1; }
  grep -q '^A ' "$dir/waves.ledger" || { echo "seed $seed: no resume marker"; exit 1; }
  echo "   seed $seed byte-identical"
done

python3 - "$work" <<'EOF'
import glob, json, sys

tot = {}
for p in glob.glob(sys.argv[1] + "/chaos-*/dist_report.json"):
    d = json.load(open(p))
    for k in ("checkpoints_written", "coordinator_resumes", "workers_respawned",
              "frames_corrupted", "workers_lost", "waves_reclaimed",
              "heartbeats_missed"):
        tot[k] = tot.get(k, 0) + d.get(k, 0)
print("soak totals:", tot)
for k in ("checkpoints_written", "coordinator_resumes", "workers_respawned",
          "frames_corrupted"):
    assert tot.get(k, 0) > 0, f"chaos soak never exercised {k}"
EOF

# At least one respawn marker must exist somewhere in the soak ledgers.
grep -hq '^S ' "$work"/chaos-*/waves.ledger \
  || { echo "no respawn marker in any soak ledger"; exit 1; }
echo "chaos soak OK: ${#SEEDS[@]} seeds, all byte-identical to the oracle"
