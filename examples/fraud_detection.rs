//! Fraud-detection scenario — the industry workload the paper's intro
//! motivates (Ant Group transaction graphs).
//!
//! A synthetic "transaction graph": accounts form communities (merchants,
//! consumers, …) including fraud-ring-like clusters; the GCN learns to tag
//! accounts by community from 2-hop sampled neighborhoods, exactly the
//! mini-batch setup of the paper (§3). Run with:
//!
//! ```bash
//! cargo run --release --example fraud_detection
//! ```

use graphgen_plus::engines::graphgen_plus::GraphGenPlus;
use graphgen_plus::featurestore::{FeatureService, HotCache, ShardedStore};
use graphgen_plus::graph::features::FeatureStore;
use graphgen_plus::graph::generator;
use graphgen_plus::pipeline::{run_pipeline, PipelineMode};
use graphgen_plus::sampler::FanoutSpec;
use graphgen_plus::train::trainer::TrainConfig;
use graphgen_plus::train::ModelRuntime;
use graphgen_plus::util::bytes::fmt_rate;

fn main() -> anyhow::Result<()> {
    graphgen_plus::util::logging::init();
    let artifacts = std::path::Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("meta.json").exists(),
        "run `make artifacts` first"
    );
    let runtime = ModelRuntime::load(artifacts, 1)?;
    let spec = runtime.meta().spec;

    // Transaction graph: 64k accounts, ~1M directed edges, 8 communities
    // (one per "behaviour profile" incl. fraud rings), heavy-tailed
    // degrees — big merchants are the hot nodes GraphGen+ cares about.
    let gen = generator::from_spec("planted:n=65536,e=524288,c=8", 42)?;
    let g = gen.csr();
    let (hub, deg) = g.max_degree();
    println!(
        "transaction graph: {} accounts, {} edges, hottest account {hub} (degree {deg})",
        g.num_nodes(),
        g.num_edges()
    );

    // Sharded feature store with a hot-node cache: the realistic serving
    // path — account features live partitioned across workers, hub
    // accounts (big merchants) are cached.
    let store = FeatureStore::with_labels(
        spec.dim,
        spec.classes as u32,
        gen.labels.clone().unwrap(),
        1,
    );
    let sharded = ShardedStore::build(&store, g.num_nodes(), 8, 42);
    let cache = HotCache::from_mb(8, spec.dim);
    let warm: Vec<u32> = g
        .top_degree_nodes(cache.capacity() / 2)
        .into_iter()
        .map(|(v, _)| v)
        .collect();
    let features = FeatureService::new(std::sync::Arc::new(sharded)).with_cache(cache);
    features.warm_cache(&warm);
    // Enough seed accounts for 40 iterations × replicas × batch.
    let replicas = 4;
    let iters = 40;
    let mut rng = graphgen_plus::util::rng::Xoshiro256::seed_from_u64(9);
    let seeds: Vec<u32> = rng
        .sample_indices(g.num_nodes() as usize, spec.batch * replicas * iters)
        .into_iter()
        .map(|v| v as u32)
        .collect();

    let ecfg = graphgen_plus::engines::EngineConfig {
        workers: 8,
        wave_size: 2048,
        fanout: FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]),
        ..Default::default()
    };
    let tcfg =
        TrainConfig { replicas, lr: 0.1, curve_every: 5, prefetch: true, ..Default::default() };
    let report = run_pipeline(
        &g, &seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
        PipelineMode::Concurrent,
    )?;
    println!("{}", report.render());
    println!("generation: {}", report.gen.render());
    println!("\nloss curve:");
    for (i, l) in &report.train.loss_curve {
        println!("  iter {i:>4}: {l:.4}");
    }
    println!(
        "\naccount-classification accuracy: {:.1}% | sampled-node throughput {}",
        report.train.accuracy * 100.0,
        fmt_rate(report.gen.nodes_per_sec(), "nodes"),
    );
    println!("feature fetch: {}", report.train.feature_fetch.render());
    if let Some(cs) = features.cache_stats() {
        println!(
            "hot-account cache: {:.0}% hit rate over {} lookups",
            cs.hit_rate() * 100.0,
            cs.lookups()
        );
    }
    anyhow::ensure!(report.train.accuracy > 0.5, "model failed to learn");
    runtime.shutdown();
    Ok(())
}
