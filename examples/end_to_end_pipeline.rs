//! End-to-end validation driver (E7 + the system-prompt's required
//! full-stack proof): generate a realistic planted-partition workload,
//! run the **concurrent** generation→training pipeline for a few hundred
//! iterations, log the loss curve, and cross-check against the
//! **sequential** ablation — exercising L3 (engines, balance table, tree
//! reduction, queue, AllReduce) → runtime (PJRT) → L2/L1 (compiled GCN
//! with Pallas kernels) in one run. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_pipeline
//! ```

use graphgen_plus::engines::graphgen_plus::GraphGenPlus;
use graphgen_plus::engines::EngineConfig;
use graphgen_plus::featurestore::FeatureService;
use graphgen_plus::graph::features::FeatureStore;
use graphgen_plus::graph::generator;
use graphgen_plus::pipeline::{run_pipeline, PipelineMode};
use graphgen_plus::sampler::FanoutSpec;
use graphgen_plus::train::trainer::TrainConfig;
use graphgen_plus::train::ModelRuntime;
use graphgen_plus::util::bytes::{fmt_count, fmt_rate, fmt_secs};

fn main() -> anyhow::Result<()> {
    graphgen_plus::util::logging::init();
    let artifacts = std::path::Path::new("artifacts");
    anyhow::ensure!(artifacts.join("meta.json").exists(), "run `make artifacts` first");
    let runtime = ModelRuntime::load(artifacts, 2)?;
    let spec = runtime.meta().spec;
    println!(
        "model: GCN b={} f1={} f2={} d={} h={} c={} ({} params)",
        spec.batch, spec.f1, spec.f2, spec.dim, spec.hidden, spec.classes,
        runtime.meta().num_params()
    );

    // Workload: 128k-node / ~2M-edge community graph (heavy-tailed).
    let gen = generator::from_spec("planted:n=131072,e=1048576,c=8", 4)?;
    let g = gen.csr();
    println!(
        "graph: {} nodes, {} directed edges, max degree {}",
        fmt_count(g.num_nodes() as f64),
        fmt_count(g.num_edges() as f64),
        g.max_degree().1
    );
    let features = FeatureService::procedural(FeatureStore::with_labels(
        spec.dim,
        spec.classes as u32,
        gen.labels.clone().unwrap(),
        3,
    ));

    // ~300 iterations × 4 replicas × batch seeds.
    let replicas = 4;
    let iterations = 300usize;
    let mut rng = graphgen_plus::util::rng::Xoshiro256::seed_from_u64(17);
    let n_seeds = spec.batch * replicas * iterations;
    let seeds: Vec<u32> = (0..n_seeds)
        .map(|_| rng.gen_range(g.num_nodes() as u64) as u32)
        .collect();
    println!(
        "training plan: {iterations} iterations × {replicas} replicas × {} batch = {} subgraphs",
        spec.batch,
        fmt_count(n_seeds as f64)
    );

    let ecfg = EngineConfig {
        workers: 8,
        wave_size: 4096,
        fanout: FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]),
        ..Default::default()
    };
    let tcfg = TrainConfig { replicas, lr: 0.08, curve_every: 20, ..Default::default() };

    // --- the headline run: concurrent generation + training -------------
    let conc = run_pipeline(
        &g, &seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
        PipelineMode::Concurrent,
    )?;
    println!("\n=== concurrent (GraphGen+) ===\n{}", conc.render());
    println!("generation: {}", conc.gen.render());
    println!("loss curve:");
    for (i, l) in &conc.train.loss_curve {
        println!("  iter {i:>5}: loss {l:.4}");
    }

    // --- ablation: generate-everything-then-train ------------------------
    let seq = run_pipeline(
        &g, &seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
        PipelineMode::Sequential,
    )?;
    println!("\n=== sequential ablation ===\n{}", seq.render());

    println!("\n=== summary ===");
    println!(
        "concurrent wall {} vs sequential wall {} → {:.2}x end-to-end",
        fmt_secs(conc.wall.as_secs_f64()),
        fmt_secs(seq.wall.as_secs_f64()),
        seq.wall.as_secs_f64() / conc.wall.as_secs_f64()
    );
    println!(
        "generation throughput: {} | nodes/iteration: {}",
        fmt_rate(conc.gen.nodes_per_sec(), "nodes"),
        conc.train.nodes_trained / conc.train.iterations.max(1)
    );
    println!(
        "final loss {:.4} (from {:.4}), train accuracy {:.1}%",
        conc.train.final_loss,
        conc.train.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN),
        conc.train.accuracy * 100.0
    );
    anyhow::ensure!(conc.train.accuracy > 0.6, "end-to-end training failed to learn");
    anyhow::ensure!(
        conc.train.final_loss < conc.train.loss_curve.first().unwrap().1 * 0.5,
        "loss did not decrease"
    );
    runtime.shutdown();
    println!("\nEND-TO-END VALIDATION: OK");
    Ok(())
}
