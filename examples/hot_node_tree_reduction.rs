//! Hot-node deep dive: why GraphGen+ uses (a) edge-centric scanning and
//! (b) hierarchical tree reduction (paper §2 step 3).
//!
//! Builds star graphs whose hubs dominate the edge count, then compares
//! GraphGen+ (edges of the hub split across scan tasks; partial results
//! merged through a tree) against the node-centric AGL baseline (a hub =
//! one serial task, whole adjacency shipped to one reducer) and against
//! flat aggregation. Reports wall time and the receiver-side network hot
//! spot from the fabric accounting.
//!
//! ```bash
//! cargo run --release --example hot_node_tree_reduction
//! ```

use graphgen_plus::engines::agl::AglNodeCentric;
use graphgen_plus::engines::graphgen_plus::GraphGenPlus;
use graphgen_plus::engines::{EngineConfig, NullSink, ReduceTopology, SubgraphEngine};
use graphgen_plus::graph::generator;
use graphgen_plus::sampler::FanoutSpec;
use graphgen_plus::util::bytes::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    graphgen_plus::util::logging::init();
    println!("hub-degree sweep: GraphGen+ (tree) vs GraphGen+ (flat) vs AGL (node-centric)\n");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>16} {:>16}",
        "hub deg", "plus/tree", "plus/flat", "agl", "tree recv hot", "agl recv hot"
    );
    for scale in [4096u32, 16384, 65536] {
        let gen = generator::from_spec(&format!("star:n={scale},hubs=2"), 1)?;
        let g = gen.csr();
        // Seeds adjacent to the hubs so the hubs land on every frontier.
        let seeds: Vec<u32> = (0..512).collect();
        let base = EngineConfig {
            workers: 8,
            wave_size: 512,
            fanout: FanoutSpec::paper(), // 40, 20 — the paper's setting
            ..Default::default()
        };
        let run = |engine: &dyn SubgraphEngine, cfg: &EngineConfig| {
            let sink = NullSink::default();
            engine.generate(&g, &seeds, cfg, &sink).unwrap()
        };
        let tree = run(&GraphGenPlus, &base);
        let flat_cfg = EngineConfig { reduce: ReduceTopology::Flat, ..base.clone() };
        let flat = run(&GraphGenPlus, &flat_cfg);
        let agl = run(&AglNodeCentric, &base);
        let hot = |r: &graphgen_plus::engines::GenReport| {
            *r.fabric.per_worker_recv.iter().max().unwrap_or(&0)
        };
        println!(
            "{:>10} {:>14} {:>14} {:>14} {:>16} {:>16}",
            g.max_degree().1,
            fmt_secs(tree.wall.as_secs_f64()),
            fmt_secs(flat.wall.as_secs_f64()),
            fmt_secs(agl.wall.as_secs_f64()),
            fmt_bytes(hot(&tree)),
            fmt_bytes(hot(&agl)),
        );
    }
    println!(
        "\nThe tree keeps the busiest receiver near the per-worker average;\n\
         flat/node-centric funnel the hub's entire neighborhood into one worker."
    );
    Ok(())
}
