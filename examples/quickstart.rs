//! Quickstart: the whole GraphGen+ API on Zachary's karate club (the
//! embedded real graph) in under a minute.
//!
//! ```bash
//! make artifacts           # once (compiles the GCN to HLO)
//! cargo run --release --example quickstart
//! ```

use graphgen_plus::engines::{CollectSink, EngineConfig, SubgraphEngine};
use graphgen_plus::engines::graphgen_plus::GraphGenPlus;
use graphgen_plus::featurestore::FeatureService;
use graphgen_plus::graph::features::FeatureStore;
use graphgen_plus::graph::generator;
use graphgen_plus::pipeline::{run_pipeline, PipelineMode};
use graphgen_plus::sampler::FanoutSpec;
use graphgen_plus::train::trainer::TrainConfig;
use graphgen_plus::train::ModelRuntime;

fn main() -> anyhow::Result<()> {
    graphgen_plus::util::logging::init();

    // 1. A real graph: Zachary's karate club (34 nodes, 156 directed edges).
    let karate = generator::from_spec("karate", 0)?;
    let g = karate.csr();
    println!("karate club: {} nodes, {} edges", g.num_nodes(), g.num_edges());

    // 2. Distributed subgraph generation: every node is a seed; 2 hops.
    let seeds: Vec<u32> = (0..g.num_nodes()).collect();
    let cfg = EngineConfig {
        workers: 2,
        fanout: FanoutSpec::new(vec![5, 3]),
        wave_size: 16,
        ..Default::default()
    };
    let sink = CollectSink::default();
    let report = GraphGenPlus.generate(&g, &seeds, &cfg, &sink)?;
    println!("{}", report.render());
    let subgraphs = sink.take_sorted();
    let sg = &subgraphs[0];
    println!(
        "subgraph of node {}: hop1 {:?}, first hop2 group {:?}",
        sg.seed,
        sg.hop1,
        sg.hop2.first()
    );

    // 3. In-memory training on the generated subgraphs (needs artifacts/).
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("meta.json").exists() {
        println!("\n(skipping training demo: run `make artifacts` first)");
        return Ok(());
    }
    let runtime = ModelRuntime::load(artifacts, 1)?;
    let spec = runtime.meta().spec;
    // Features derived from the historical club split (labels 0/1).
    let features = FeatureService::procedural(FeatureStore::with_labels(
        spec.dim,
        spec.classes as u32,
        karate.labels.clone().unwrap(),
        7,
    ));
    // Repeat the 34 seeds to fill a few training iterations.
    let many_seeds: Vec<u32> = (0..(spec.batch as u32 * 2 * 8)).map(|i| i % 34).collect();
    let mut ecfg = cfg.clone();
    ecfg.fanout = FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]);
    let result = run_pipeline(
        &g,
        &many_seeds,
        &GraphGenPlus,
        &ecfg,
        &features,
        &runtime,
        &TrainConfig { replicas: 2, lr: 0.1, ..Default::default() },
        PipelineMode::Concurrent,
    )?;
    println!("\n{}", result.render());
    println!(
        "trained {} iterations; club-faction accuracy {:.0}%",
        result.train.iterations,
        result.train.accuracy * 100.0
    );
    runtime.shutdown();
    Ok(())
}
