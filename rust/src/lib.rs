//! # GraphGen+
//!
//! A reproduction of *"GraphGen+: Advancing Distributed Subgraph Generation
//! and Graph Learning On Industrial Graphs"* (Jin, Liu & Hong, Ant Group,
//! 2025) as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: graph
//!   partitioning, the load-balance table, edge-centric distributed
//!   subgraph generation with hierarchical tree reduction for hot nodes,
//!   a sharded feature store with batched fetch + hot-node caching +
//!   prefetch ([`featurestore`]), and a concurrent generation→training
//!   in-memory pipeline.
//! * **L2 (`python/compile/model.py`)** — a 2-layer GCN over fixed-shape
//!   padded 2-hop subgraph batches, AOT-lowered to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for masked
//!   neighbor aggregation and the fused GCN layer.
//!
//! Python runs only at build time (`make artifacts`); the rust runtime
//! loads the HLO artifacts through PJRT (the `xla` crate when available;
//! this tree builds against [`xla_shim`] so the L3 system compiles and
//! tests without libxla). See `DESIGN.md` at the repo root for the full
//! module inventory and the experiment index (E1–E7).

pub mod balance;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod engines;
pub mod featurestore;
pub mod graph;
pub mod storage;
pub mod mapreduce;
pub mod obs;
pub mod pipeline;
pub mod sampler;
pub mod train;
pub mod testkit;
pub mod util;
pub mod xla_shim;
