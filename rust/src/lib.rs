//! # GraphGen+
//!
//! A reproduction of *"GraphGen+: Advancing Distributed Subgraph Generation
//! and Graph Learning On Industrial Graphs"* (Jin, Liu & Hong, Ant Group,
//! 2025) as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: graph
//!   partitioning, the load-balance table, edge-centric distributed
//!   subgraph generation with hierarchical tree reduction for hot nodes,
//!   and a concurrent generation→training in-memory pipeline.
//! * **L2 (`python/compile/model.py`)** — a 2-layer GCN over fixed-shape
//!   padded 2-hop subgraph batches, AOT-lowered to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for masked
//!   neighbor aggregation and the fused GCN layer.
//!
//! Python runs only at build time (`make artifacts`); the rust runtime
//! loads the HLO artifacts through PJRT (`xla` crate) and is otherwise
//! self-contained. See `DESIGN.md` for the full system inventory and the
//! experiment index, and `EXPERIMENTS.md` for measured results.

pub mod balance;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod engines;
pub mod graph;
pub mod storage;
pub mod mapreduce;
pub mod pipeline;
pub mod sampler;
pub mod train;
pub mod testkit;
pub mod util;
