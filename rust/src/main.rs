//! GraphGen+ launcher.
//!
//! ```text
//! graphgen-plus generate   # distributed subgraph generation, one engine
//! graphgen-plus compare    # all four engines on one workload (mini E1)
//! graphgen-plus pipeline   # generation + in-memory training (E6/E7)
//! graphgen-plus partition  # partitioner diagnostics
//! graphgen-plus inspect    # graph/degree diagnostics
//! graphgen-plus make-graph # generate + save a graph file
//! ```
//!
//! Every command accepts `--config run.json` plus individual overrides
//! (see `config::RunConfig`).

use anyhow::{Context, Result};
use graphgen_plus::cli::{flag, opt, App, CliError, CommandSpec, Parsed};
use graphgen_plus::cluster::proc::{
    run_coordinator_with, worker_main, Checkpoint, ConsumerCut, DistOptions, DistPlan, WaveBytes,
};
use graphgen_plus::config::RunConfig;
use graphgen_plus::engines::{self, EncodeSink, NullSink};
use graphgen_plus::featurestore::{BackendKind, FeatureService, HotCache, ShardedStore, TieredStore};
use graphgen_plus::graph::features::FeatureStore;
use graphgen_plus::graph::{generator, io, partition};
use graphgen_plus::pipeline::{run_pipeline, run_pipeline_distributed, PipelineMode};
use graphgen_plus::train::ModelRuntime;
use graphgen_plus::util::bytes::{fmt_bytes, fmt_count, fmt_rate, fmt_secs};
use graphgen_plus::util::stats::Samples;

fn common_opts() -> Vec<graphgen_plus::cli::OptSpec> {
    vec![
        opt("config", "JSON config file (see config::RunConfig)", None),
        opt("graph", "generator spec, e.g. rmat:n=65536,e=524288", None),
        opt("graph-seed", "graph generation seed", None),
        opt("num-seeds", "number of seed nodes", None),
        opt("workers", "simulated cluster width", None),
        opt("threads", "OS threads", None),
        opt("wave-size", "seeds per generation wave", None),
        opt("fanout", "per-hop fanouts, e.g. 40,20", None),
        opt("sample-seed", "sampling determinism seed", None),
        opt("mapping", "seed mapping: paper|contiguous|hash", None),
        opt("reduce", "aggregation: tree|flat", None),
        opt("reduce-arity", "tree reduction arity", None),
        opt("wave-pipeline", "overlap look-ahead waves with reduce/emit (true|false)", None),
        opt("lookahead-depth", "wave look-ahead ring depth ceiling (>=1; >=2 speculates hop-2)", None),
        opt("lookahead-workers", "look-ahead speculator threads claiming waves out of order (>=1)", None),
        opt("trace-out", "write a Chrome-trace timeline (Perfetto) to this path", None),
        opt("obs-snapshot-secs", "metrics snapshot period in seconds (0=off)", None),
        opt("pin-cores", "pin pool workers to cores, slot i -> core i%cores (true|false)", None),
        opt(
            "memory-budget-mb",
            "tiered-memory budget (MiB) split between feature hot tier and graph page cache; 0=resident (GG_MEMORY_BUDGET_MB also applies)",
            None,
        ),
        opt(
            "processes",
            "worker processes for distributed generation (0 = in-process oracle)",
            None,
        ),
        opt("run-dir", "distributed run directory (config, heartbeats, ledger; empty = temp)", None),
        opt("heartbeat-ms", "distributed heartbeat period (ms)", None),
        opt("lease-ms", "liveness lease before a silent worker is declared lost (ms)", None),
        opt("op-deadline-ms", "distributed transport per-op deadline (ms)", None),
        opt("checkpoint-waves", "coordinator checkpoint period in emitted waves (0=off)", None),
        opt("respawn-budget", "replacement worker spawns allowed per lost rank", None),
        opt("chaos", "deterministic fault-injection seed (0=off; GG_CHAOS_SEED overrides)", None),
        flag("resume", "resume a distributed run from the checkpoint in --run-dir"),
        flag("dump-config", "print the effective config and exit"),
    ]
}

fn build_app() -> App {
    App {
        name: "graphgen-plus",
        about: "distributed subgraph generation + in-memory graph learning (GraphGen+ reproduction)",
        commands: vec![
            CommandSpec {
                name: "generate",
                about: "run one generation engine and report throughput",
                opts: {
                    let mut o = common_opts();
                    o.push(opt("engine", "graphgen+|graphgen|agl|sql-like", Some("graphgen+")));
                    o.push(opt(
                        "subgraph-bytes-out",
                        "dump encoded subgraphs (emission order) to this path — the distributed byte-equivalence probe",
                        None,
                    ));
                    o
                },
            },
            CommandSpec {
                name: "compare",
                about: "run all four engines on the same workload (mini E1)",
                opts: common_opts(),
            },
            CommandSpec {
                name: "pipeline",
                about: "generation + concurrent in-memory GCN training",
                opts: {
                    let mut o = common_opts();
                    o.push(opt("engine", "generation engine", Some("graphgen+")));
                    o.push(opt("artifacts", "AOT artifact directory", Some("artifacts")));
                    o.push(opt("replicas", "training replicas", None));
                    o.push(opt("lr", "learning rate", None));
                    o.push(opt("allreduce", "ring|tree", None));
                    o.push(opt("mode", "concurrent|sequential", None));
                    o.push(opt("feature-backend", "feature store: procedural|sharded|tiered", None));
                    o.push(opt("feature-cache-mb", "hot-node feature cache (MiB, 0=off)", None));
                    o.push(opt("feature-prefetch", "overlap feature gather with training (true|false)", None));
                    o.push(opt("gather-threads", "pool threads reserved for feature gathers (0=auto)", None));
                    o.push(opt("pjrt-pool", "PJRT executor threads", None));
                    o.push(opt("save-ckpt", "write trained params to this path", None));
                    o.push(opt("eval-seeds", "evaluate on N held-out seeds after training", None));
                    o
                },
            },
            CommandSpec {
                name: "partition",
                about: "partitioner diagnostics on a generated graph",
                opts: {
                    let mut o = common_opts();
                    o.push(opt("strategy", "hash|range|edge-balanced", Some("hash")));
                    o
                },
            },
            CommandSpec {
                name: "inspect",
                about: "graph statistics (degrees, hot nodes, memory)",
                opts: common_opts(),
            },
            CommandSpec {
                name: "make-graph",
                about: "generate a graph and save it (.tsv or binary)",
                opts: {
                    let mut o = common_opts();
                    o.push(opt("out", "output path (.tsv → text, else binary)", Some("graph.bin")));
                    o
                },
            },
            CommandSpec {
                name: "gg-worker",
                about: "worker-process body of a distributed run (spawned by the coordinator)",
                opts: vec![
                    opt("run-dir", "shared run directory written by the coordinator", None),
                    opt("rank", "this worker's rank", None),
                ],
            },
        ],
    }
}

/// Fold CLI values into a RunConfig (config file first, then flags).
fn run_config(p: &Parsed) -> Result<RunConfig> {
    let mut cfg = match p.get("config") {
        Some(path) => RunConfig::from_json_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    for (k, v) in p.values() {
        if k == "config" {
            continue;
        }
        let key = k.replace('-', "_");
        // CLI names map 1:1 onto config keys (dash→underscore); options
        // consumed directly by a command handler are passed through.
        const COMMAND_LOCAL: &[&str] =
            &["engine", "strategy", "out", "save_ckpt", "eval_seeds", "subgraph_bytes_out"];
        if cfg.apply_override(&key, v).is_err() && !COMMAND_LOCAL.contains(&key.as_str()) {
            anyhow::bail!("unknown option --{k}");
        }
    }
    // Enable-only: leaving the flag off must not clobber a GG_PIN_CORES
    // opt-in from the environment.
    if cfg.pin_cores {
        graphgen_plus::util::workpool::set_pin_cores(true);
    }
    Ok(cfg)
}

fn seeds_for(cfg: &RunConfig, n: u32) -> Vec<u32> {
    // Deterministic, config-derived draw — the same list every process of
    // a distributed run rebuilds locally (see `RunConfig::seeds`).
    cfg.seeds(n)
}

fn cmd_generate(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    if p.flag("dump-config") {
        println!("{}", cfg.to_json().to_pretty());
        return Ok(());
    }
    if cfg.processes > 0 {
        return cmd_generate_distributed(&cfg, p);
    }
    let mut obs = start_obs(&cfg, p.get("engine").unwrap_or(&cfg.engine));
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let mut g = gen.csr();
    // Pure generation has no feature tier: the whole memory budget goes
    // to the graph page cache.
    let budget_mb = graphgen_plus::storage::tier::memory_budget_mb(cfg.memory_budget_mb);
    if budget_mb > 0 {
        let (_, graph_bytes) = graphgen_plus::pipeline::split_memory_budget(budget_mb, false, true);
        g = g.to_paged(graph_bytes);
        log::info!(
            "paged graph: {} cold (compressed), {} resident budget",
            fmt_bytes(g.cold_bytes()),
            fmt_bytes(graph_bytes)
        );
    }
    let seeds = seeds_for(&cfg, g.num_nodes());
    let engine = engines::by_name(p.get("engine").unwrap_or(&cfg.engine))?;
    log::info!("graph {}: {} nodes, {} edges", gen.name, g.num_nodes(), g.num_edges());
    let report = match p.get("subgraph-bytes-out") {
        Some(path) => {
            // Oracle byte dump: encoded subgraphs in emission order, the
            // reference a distributed run must match byte-for-byte.
            let sink = EncodeSink::default();
            let report = engine.generate(&g, &seeds, &cfg.engine_config()?, &sink)?;
            std::fs::write(path, sink.into_bytes())
                .with_context(|| format!("write {path}"))?;
            report
        }
        None => {
            let sink = NullSink::default();
            engine.generate(&g, &seeds, &cfg.engine_config()?, &sink)?
        }
    };
    println!("{}", report.render());
    print_tier_stats(&g);
    obs.finish()?;
    Ok(())
}

/// Multi-process generation (`--processes N`): spawn the coordinator in
/// this process and N `gg-worker` children; emitted waves are FIFO and
/// byte-identical to the in-process oracle above.
fn cmd_generate_distributed(cfg: &RunConfig, p: &Parsed) -> Result<()> {
    // The shared config.json must carry the *effective* engine: --engine
    // is command-local, never folded into the config by run_config.
    let mut dcfg = cfg.clone();
    if let Some(e) = p.get("engine") {
        dcfg.engine = e.to_string();
    }
    let mut obs = start_obs(&dcfg, &dcfg.engine);
    let gen = generator::from_spec(&dcfg.graph, dcfg.graph_seed)?;
    let g = gen.csr();
    log::info!("graph {}: {} nodes, {} edges", gen.name, g.num_nodes(), g.num_edges());
    let plan = DistPlan::from_config(&dcfg, g.num_nodes())?;
    let mut opts = DistOptions::from_config(&dcfg, worker_bin()?);
    let mut base_bytes = 0u64;
    if p.flag("resume") {
        anyhow::ensure!(
            !dcfg.run_dir.is_empty(),
            "--resume needs the original --run-dir (a fresh temp dir has no checkpoint)"
        );
        let ck = Checkpoint::load(&opts.run_dir)?
            .with_context(|| format!("no checkpoint under {}", opts.run_dir.display()))?;
        base_bytes = ck.emitted_bytes;
        opts.resume_from = Some(ck);
    }
    let out = match p.get("subgraph-bytes-out") {
        Some(path) => {
            use std::io::Seek;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .write(true)
                .open(path)
                .with_context(|| format!("create {path}"))?;
            // On resume, drop everything past the checkpointed cut and
            // append from there; a fresh run starts empty.
            f.set_len(base_bytes)?;
            f.seek(std::io::SeekFrom::End(0))?;
            Some(std::io::BufWriter::new(f))
        }
        None => None,
    };
    let out = std::cell::RefCell::new(out);
    let written = std::cell::Cell::new(base_bytes);
    let mut emit = |wb: WaveBytes| -> Result<()> {
        if let Some(w) = out.borrow_mut().as_mut() {
            std::io::Write::write_all(w, &wb.bytes)?;
        }
        written.set(written.get() + wb.bytes.len() as u64);
        Ok(())
    };
    // Checkpoint cut for the generate path: every emitted wave is already
    // consumed (written out), so the cut sits at the emit frontier and the
    // byte offset tells `--resume` where to truncate the dump.
    let mut snapshot = |frontier: u64| -> Result<ConsumerCut> {
        if let Some(w) = out.borrow_mut().as_mut() {
            std::io::Write::flush(w)?;
        }
        Ok(ConsumerCut {
            resume_wave: frontier,
            skip_subgraphs: 0,
            emitted_bytes: written.get(),
            payload: Vec::new(),
        })
    };
    let report = run_coordinator_with(&plan, &opts, &mut emit, Some(&mut snapshot))?;
    if let Some(w) = out.borrow_mut().as_mut() {
        std::io::Write::flush(w)?;
    }
    println!("{}", report.render());
    std::fs::write(opts.run_dir.join("dist_report.json"), report.to_json().to_pretty())?;
    obs.finish()?;
    Ok(())
}

/// The binary to spawn workers from: `GG_WORKER_BIN` overrides (tests
/// point it at the cargo-built binary), otherwise this very executable.
fn worker_bin() -> Result<std::path::PathBuf> {
    match std::env::var("GG_WORKER_BIN") {
        Ok(p) if !p.is_empty() => Ok(std::path::PathBuf::from(p)),
        _ => std::env::current_exe().context("resolve current executable"),
    }
}

/// `gg-worker` — never invoked by hand; the coordinator spawns it with a
/// run directory whose config.json fully determines the work.
fn cmd_worker(p: &Parsed) -> Result<()> {
    let run_dir = p.get("run-dir").context("gg-worker requires --run-dir")?;
    let rank = p.get_parse::<u32>("rank")?.context("gg-worker requires --rank")?;
    let code = worker_main(std::path::Path::new(run_dir), rank)?;
    std::process::exit(code)
}

/// Report hot/cold tier traffic for a paged graph (no-op when resident).
fn print_tier_stats(g: &graphgen_plus::graph::csr::Csr) {
    if let Some(s) = g.tier_stats() {
        println!(
            "graph tier: {} faults / {} hits ({:.1}% fault rate), {} promotions, {} evictions, {} cold",
            s.faults,
            s.hits,
            s.fault_rate() * 100.0,
            s.promotions,
            s.evictions,
            fmt_bytes(g.cold_bytes())
        );
    }
}

/// Start the per-run observability session and stamp the report header
/// metadata (engine + effective config) every report writer picks up.
fn start_obs(cfg: &RunConfig, engine: &str) -> graphgen_plus::obs::ObsSession {
    graphgen_plus::obs::report::set_run_config_meta(cfg);
    graphgen_plus::obs::report::set_meta("engine", engine);
    graphgen_plus::obs::ObsSession::start(
        &cfg.trace_out,
        cfg.obs_snapshot_secs,
        "obs_metrics.jsonl",
    )
}

fn cmd_compare(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let g = gen.csr();
    let seeds = seeds_for(&cfg, g.num_nodes());
    println!(
        "workload: {} ({} nodes / {} edges), {} seeds, fanout {}",
        gen.name,
        fmt_count(g.num_nodes() as f64),
        fmt_count(g.num_edges() as f64),
        seeds.len(),
        cfg.fanout
    );
    let mut rows = Vec::new();
    let mut baseline = None;
    for name in ["sql-like", "agl", "graphgen", "graphgen+"] {
        let engine = engines::by_name(name)?;
        let sink = NullSink::default();
        let report = engine.generate(&g, &seeds, &cfg.engine_config()?, &sink)?;
        if name == "sql-like" {
            baseline = Some(report.wall.as_secs_f64());
        }
        let speedup = baseline
            .map(|b| format!("{:.2}x", b / report.wall.as_secs_f64()))
            .unwrap_or_default();
        rows.push(vec![
            name.to_string(),
            fmt_secs(report.wall.as_secs_f64()),
            fmt_rate(report.nodes_per_sec(), "nodes"),
            fmt_bytes(report.fabric.total_bytes),
            speedup,
        ]);
        println!("  {}", report.render());
    }
    println!(
        "\n{}",
        graphgen_plus::bench_harness::render_markdown(
            "engine comparison (speedup vs sql-like)",
            &["engine".into(), "wall".into(), "throughput".into(), "shuffle".into(), "speedup".into()],
            &rows
        )
    );
    Ok(())
}

fn cmd_pipeline(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    if p.flag("dump-config") {
        println!("{}", cfg.to_json().to_pretty());
        return Ok(());
    }
    let mut obs = start_obs(&cfg, p.get("engine").unwrap_or(&cfg.engine));
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let g = gen.csr();
    let seeds = seeds_for(&cfg, g.num_nodes());
    let runtime = ModelRuntime::load(std::path::Path::new(&cfg.artifacts), cfg.pjrt_pool)
        .context("load artifacts (run `make artifacts`)")?;
    let spec = runtime.meta().spec;
    let mut ecfg = cfg.engine_config()?;
    // Fanout must match the compiled batch layout.
    ecfg.fanout = graphgen_plus::sampler::FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]);
    let classes = spec.classes as u32;
    let store = match &gen.labels {
        Some(l) => FeatureStore::with_labels(spec.dim, classes.max(gen.num_classes), l.clone(), cfg.feature_seed),
        None => FeatureStore::hashed(spec.dim, classes, cfg.feature_seed),
    };
    let backend: BackendKind = cfg
        .feature_backend
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    // Tiered memory: split the budget between the feature hot tier (only
    // when the tiered backend is selected) and the graph page cache (any
    // time a budget is set). Budget 0 keeps everything resident.
    let budget_mb = graphgen_plus::storage::tier::memory_budget_mb(cfg.memory_budget_mb);
    let (feat_bytes, graph_bytes) = graphgen_plus::pipeline::split_memory_budget(
        budget_mb,
        backend == BackendKind::Tiered,
        budget_mb > 0,
    );
    let g = if budget_mb > 0 { g.to_paged(graph_bytes) } else { g };
    if g.is_paged() {
        log::info!(
            "paged graph: {} cold (compressed), {} resident budget",
            fmt_bytes(g.cold_bytes()),
            fmt_bytes(graph_bytes)
        );
    }
    let mut tiered_store: Option<std::sync::Arc<TieredStore>> = None;
    let mut features = match backend {
        BackendKind::Procedural => FeatureService::procedural(store),
        BackendKind::Sharded => FeatureService::new(std::sync::Arc::new(ShardedStore::build(
            &store,
            g.num_nodes(),
            cfg.workers.max(1),
            cfg.sample_seed,
        ))),
        BackendKind::Tiered => {
            let ts = std::sync::Arc::new(TieredStore::build(
                &store,
                g.num_nodes(),
                cfg.workers.max(1),
                cfg.sample_seed,
                feat_bytes,
            ));
            tiered_store = Some(ts.clone());
            FeatureService::new(ts)
        }
    };
    if cfg.feature_cache_mb > 0 {
        let cache = HotCache::from_mb(cfg.feature_cache_mb, spec.dim);
        // Seed the cache with the hottest rows: high-degree nodes appear
        // in the most sampled neighborhoods.
        let warm: Vec<u32> = g
            .top_degree_nodes(cache.capacity() / 2)
            .into_iter()
            .map(|(v, _)| v)
            .collect();
        features = features.with_cache(cache);
        features.warm_cache(&warm);
    }
    let engine = engines::by_name(p.get("engine").unwrap_or(&cfg.engine))?;
    let mode: PipelineMode = cfg.mode.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    if mode == PipelineMode::Concurrent && cfg.processes == 0 {
        // Partition the pool between generation scans and feature gathers
        // so the two stop fighting over the same workers. With no explicit
        // --gather-threads, the measured E7 knee (BENCH_e7.json) seeds the
        // gather share. (Distributed runs generate in worker processes —
        // the gathers keep the whole local pool.)
        let (gen_threads, gather_threads) =
            graphgen_plus::pipeline::split_pool_budget_seeded(ecfg.threads, cfg.gather_threads);
        ecfg.threads = gen_threads;
        features = features.with_threads(gather_threads);
        log::info!("pool budget: {gen_threads} generation / {gather_threads} gather threads");
    }
    let train = if cfg.processes > 0 {
        // Multi-process generation streaming into local training: the
        // shared config.json must carry the effective engine AND the
        // artifact-matched fanout so workers rebuild the exact table.
        let mut dcfg = cfg.clone();
        if let Some(e) = p.get("engine") {
            dcfg.engine = e.to_string();
        }
        dcfg.fanout = format!("{},{}", spec.f1, spec.f2);
        let dplan = DistPlan::from_config(&dcfg, g.num_nodes())?;
        let mut dopts = DistOptions::from_config(&dcfg, worker_bin()?);
        if p.flag("resume") {
            anyhow::ensure!(
                !dcfg.run_dir.is_empty(),
                "--resume needs the original --run-dir (a fresh temp dir has no checkpoint)"
            );
            let ck = Checkpoint::load(&dopts.run_dir)?
                .with_context(|| format!("no checkpoint under {}", dopts.run_dir.display()))?;
            dopts.resume_from = Some(ck);
        }
        let report =
            run_pipeline_distributed(&dplan, &dopts, &features, &runtime, &cfg.train_config()?)?;
        println!("{}", report.render());
        std::fs::write(dopts.run_dir.join("dist_report.json"), report.dist.to_json().to_pretty())?;
        report.train
    } else {
        let report = run_pipeline(
            &g, &seeds, engine.as_ref(), &ecfg, &features, &runtime, &cfg.train_config()?, mode,
        )?;
        println!("{}", report.render());
        println!("{}", report.gen.render());
        report.train
    };
    println!("feature store [{}]: {}", cfg.feature_backend, train.feature_fetch.render());
    if let Some(cs) = features.cache_stats() {
        println!(
            "feature cache: {} hits / {} lookups ({:.0}%), {} evictions",
            cs.hits,
            cs.lookups(),
            cs.hit_rate() * 100.0,
            cs.evictions
        );
    }
    print_tier_stats(&g);
    if let Some(ts) = &tiered_store {
        let s = ts.tier_stats();
        println!(
            "feature tier: {} faults / {} hits ({:.1}% fault rate), {} promotions, {} evictions, {} cold",
            s.faults,
            s.hits,
            s.fault_rate() * 100.0,
            s.promotions,
            s.evictions,
            fmt_bytes(ts.cold_bytes())
        );
    }
    println!("loss curve (iter, loss):");
    for (i, l) in &train.loss_curve {
        println!("  {i:>6} {l:.4}");
    }
    if let Some(path) = p.get("save-ckpt") {
        graphgen_plus::train::checkpoint::save(
            std::path::Path::new(path),
            runtime.meta(),
            &train.params,
        )?;
        println!("checkpoint written to {path}");
    }
    if let Some(n) = p.get_parse::<u32>("eval-seeds")? {
        // Held-out seeds: ids not used for training (training drew the
        // first `num_seeds` draws of the deterministic sampler).
        let mut rng = graphgen_plus::util::rng::Xoshiro256::seed_from_u64(cfg.sample_seed ^ 0xe7a1);
        let eval_seeds: Vec<u32> = rng
            .sample_indices(g.num_nodes() as usize, (n as usize).min(g.num_nodes() as usize))
            .into_iter()
            .map(|v| v as u32)
            .collect();
        let ev = graphgen_plus::train::eval::evaluate(
            &runtime, engine.as_ref(), &g, &features, &eval_seeds, &ecfg, &train.params,
        )?;
        println!(
            "held-out eval: {}/{} correct = {:.1}%",
            ev.correct,
            ev.examples,
            ev.accuracy * 100.0
        );
    }
    runtime.shutdown();
    obs.finish()?;
    Ok(())
}

fn cmd_partition(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let g = gen.csr();
    let strategy: partition::Strategy = p
        .get("strategy")
        .unwrap_or("hash")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let parts = partition::partition_graph(&g, cfg.workers, strategy, cfg.sample_seed);
    println!("strategy={:?} workers={}", strategy, cfg.workers);
    let mut edges = Samples::new();
    for part in &parts.parts {
        println!(
            "  worker {:>3}: {:>8} nodes {:>10} edges",
            part.worker,
            part.nodes.len(),
            part.num_edges
        );
        edges.push(part.num_edges as f64);
    }
    println!("edge imbalance (max/mean): {:.3}", parts.edge_imbalance());
    println!("edge cv: {:.3}", edges.cv());
    Ok(())
}

fn cmd_inspect(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let g = gen.csr();
    println!("graph: {}", gen.name);
    println!("  nodes: {}", fmt_count(g.num_nodes() as f64));
    println!("  edges: {}", fmt_count(g.num_edges() as f64));
    println!("  mean degree: {:.2}", g.mean_degree());
    let (hot, deg) = g.max_degree();
    println!("  max degree: {deg} (node {hot})");
    println!("  csr memory: {}", fmt_bytes(g.memory_bytes()));
    println!("  top-10 hot nodes:");
    for (v, d) in gen.edges.top_degree_nodes(10) {
        println!("    node {v:>9} degree {d}");
    }
    if let Some(labels) = &gen.labels {
        let mut counts = vec![0u64; gen.num_classes as usize];
        for &l in labels {
            counts[l as usize] += 1;
        }
        println!("  classes: {counts:?}");
    }
    Ok(())
}

fn cmd_make_graph(p: &Parsed) -> Result<()> {
    let cfg = run_config(p)?;
    let gen = generator::from_spec(&cfg.graph, cfg.graph_seed)?;
    let out = std::path::PathBuf::from(p.get("out").unwrap_or("graph.bin"));
    if out.extension().is_some_and(|e| e == "tsv") {
        io::save_text(&gen.edges, &out)?;
    } else {
        io::save_binary(&gen.edges, &out)?;
    }
    println!(
        "wrote {} ({} nodes, {} edges, {})",
        out.display(),
        gen.edges.num_nodes,
        gen.edges.len(),
        fmt_bytes(std::fs::metadata(&out)?.len())
    );
    Ok(())
}

fn main() {
    graphgen_plus::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let app = build_app();
    let parsed = match app.parse(&args) {
        Ok(p) => p,
        Err(CliError::HelpRequested) => return,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", app.help());
            std::process::exit(2);
        }
    };
    let result = match parsed.command.as_str() {
        "generate" => cmd_generate(&parsed),
        "compare" => cmd_compare(&parsed),
        "pipeline" => cmd_pipeline(&parsed),
        "partition" => cmd_partition(&parsed),
        "inspect" => cmd_inspect(&parsed),
        "make-graph" => cmd_make_graph(&parsed),
        "gg-worker" => cmd_worker(&parsed),
        other => Err(anyhow::anyhow!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
