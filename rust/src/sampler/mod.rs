//! Subgraph sampling primitives shared by all generation engines.
//!
//! The paper samples a 2-hop neighborhood per seed with fanouts (40, 20).
//! The key design decision here is *deterministic, mergeable* sampling:
//! each candidate neighbor gets a hash priority derived from
//! `(sample_seed, hop, seed-node, frontier-node, neighbor)`, and "sample k
//! of N" means "keep the k smallest priorities" ([`reservoir::TopK`]).
//! Because top-k-by-priority merges associatively and commutatively,
//!
//! * every engine (edge-centric, node-centric, SQL-like) produces **the
//!   same subgraphs** for the same sample seed — engines are comparable
//!   apples-to-apples and cross-validated in tests; and
//! * the hierarchical tree reduction of partial results (the paper's
//!   hot-node strategy) is *exact*, not approximate (flat ≡ tree, also
//!   property-tested).

pub mod inverted;
pub mod reservoir;
pub mod spec;
pub mod subgraph;

pub use spec::FanoutSpec;
pub use subgraph::Subgraph;

use crate::graph::NodeId;

/// Loop-invariant part of the priority hash: everything except the
/// neighbor. The scan hot loop hoists this out of the per-edge iteration
/// (one `mix64` per edge instead of three — see EXPERIMENTS.md §Perf).
#[inline]
pub fn priority_base(sample_seed: u64, hop: u32, seed_node: NodeId, frontier: NodeId) -> u64 {
    crate::util::rng::mix2(
        sample_seed ^ ((hop as u64) << 56),
        ((seed_node as u64) << 32) | frontier as u64,
    )
}

/// Finish the priority hash for one neighbor. Smaller = preferred.
#[inline]
pub fn priority_from_base(base: u64, neighbor: NodeId) -> u64 {
    crate::util::rng::mix64(base ^ (neighbor as u64).rotate_left(16))
}

/// Sampling priority of `neighbor` as a hop-`hop` candidate under
/// `frontier` within `seed_node`'s subgraph. Smaller = preferred.
/// Equivalent to `priority_from_base(priority_base(..), neighbor)` —
/// property-tested in `reservoir` tests.
#[inline]
pub fn priority(sample_seed: u64, hop: u32, seed_node: NodeId, frontier: NodeId, neighbor: NodeId) -> u64 {
    priority_from_base(priority_base(sample_seed, hop, seed_node, frontier), neighbor)
}
