//! Frontier inverted index: node → subgraphs that want its neighbors.
//!
//! The edge-centric pass (Alg. 1 step 15-21) scans *edges* and must answer
//! "which seeds' current frontiers contain this edge's source?" in O(1).
//! The index is rebuilt per hop from the previous hop's sampled frontier —
//! so it is **rebuildable in place**: a CSR-style layout (one flat entry
//! vec grouped by node, plus a node → range map) whose buffers are reused
//! across hops and waves instead of reallocated. Values are compact
//! subgraph slot indices plus the frontier-entry *ordinal* (the entry's
//! index in the frontier vec), which is what the dense reservoir frames
//! key on.

use crate::graph::NodeId;
use crate::util::fxhash::FxHashMap;
use crate::util::parallel_scan;
use crate::util::workpool::WorkPool;

/// node → list of (subgraph slot, frontier ordinal) pairs.
///
/// The ordinal identifies *which* frontier entry of the wave this is (a
/// node can appear in several subgraphs and even at several positions of
/// one subgraph's frontier); `frontier[ordinal]` recovers the `(node,
/// slot, position)` triple, so hop-2 samples can be attached to the right
/// parent.
#[derive(Debug, Default, Clone)]
pub struct InvertedIndex {
    /// node → (index into `order`/`starts`/`lens`, fill cursor).
    map: FxHashMap<NodeId, (u32, u32)>,
    /// (slot, ordinal) entries, grouped by node.
    flat: Vec<(u32, u32)>,
    /// Distinct frontier nodes in first-appearance order — the
    /// deterministic iteration order for task construction.
    order: Vec<NodeId>,
    /// Per-distinct-node entry count, aligned with `order`.
    lens: Vec<u32>,
    /// Per-distinct-node group start into `flat` (exclusive prefix scan
    /// of `lens`), aligned with `order`.
    starts: Vec<u32>,
}

impl InvertedIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from a frontier, reusing all internal buffers. Entry `i` of
    /// `frontier` is `(node, slot, position)`; its ordinal is `i`.
    pub fn rebuild(&mut self, frontier: &[(NodeId, u32, u32)]) {
        self.rebuild_par(frontier, 1);
    }

    /// [`rebuild`](Self::rebuild) with a thread budget for the group-start
    /// scan: the serial offset-assignment walk over all distinct nodes
    /// becomes a parallel exclusive prefix scan over `lens`. Layout is
    /// byte-identical at every thread count.
    pub fn rebuild_par(&mut self, frontier: &[(NodeId, u32, u32)], threads: usize) {
        self.map.clear();
        self.order.clear();
        self.lens.clear();
        self.flat.clear();
        self.flat.resize(frontier.len(), (0, 0));
        // Pass 1: count entries per distinct node (first-appearance
        // order), resetting each map cursor for pass 2.
        for &(node, _, _) in frontier {
            match self.map.entry(node) {
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert((self.order.len() as u32, 0));
                    self.order.push(node);
                    self.lens.push(1);
                }
                std::collections::hash_map::Entry::Occupied(e) => {
                    self.lens[e.get().0 as usize] += 1;
                }
            }
        }
        // Group starts: exclusive prefix scan of the counts.
        self.starts.clear();
        self.starts.extend_from_slice(&self.lens);
        parallel_scan::exclusive_scan(WorkPool::global(), threads, &mut self.starts);
        // Pass 2: fill the flat entries.
        for (ord, &(node, slot, _pos)) in frontier.iter().enumerate() {
            let e = self.map.get_mut(&node).expect("counted");
            self.flat[(self.starts[e.0 as usize] + e.1) as usize] = (slot, ord as u32);
            e.1 += 1;
        }
    }

    /// Convenience constructor (tests, one-shot callers).
    pub fn from_frontier(frontier: &[(NodeId, u32, u32)]) -> Self {
        let mut ix = Self::new();
        ix.rebuild(frontier);
        ix
    }

    /// All (slot, ordinal) pairs interested in `node`.
    #[inline]
    pub fn get(&self, node: NodeId) -> &[(u32, u32)] {
        match self.map.get(&node) {
            Some(&(idx, _)) => {
                let start = self.starts[idx as usize] as usize;
                let len = self.lens[idx as usize] as usize;
                &self.flat[start..start + len]
            }
            None => &[],
        }
    }

    #[inline]
    pub fn contains(&self, node: NodeId) -> bool {
        self.map.contains_key(&node)
    }

    /// Number of distinct frontier nodes.
    pub fn num_nodes(&self) -> usize {
        self.order.len()
    }

    /// Total (node, slot) entries — the replication factor numerator.
    pub fn num_entries(&self) -> usize {
        self.flat.len()
    }

    /// Distinct frontier nodes in first-appearance order (deterministic —
    /// unlike hashmap iteration, which would make scan-task composition,
    /// and with it the simulated ledger, vary run to run).
    pub fn nodes(&self) -> &[NodeId] {
        &self.order
    }

    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &[(u32, u32)])> {
        self.order.iter().map(move |&n| (n, self.get(n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebuild_and_lookup() {
        let mut ix = InvertedIndex::new();
        // frontier: ordinals 0..3
        ix.rebuild(&[(5, 0, 0), (9, 1, 0), (5, 3, 1)]);
        assert_eq!(ix.get(5), &[(0, 0), (3, 2)]);
        assert_eq!(ix.get(9), &[(1, 1)]);
        assert_eq!(ix.get(42), &[] as &[(u32, u32)]);
        assert!(ix.contains(5));
        assert!(!ix.contains(42));
        assert_eq!(ix.num_nodes(), 2);
        assert_eq!(ix.num_entries(), 3);
        assert_eq!(ix.nodes(), &[5, 9]);
    }

    #[test]
    fn rebuild_reuses_without_leaking_state() {
        let mut ix = InvertedIndex::new();
        ix.rebuild(&[(1, 0, 0), (2, 1, 0), (1, 2, 0)]);
        assert_eq!(ix.num_entries(), 3);
        // Rebuild with a disjoint, smaller frontier: nothing may survive.
        ix.rebuild(&[(7, 0, 0)]);
        assert_eq!(ix.get(1), &[] as &[(u32, u32)]);
        assert_eq!(ix.get(7), &[(0, 0)]);
        assert_eq!(ix.num_nodes(), 1);
        assert_eq!(ix.num_entries(), 1);
        assert_eq!(ix.nodes(), &[7]);
    }

    #[test]
    fn replication_counts_duplicates() {
        // Same node wanted by 3 subgraphs = replication factor 3 for its edges.
        let frontier: Vec<(NodeId, u32, u32)> = (0..3).map(|slot| (1, slot, 0)).collect();
        let ix = InvertedIndex::from_frontier(&frontier);
        assert_eq!(ix.num_nodes(), 1);
        assert_eq!(ix.num_entries(), 3);
        // Ordinals ascend within one node's group (the frames rely on it).
        assert_eq!(ix.get(1), &[(0, 0), (1, 1), (2, 2)]);
    }
}
