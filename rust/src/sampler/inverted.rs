//! Frontier inverted index: node → subgraphs that want its neighbors.
//!
//! The edge-centric pass (Alg. 1 step 15-21) scans *edges* and must answer
//! "which seeds' current frontiers contain this edge's source?" in O(1).
//! This index is rebuilt per hop from the previous hop's sampled frontier.
//! Values are compact subgraph slot indices (`u32`), not node ids.

use std::collections::HashMap;

use crate::graph::NodeId;

/// node → list of (subgraph slot, frontier position) pairs.
///
/// The frontier position disambiguates *which* hop-1 node of the subgraph
/// this frontier entry corresponds to, so hop-2 samples can be attached to
/// the right parent (a node can appear in several subgraphs and even at
/// several positions of one subgraph's frontier).
#[derive(Debug, Default, Clone)]
pub struct InvertedIndex {
    map: HashMap<NodeId, Vec<(u32, u32)>>,
    entries: usize,
}

impl InvertedIndex {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { map: HashMap::with_capacity(cap), entries: 0 }
    }

    pub fn insert(&mut self, node: NodeId, slot: u32, position: u32) {
        self.map.entry(node).or_default().push((slot, position));
        self.entries += 1;
    }

    /// All (slot, position) pairs interested in `node`.
    #[inline]
    pub fn get(&self, node: NodeId) -> &[(u32, u32)] {
        self.map.get(&node).map(Vec::as_slice).unwrap_or(&[])
    }

    #[inline]
    pub fn contains(&self, node: NodeId) -> bool {
        self.map.contains_key(&node)
    }

    /// Number of distinct frontier nodes.
    pub fn num_nodes(&self) -> usize {
        self.map.len()
    }

    /// Total (node, slot) entries — the replication factor numerator.
    pub fn num_entries(&self) -> usize {
        self.entries
    }

    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &[(u32, u32)])> {
        self.map.iter().map(|(&k, v)| (k, v.as_slice()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_lookup() {
        let mut ix = InvertedIndex::new();
        ix.insert(5, 0, 0);
        ix.insert(5, 3, 1);
        ix.insert(9, 1, 0);
        assert_eq!(ix.get(5), &[(0, 0), (3, 1)]);
        assert_eq!(ix.get(9), &[(1, 0)]);
        assert_eq!(ix.get(42), &[] as &[(u32, u32)]);
        assert!(ix.contains(5));
        assert!(!ix.contains(42));
        assert_eq!(ix.num_nodes(), 2);
        assert_eq!(ix.num_entries(), 3);
    }

    #[test]
    fn replication_counts_duplicates() {
        let mut ix = InvertedIndex::new();
        // Same node wanted by 3 subgraphs = replication factor 3 for its edges.
        for slot in 0..3 {
            ix.insert(1, slot, 0);
        }
        assert_eq!(ix.num_nodes(), 1);
        assert_eq!(ix.num_entries(), 3);
    }
}
