//! Fanout specification: how many neighbors to keep per hop.

/// Per-hop fanout, e.g. the paper's `FanoutSpec::paper()` = (40, 20).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FanoutSpec {
    pub fanouts: Vec<u32>,
}

impl FanoutSpec {
    pub fn new(fanouts: Vec<u32>) -> Self {
        assert!(!fanouts.is_empty(), "need at least one hop");
        assert!(fanouts.iter().all(|&f| f > 0), "fanouts must be positive");
        Self { fanouts }
    }

    /// The paper's evaluation setting: 2-hop, 40 then 20.
    pub fn paper() -> Self {
        Self::new(vec![40, 20])
    }

    /// Small spec matched to the default AOT training artifact.
    pub fn small() -> Self {
        Self::new(vec![10, 5])
    }

    pub fn hops(&self) -> usize {
        self.fanouts.len()
    }

    /// Maximum sampled nodes per subgraph, *excluding* the seed:
    /// f1 + f1*f2 + f1*f2*f3 + ...
    pub fn max_nodes(&self) -> u64 {
        let mut total = 0u64;
        let mut layer = 1u64;
        for &f in &self.fanouts {
            layer *= f as u64;
            total += layer;
        }
        total
    }

    /// Parse `"40,20"`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let fanouts: Result<Vec<u32>, _> = s.split(',').map(|p| p.trim().parse::<u32>()).collect();
        let fanouts = fanouts.map_err(|e| anyhow::anyhow!("bad fanout spec '{s}': {e}"))?;
        if fanouts.is_empty() || fanouts.iter().any(|&f| f == 0) {
            anyhow::bail!("bad fanout spec '{s}': need positive per-hop fanouts");
        }
        Ok(Self::new(fanouts))
    }
}

impl std::fmt::Display for FanoutSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s: Vec<String> = self.fanouts.iter().map(|x| x.to_string()).collect();
        write!(f, "{}", s.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_spec() {
        let s = FanoutSpec::paper();
        assert_eq!(s.hops(), 2);
        assert_eq!(s.max_nodes(), 40 + 40 * 20);
    }

    #[test]
    fn parse_and_display() {
        let s = FanoutSpec::parse("10, 5").unwrap();
        assert_eq!(s.fanouts, vec![10, 5]);
        assert_eq!(s.to_string(), "10,5");
        assert!(FanoutSpec::parse("10,0").is_err());
        assert!(FanoutSpec::parse("").is_err());
        assert!(FanoutSpec::parse("a,b").is_err());
    }

    #[test]
    #[should_panic]
    fn zero_fanout_panics() {
        FanoutSpec::new(vec![0]);
    }
}
