//! The sampled subgraph type produced by every generation engine and
//! consumed by batch assembly ([`crate::train::batch`]).

use crate::graph::NodeId;

use super::spec::FanoutSpec;

/// A 2-hop (generally k-hop) sampled neighborhood rooted at `seed`.
///
/// Layered tree representation matching the fixed-fanout training layout:
/// `hop1` holds up to `f1` neighbors of the seed; `hop2[i]` holds up to
/// `f2` neighbors of `hop1[i]`, and so on. Engines must emit hops in
/// priority order (what [`super::reservoir::TopK::nodes`] yields) so that
/// identical sampling decisions produce byte-identical subgraphs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Subgraph {
    pub seed: NodeId,
    pub hop1: Vec<NodeId>,
    /// `hop2[i]` = sampled neighbors of `hop1[i]`. `hop2.len() == hop1.len()`.
    pub hop2: Vec<Vec<NodeId>>,
}

impl Subgraph {
    pub fn new(seed: NodeId) -> Self {
        Self { seed, hop1: Vec::new(), hop2: Vec::new() }
    }

    /// Total sampled node slots including the seed (counting multiplicity;
    /// the padded training layout also counts this way). This is the unit
    /// behind the paper's "nodes per second" generation metric.
    pub fn num_nodes(&self) -> u64 {
        1 + self.hop1.len() as u64 + self.hop2.iter().map(|h| h.len() as u64).sum::<u64>()
    }

    /// Number of tree edges (seed→hop1 plus hop1→hop2).
    pub fn num_edges(&self) -> u64 {
        self.hop1.len() as u64 + self.hop2.iter().map(|h| h.len() as u64).sum::<u64>()
    }

    /// Check structural invariants against a fanout spec.
    pub fn validate(&self, spec: &FanoutSpec) -> Result<(), String> {
        if spec.hops() != 2 {
            return Err("Subgraph currently models 2-hop trees".into());
        }
        let (f1, f2) = (spec.fanouts[0] as usize, spec.fanouts[1] as usize);
        if self.hop1.len() > f1 {
            return Err(format!("hop1 {} > fanout {}", self.hop1.len(), f1));
        }
        if self.hop2.len() != self.hop1.len() {
            return Err(format!(
                "hop2 groups {} != hop1 nodes {}",
                self.hop2.len(),
                self.hop1.len()
            ));
        }
        for (i, h) in self.hop2.iter().enumerate() {
            if h.len() > f2 {
                return Err(format!("hop2[{i}] {} > fanout {f2}", h.len()));
            }
        }
        Ok(())
    }

    /// Serialized size in bytes (used for storage/IO accounting and the
    /// offline-baseline spill format).
    pub fn encoded_len(&self) -> usize {
        // seed + hop1 len + hop1 + per-group len + hop2
        4 + 2 + 4 * self.hop1.len() + self.hop2.iter().map(|h| 2 + 4 * h.len()).sum::<usize>()
    }

    /// Append the binary encoding to `out` (little-endian, u16 lengths).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.hop1.len() as u16).to_le_bytes());
        for &v in &self.hop1 {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for h in &self.hop2 {
            out.extend_from_slice(&(h.len() as u16).to_le_bytes());
            for &v in h {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    /// Decode one subgraph from `buf` starting at `pos`; advances `pos`.
    pub fn decode_from(buf: &[u8], pos: &mut usize) -> anyhow::Result<Self> {
        let take4 = |buf: &[u8], pos: &mut usize| -> anyhow::Result<[u8; 4]> {
            let b = buf
                .get(*pos..*pos + 4)
                .ok_or_else(|| anyhow::anyhow!("truncated subgraph"))?;
            *pos += 4;
            Ok(b.try_into().unwrap())
        };
        let take2 = |buf: &[u8], pos: &mut usize| -> anyhow::Result<u16> {
            let b = buf
                .get(*pos..*pos + 2)
                .ok_or_else(|| anyhow::anyhow!("truncated subgraph"))?;
            *pos += 2;
            Ok(u16::from_le_bytes(b.try_into().unwrap()))
        };
        let seed = NodeId::from_le_bytes(take4(buf, pos)?);
        let n1 = take2(buf, pos)? as usize;
        let mut hop1 = Vec::with_capacity(n1);
        for _ in 0..n1 {
            hop1.push(NodeId::from_le_bytes(take4(buf, pos)?));
        }
        let mut hop2 = Vec::with_capacity(n1);
        for _ in 0..n1 {
            let n2 = take2(buf, pos)? as usize;
            let mut h = Vec::with_capacity(n2);
            for _ in 0..n2 {
                h.push(NodeId::from_le_bytes(take4(buf, pos)?));
            }
            hop2.push(h);
        }
        Ok(Self { seed, hop1, hop2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Subgraph {
        Subgraph {
            seed: 7,
            hop1: vec![1, 2],
            hop2: vec![vec![3, 4, 5], vec![]],
        }
    }

    #[test]
    fn counts() {
        let s = sample();
        assert_eq!(s.num_nodes(), 1 + 2 + 3);
        assert_eq!(s.num_edges(), 2 + 3);
    }

    #[test]
    fn validate_against_spec() {
        let s = sample();
        assert!(s.validate(&FanoutSpec::new(vec![2, 3])).is_ok());
        assert!(s.validate(&FanoutSpec::new(vec![1, 3])).is_err()); // hop1 too big
        assert!(s.validate(&FanoutSpec::new(vec![2, 2])).is_err()); // hop2 group too big
        let mut bad = sample();
        bad.hop2.pop();
        assert!(bad.validate(&FanoutSpec::new(vec![2, 3])).is_err());
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = sample();
        let mut buf = Vec::new();
        s.encode_into(&mut buf);
        assert_eq!(buf.len(), s.encoded_len());
        let mut pos = 0;
        let d = Subgraph::decode_from(&buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        assert_eq!(d, s);
    }

    #[test]
    fn decode_rejects_truncation() {
        let s = sample();
        let mut buf = Vec::new();
        s.encode_into(&mut buf);
        for cut in [0, 3, buf.len() - 1] {
            let mut pos = 0;
            assert!(Subgraph::decode_from(&buf[..cut], &mut pos).is_err());
        }
    }
}
