//! Deterministic top-k "reservoir": keep the k items with the smallest
//! hash priorities. The distributed equivalent of "sample k neighbors
//! uniformly" — and crucially, **associative + commutative under merge**,
//! which makes the paper's hierarchical tree reduction exact (§2 step 3).
//!
//! Representation note (§Perf): entries are kept sorted ascending by
//! (priority, node). An unsorted layout with a cached threshold was tried
//! and measured **37% slower** on the E1 hot path (the duplicate check
//! degenerates to O(len) per insert during filling); the sorted layout
//! gets idempotence for free from the binary search and its memmoves stay
//! within one cache line at realistic fanouts. See EXPERIMENTS.md §Perf.

use crate::graph::NodeId;

/// Top-k-by-priority set of nodes. Invariants: entries sorted ascending by
/// (priority, node), length ≤ k, no duplicate (priority, node) pairs
/// (insert is idempotent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopK {
    k: usize,
    entries: Vec<(u64, NodeId)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        Self { k, entries: Vec::with_capacity(k.min(64)) }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.entries.len() == self.k
    }

    /// Current admission threshold: priorities >= this are rejected when
    /// full. Lets the edge-centric scan skip hash+insert work cheaply.
    #[inline]
    pub fn threshold(&self) -> u64 {
        if self.is_full() {
            self.entries[self.k - 1].0
        } else {
            u64::MAX
        }
    }

    /// Insert a candidate. Returns true if it was admitted.
    #[inline]
    pub fn insert(&mut self, priority: u64, node: NodeId) -> bool {
        if priority >= self.threshold() {
            return false;
        }
        match self.entries.binary_search(&(priority, node)) {
            Ok(_) => false, // identical (priority, node): idempotent
            Err(pos) => {
                self.entries.insert(pos, (priority, node));
                if self.entries.len() > self.k {
                    self.entries.pop();
                }
                true
            }
        }
    }

    /// Merge another reservoir into this one (same k).
    pub fn merge(&mut self, other: &TopK) {
        debug_assert_eq!(self.k, other.k);
        for &(p, n) in &other.entries {
            self.insert(p, n);
        }
    }

    /// Re-arm for a new use with capacity retained — the arena-reuse hook:
    /// a pooled `TopK` is `reset` instead of reallocated, so steady-state
    /// hop rounds perform no reservoir heap allocations.
    #[inline]
    pub fn reset(&mut self, k: usize) {
        debug_assert!(k > 0);
        self.k = k;
        self.entries.clear();
    }

    /// Become a copy of `other`, reusing this reservoir's buffer.
    #[inline]
    pub fn copy_from(&mut self, other: &TopK) {
        self.k = other.k;
        self.entries.clear();
        self.entries.extend_from_slice(&other.entries);
    }

    /// Become the merge of `a` and `b`, reusing this reservoir's buffer.
    /// Both inputs are sorted and duplicate-free (the `TopK` invariant),
    /// so a two-pointer merge beats repeated binary-search inserts.
    /// Produces the identical set to the insert-based
    /// [`merge`](Self::merge) (property-tested) whenever priorities at
    /// the k-boundary are untied across distinct nodes — always, in
    /// practice, with 64-bit hash priorities. On such a tie this keeps
    /// the smaller `(priority, node)` tuple (order-independent, hence
    /// exactly associative), whereas the insert path's threshold check
    /// keeps the incumbent — a ~2⁻⁶⁴ divergence the seed code never
    /// defined consistently either (its SQL window breaks priority ties
    /// by unstable sort order).
    pub fn assign_merged(&mut self, a: &TopK, b: &TopK) {
        debug_assert_eq!(a.k, b.k);
        self.k = a.k;
        self.entries.clear();
        let (ea, eb) = (&a.entries, &b.entries);
        let (mut i, mut j) = (0usize, 0usize);
        while self.entries.len() < self.k && (i < ea.len() || j < eb.len()) {
            let from_a = match (ea.get(i), eb.get(j)) {
                (Some(x), Some(y)) => {
                    if x == y {
                        j += 1; // identical (priority, node): keep one
                        true
                    } else {
                        x < y
                    }
                }
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => unreachable!(),
            };
            if from_a {
                self.entries.push(ea[i]);
                i += 1;
            } else {
                self.entries.push(eb[j]);
                j += 1;
            }
        }
    }

    /// The kept nodes, in priority order.
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries.iter().map(|&(_, n)| n)
    }

    /// Entries sorted ascending by (priority, node).
    pub fn entries_sorted(&self) -> Vec<(u64, NodeId)> {
        self.entries.clone()
    }

    pub fn entries(&self) -> &[(u64, NodeId)] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Cases;
    use crate::util::rng::mix64;

    #[test]
    fn keeps_k_smallest() {
        let mut r = TopK::new(3);
        for (p, n) in [(50, 5), (10, 1), (40, 4), (20, 2), (30, 3)] {
            r.insert(p, n);
        }
        let kept: Vec<NodeId> = r.nodes().collect();
        assert_eq!(kept, vec![1, 2, 3]);
        assert!(r.is_full());
        assert_eq!(r.threshold(), 30);
    }

    #[test]
    fn insert_is_idempotent() {
        let mut r = TopK::new(2);
        assert!(r.insert(5, 1));
        assert!(!r.insert(5, 1));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn under_filled_accepts_everything() {
        let mut r = TopK::new(10);
        for n in 0..5u32 {
            assert!(r.insert(mix64(n as u64), n));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.threshold(), u64::MAX);
    }

    #[test]
    fn eviction_keeps_exactly_k_and_updates_threshold() {
        let mut r = TopK::new(2);
        r.insert(30, 3);
        r.insert(20, 2);
        assert_eq!(r.threshold(), 30);
        assert!(r.insert(10, 1)); // evicts 30
        assert_eq!(r.len(), 2);
        assert_eq!(r.threshold(), 20);
        assert!(!r.insert(25, 9)); // >= threshold
        let kept: Vec<NodeId> = r.nodes().collect();
        assert_eq!(kept, vec![1, 2]);
    }

    /// Matches a sorted-reference implementation on random streams.
    #[test]
    fn matches_sorted_reference() {
        Cases::new("topk vs sorted reference", 200).run(|rng| {
            let k = 1 + rng.gen_range(10) as usize;
            let mut r = TopK::new(k);
            let mut all: Vec<(u64, NodeId)> = Vec::new();
            for _ in 0..rng.gen_range(100) {
                let p = mix64(rng.next_u64());
                let n = rng.gen_range(1000) as NodeId;
                r.insert(p, n);
                if !all.contains(&(p, n)) {
                    all.push((p, n));
                }
            }
            all.sort_unstable();
            all.truncate(k);
            assert_eq!(r.entries_sorted(), all);
        });
    }

    /// Two-pointer merge-into-buffer equals the insert-based merge — the
    /// dense-frame reduce path depends on this equivalence.
    #[test]
    fn assign_merged_matches_insert_merge() {
        Cases::new("assign_merged == merge", 200).run(|rng| {
            let k = 1 + rng.gen_range(8) as usize;
            let mk = |rng: &mut crate::util::rng::Xoshiro256| {
                let mut r = TopK::new(k);
                for _ in 0..rng.gen_range(30) {
                    r.insert(mix64(rng.next_u64()), rng.gen_range(50) as NodeId);
                }
                r
            };
            let a = mk(rng);
            let b = mk(rng);
            let mut reference = a.clone();
            reference.merge(&b);
            let mut out = TopK::new(1); // stale state: must be overwritten
            out.insert(7, 7);
            out.assign_merged(&a, &b);
            assert_eq!(out, reference);
        });
    }

    /// Reset re-arms a used reservoir with no stale entries.
    #[test]
    fn reset_clears_state() {
        let mut r = TopK::new(2);
        r.insert(10, 1);
        r.insert(20, 2);
        r.reset(3);
        assert!(r.is_empty());
        assert_eq!(r.k(), 3);
        assert_eq!(r.threshold(), u64::MAX);
        let mut c = TopK::new(1);
        c.copy_from(&r);
        assert!(c.is_empty());
        assert_eq!(c.k(), 3);
    }

    /// The property the tree reduction depends on: merging in any grouping
    /// and order gives the same reservoir as inserting everything into one.
    #[test]
    fn merge_is_associative_and_commutative() {
        Cases::new("topk merge assoc/comm", 200).run(|rng| {
            let k = 1 + rng.gen_range(8) as usize;
            let n_items = rng.gen_range(40) as usize;
            let items: Vec<(u64, NodeId)> = (0..n_items)
                .map(|_| (mix64(rng.next_u64()), rng.gen_range(1000) as NodeId))
                .collect();

            // Reference: single reservoir, sequential insert.
            let mut reference = TopK::new(k);
            for &(p, n) in &items {
                reference.insert(p, n);
            }

            // Random partition into 1-4 groups, random merge order.
            let groups = 1 + rng.gen_range(4) as usize;
            let mut parts: Vec<TopK> = (0..groups).map(|_| TopK::new(k)).collect();
            for &(p, n) in &items {
                parts[rng.gen_range(groups as u64) as usize].insert(p, n);
            }
            // Merge in random order (fold pairwise).
            while parts.len() > 1 {
                let i = rng.gen_range(parts.len() as u64) as usize;
                let part = parts.swap_remove(i);
                let j = rng.gen_range(parts.len() as u64) as usize;
                parts[j].merge(&part);
            }
            assert_eq!(parts[0], reference);
        });
    }
}
