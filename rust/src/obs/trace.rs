//! Span tracer: per-thread ring buffers of timeline events plus the
//! Chrome trace-event JSON exporter.
//!
//! Every concurrency layer of the pipeline records onto a named
//! [`Track`] (one Perfetto row per track). Spans are opened with
//! [`span`]/[`span_on`] and closed by dropping the returned RAII
//! [`SpanGuard`]; point-in-time decisions (depth-controller steps, stall
//! classifications, admission credits, cache evictions) are [`instant`]
//! events. Each event carries a globally monotonic sequence number
//! assigned at record time, so per-track order is recoverable even after
//! the per-thread rings are merged.
//!
//! Threads record into a thread-local ring registered with a global
//! collector on first use — persistent pool workers park forever, so the
//! collector (not thread exit) is what drains them. Rings are bounded
//! ([`RING_CAP`] events); overflow overwrites the oldest events and is
//! counted, never reallocated past the cap.

use std::cell::{Cell, OnceCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Maximum numeric arguments attached to one event (fixed-size so events
/// never allocate).
pub const MAX_ARGS: usize = 4;

/// Per-thread ring capacity in events. At wave/job/batch granularity a
/// run records a few thousand events per track; 64Ki leaves headroom
/// without unbounded growth on long runs.
pub const RING_CAP: usize = 1 << 16;

/// A timeline row. One per concurrency role; indexed variants carry the
/// worker slot so e.g. each speculator gets its own row.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Track {
    /// The caller thread driving waves/reduce/emit (also the pipeline
    /// trainer-side caller in sequential mode).
    Main,
    /// The dedicated generation thread of the concurrent pipeline.
    Generator,
    /// Training-queue admission/credit events.
    Queue,
    /// The spill write-behind flusher thread.
    SpillFlush,
    /// The spill read-ahead prefetcher thread.
    SpillPrefetch,
    /// Cold-tier page faults (tiered memory): one marker per promotion
    /// so paging stalls line up against generation bubbles.
    TierFault,
    /// Distributed-run fault handling on the coordinator: lease expiries,
    /// worker losses and stale-wave reclaims (see `cluster::proc`).
    ClusterRecovery,
    /// Trainer worker `i` of the data-parallel training loop.
    Trainer(u16),
    /// Look-ahead speculator `i` (out-of-order wave claiming).
    Speculator(u16),
    /// Persistent scan-pool worker `i` (`WorkPool::global`).
    PoolWorker(u16),
    /// Gather-pool worker `i` (`WorkPool::gather_global`).
    GatherWorker(u16),
}

impl Track {
    /// Stable Chrome-trace thread id. Ranges are spaced so indexed
    /// tracks never collide: trainers 10+, speculators 40+, pool workers
    /// 100+, gather workers 300+.
    pub fn tid(self) -> u64 {
        match self {
            Track::Main => 0,
            Track::Generator => 1,
            Track::Queue => 2,
            Track::SpillFlush => 3,
            Track::SpillPrefetch => 4,
            Track::TierFault => 5,
            Track::ClusterRecovery => 6,
            Track::Trainer(i) => 10 + i as u64,
            Track::Speculator(i) => 40 + i as u64,
            Track::PoolWorker(i) => 100 + (i as u64).min(199),
            Track::GatherWorker(i) => 300 + (i as u64).min(199),
        }
    }

    /// Human-readable row label (the Perfetto thread name).
    pub fn label(self) -> String {
        match self {
            Track::Main => "main".into(),
            Track::Generator => "generator".into(),
            Track::Queue => "queue".into(),
            Track::SpillFlush => "spill-flush".into(),
            Track::SpillPrefetch => "spill-prefetch".into(),
            Track::TierFault => "tier-fault".into(),
            Track::ClusterRecovery => "cluster-recovery".into(),
            Track::Trainer(i) => format!("trainer-{i}"),
            Track::Speculator(i) => format!("speculator-{i}"),
            Track::PoolWorker(i) => format!("pool-worker-{i}"),
            Track::GatherWorker(i) => format!("gather-worker-{i}"),
        }
    }
}

/// One recorded timeline event (fixed-size, `Copy`, allocation-free).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub track: Track,
    pub name: &'static str,
    /// Microseconds since the trace epoch.
    pub start_us: u64,
    /// Span duration in µs (0 for instants).
    pub dur_us: u64,
    /// Instant event (a point marker) rather than a duration span.
    pub instant: bool,
    /// Globally monotonic sequence number assigned at record time
    /// (therefore monotonic within every track).
    pub seq: u64,
    pub args: [(&'static str, f64); MAX_ARGS],
    pub nargs: u8,
}

const NO_ARGS: [(&'static str, f64); MAX_ARGS] = [("", 0.0); MAX_ARGS];

struct RingInner {
    buf: Vec<Event>,
    next: usize,
    dropped: u64,
}

/// One thread's event ring. The owning thread pushes; the collector
/// drains. The mutex is uncontended except at drain time.
struct ThreadRing {
    inner: Mutex<RingInner>,
}

impl ThreadRing {
    fn push(&self, ev: Event) {
        let mut r = self.inner.lock().unwrap();
        if r.buf.len() < RING_CAP {
            r.buf.push(ev);
        } else {
            let i = r.next;
            r.buf[i] = ev;
            r.next = (r.next + 1) % RING_CAP;
            r.dropped += 1;
        }
    }
}

static SEQ: AtomicU64 = AtomicU64::new(0);
static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();

thread_local! {
    static RING: OnceCell<Arc<ThreadRing>> = const { OnceCell::new() };
    static TRACK: Cell<Track> = const { Cell::new(Track::Main) };
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn record(ev: Event) {
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let ring = Arc::new(ThreadRing {
                inner: Mutex::new(RingInner { buf: Vec::new(), next: 0, dropped: 0 }),
            });
            registry().lock().unwrap().push(ring.clone());
            ring
        });
        ring.push(ev);
    });
}

/// Bind this thread to a track. Long-lived role threads (pool workers,
/// speculators, trainer workers, spill threads, the generator) call this
/// once at startup; [`span`]/[`instant`] then land on the bound track.
pub fn set_track(track: Track) {
    TRACK.with(|t| t.set(track));
}

/// The track this thread records onto (default [`Track::Main`]).
pub fn current_track() -> Track {
    TRACK.with(|t| t.get())
}

/// RAII span: records a duration event from construction to drop. Inert
/// (no clock reads, no buffer touches) when tracing is disabled.
pub struct SpanGuard {
    active: bool,
    track: Track,
    name: &'static str,
    start_us: u64,
    args: [(&'static str, f64); MAX_ARGS],
    nargs: u8,
}

impl SpanGuard {
    /// Attach a numeric argument (builder style). No-op when inert or at
    /// the [`MAX_ARGS`] cap.
    pub fn arg(mut self, key: &'static str, value: f64) -> SpanGuard {
        self.push_arg(key, value);
        self
    }

    /// Attach a numeric argument after construction (e.g. a value only
    /// known mid-span).
    pub fn push_arg(&mut self, key: &'static str, value: f64) {
        if self.active && (self.nargs as usize) < MAX_ARGS {
            self.args[self.nargs as usize] = (key, value);
            self.nargs += 1;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = super::now_us();
        record(Event {
            track: self.track,
            name: self.name,
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            instant: false,
            seq: SEQ.fetch_add(1, Ordering::Relaxed),
            args: self.args,
            nargs: self.nargs,
        });
    }
}

#[inline]
fn inert(name: &'static str) -> SpanGuard {
    SpanGuard { active: false, track: Track::Main, name, start_us: 0, args: NO_ARGS, nargs: 0 }
}

/// Open a span on the thread's bound track.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return inert(name);
    }
    span_on(current_track(), name)
}

/// Open a span on an explicit track (for events recorded on behalf of
/// another role, e.g. queue-side bookkeeping).
#[inline]
pub fn span_on(track: Track, name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return inert(name);
    }
    SpanGuard {
        active: true,
        track,
        name,
        start_us: super::now_us(),
        args: NO_ARGS,
        nargs: 0,
    }
}

/// Record an instant (point) event on the thread's bound track.
#[inline]
pub fn instant(name: &'static str, args: &[(&'static str, f64)]) {
    if !super::enabled() {
        return;
    }
    instant_on(current_track(), name, args);
}

/// Record an instant event on an explicit track.
#[inline]
pub fn instant_on(track: Track, name: &'static str, args: &[(&'static str, f64)]) {
    if !super::enabled() {
        return;
    }
    let mut a = NO_ARGS;
    let n = args.len().min(MAX_ARGS);
    a[..n].copy_from_slice(&args[..n]);
    record(Event {
        track,
        name,
        start_us: super::now_us(),
        dur_us: 0,
        instant: true,
        seq: SEQ.fetch_add(1, Ordering::Relaxed),
        args: a,
        nargs: n as u8,
    });
}

/// Drain all threads' rings into one record-order (sequence-sorted)
/// vector, plus the total number of ring-overflow drops. Rings stay
/// registered; subsequent events accumulate for the next drain.
pub fn drain() -> (Vec<Event>, u64) {
    let mut all = Vec::new();
    let mut dropped = 0;
    for ring in registry().lock().unwrap().iter() {
        let mut r = ring.inner.lock().unwrap();
        all.append(&mut r.buf);
        r.next = 0;
        dropped += r.dropped;
        r.dropped = 0;
    }
    all.sort_by_key(|e| e.seq);
    (all, dropped)
}

/// Render drained events as a Chrome trace-event document (the JSON
/// Object Format: `{"traceEvents": [...], ...}`), loadable in Perfetto
/// or `chrome://tracing`.
///
/// Sequence numbers are renumbered per track (rank in global record
/// order), so two identical single-threaded runs serialize to identical
/// bytes modulo the `ts`/`dur` fields.
pub fn chrome_trace_from(events: &[Event], dropped: u64) -> Json {
    let mut tracks: BTreeMap<u64, String> = BTreeMap::new();
    for e in events {
        tracks.entry(e.track.tid()).or_insert_with(|| e.track.label());
    }

    let mut out = Vec::with_capacity(events.len() + tracks.len());
    for (tid, label) in &tracks {
        let mut name_args = Json::obj();
        name_args.set("name", label.as_str());
        let mut m = Json::obj();
        m.set("ph", "M")
            .set("pid", 1.0)
            .set("tid", *tid as f64)
            .set("name", "thread_name")
            .set("args", name_args);
        out.push(m);
    }

    let mut track_rank: BTreeMap<u64, u64> = BTreeMap::new();
    for e in events {
        let rank = track_rank.entry(e.track.tid()).or_insert(0);
        let mut args = Json::obj();
        args.set("seq", *rank as f64);
        *rank += 1;
        for (k, v) in e.args.iter().take(e.nargs as usize) {
            args.set(k, *v);
        }
        let mut j = Json::obj();
        j.set("pid", 1.0)
            .set("tid", e.track.tid() as f64)
            .set("name", e.name)
            .set("ts", e.start_us as f64)
            .set("args", args);
        if e.instant {
            j.set("ph", "i").set("s", "t");
        } else {
            j.set("ph", "X").set("dur", e.dur_us as f64);
        }
        out.push(j);
    }

    let mut other = Json::obj();
    other.set("run_meta", super::report::run_meta());
    other.set("dropped_events", dropped as f64);

    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(out))
        .set("displayTimeUnit", "ms")
        .set("otherData", other);
    doc
}

/// Drain every ring and write the Chrome trace-event JSON to `path`.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<()> {
    let (events, dropped) = drain();
    let doc = chrome_trace_from(&events, dropped);
    std::fs::write(path, doc.to_string() + "\n")
}
