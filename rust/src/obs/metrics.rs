//! Process-global metrics registry: named atomic counters/gauges plus
//! [`LogHistogram`] latency histograms, registered once and snapshotted
//! as JSON lines.
//!
//! Handles ([`Counter`], [`Gauge`], [`Hist`]) are cheap clones of the
//! underlying shared cell; instrumentation sites look them up once (a
//! registry lock) and then update lock-free (counters/gauges) or under a
//! short uncontended mutex (histograms). Updates are unconditional —
//! they are cheap enough to run even when tracing is off, and the
//! registry allocates only at registration.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// Monotonic named counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins named gauge (an `f64` stored as bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Named latency histogram (log-bucketed nanoseconds).
#[derive(Clone)]
pub struct Hist(Arc<Mutex<LogHistogram>>);

impl Hist {
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.0.lock().unwrap().record(ns);
    }

    /// Fold a locally-accumulated histogram in (one lock instead of one
    /// per sample — the pattern for per-thread histograms).
    pub fn merge(&self, other: &LogHistogram) {
        self.0.lock().unwrap().merge(other);
    }

    pub fn count(&self) -> u64 {
        self.0.lock().unwrap().count()
    }
}

enum Slot {
    C(Counter),
    G(Gauge),
    H(Hist),
}

static REG: OnceLock<Mutex<BTreeMap<String, Slot>>> = OnceLock::new();

fn reg() -> &'static Mutex<BTreeMap<String, Slot>> {
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Get-or-register the named counter. Panics if `name` is already
/// registered as a different kind (a wiring bug, not a runtime state).
pub fn counter(name: &str) -> Counter {
    let mut m = reg().lock().unwrap();
    match m
        .entry(name.to_string())
        .or_insert_with(|| Slot::C(Counter(Arc::new(AtomicU64::new(0)))))
    {
        Slot::C(c) => c.clone(),
        _ => panic!("metric {name:?} already registered with a different kind"),
    }
}

/// Get-or-register the named gauge.
pub fn gauge(name: &str) -> Gauge {
    let mut m = reg().lock().unwrap();
    match m
        .entry(name.to_string())
        .or_insert_with(|| Slot::G(Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))))
    {
        Slot::G(g) => g.clone(),
        _ => panic!("metric {name:?} already registered with a different kind"),
    }
}

/// Get-or-register the named histogram.
pub fn histogram(name: &str) -> Hist {
    let mut m = reg().lock().unwrap();
    match m
        .entry(name.to_string())
        .or_insert_with(|| Slot::H(Hist(Arc::new(Mutex::new(LogHistogram::new())))))
    {
        Slot::H(h) => h.clone(),
        _ => panic!("metric {name:?} already registered with a different kind"),
    }
}

/// One point-in-time view of the whole registry, keyed by kind. The
/// snapshotter emits one of these per tick as a JSON line.
pub fn snapshot() -> Json {
    let m = reg().lock().unwrap();
    let mut counters = Json::obj();
    let mut gauges = Json::obj();
    let mut hists = Json::obj();
    for (name, slot) in m.iter() {
        match slot {
            Slot::C(c) => {
                counters.set(name, c.get());
            }
            Slot::G(g) => {
                gauges.set(name, g.get());
            }
            Slot::H(h) => {
                let hg = h.0.lock().unwrap();
                let mut j = Json::obj();
                // An empty histogram's mean is NaN, which JSON can't carry.
                let mean = hg.mean_ns();
                j.set("count", hg.count())
                    .set("mean_ns", if mean.is_finite() { mean } else { 0.0 })
                    .set("p50_ns", hg.quantile_ns(0.50))
                    .set("p90_ns", hg.quantile_ns(0.90))
                    .set("p99_ns", hg.quantile_ns(0.99));
                hists.set(name, j);
            }
        }
    }
    let mut out = Json::obj();
    out.set("t_us", super::now_us())
        .set("counters", counters)
        .set("gauges", gauges)
        .set("histograms", hists);
    out
}

/// Background thread appending a [`snapshot`] JSON line to a file every
/// tick. Stopped (with one final snapshot) via [`Snapshotter::stop`] or
/// drop.
pub struct Snapshotter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Snapshotter {
    pub fn spawn(path: &Path, every: Duration) -> Snapshotter {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let path = path.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("gg-obs-snapshot".into())
            .spawn(move || {
                let file = std::fs::OpenOptions::new().create(true).append(true).open(&path);
                let mut file = match file {
                    Ok(f) => f,
                    Err(e) => {
                        log::warn!("obs: cannot open snapshot file {}: {e}", path.display());
                        return;
                    }
                };
                let tick = Duration::from_millis(50);
                loop {
                    let mut waited = Duration::ZERO;
                    while waited < every && !flag.load(Ordering::Relaxed) {
                        std::thread::sleep(tick.min(every - waited));
                        waited += tick;
                    }
                    let line = snapshot().to_string();
                    if writeln!(file, "{line}").is_err() {
                        return;
                    }
                    if flag.load(Ordering::Relaxed) {
                        return;
                    }
                }
            })
            .expect("spawn obs snapshotter");
        Snapshotter { stop, handle: Some(handle) }
    }

    /// Signal the thread, wait for its final snapshot line.
    pub fn stop(mut self) {
        self.join();
    }

    fn join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip_through_snapshot() {
        let c = counter("test.metrics.counter");
        c.add(3);
        c.inc();
        gauge("test.metrics.gauge").set(2.5);
        histogram("test.metrics.hist").record_ns(1500);
        let snap = snapshot();
        let c = snap.get("counters").unwrap().get("test.metrics.counter");
        assert_eq!(c.unwrap().as_u64(), Some(4));
        let g = snap.get("gauges").unwrap().get("test.metrics.gauge");
        assert_eq!(g.unwrap().as_f64(), Some(2.5));
        let h = snap.get("histograms").unwrap().get("test.metrics.hist");
        assert_eq!(h.unwrap().get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn handles_alias_the_same_cell() {
        let a = counter("test.metrics.alias");
        let b = counter("test.metrics.alias");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
    }

    #[test]
    fn snapshotter_appends_json_lines() {
        let dir = std::env::temp_dir().join(format!("gg_obs_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        counter("test.metrics.snapline").inc();
        let s = Snapshotter::spawn(&path, Duration::from_millis(10));
        std::thread::sleep(Duration::from_millis(40));
        s.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert!(!lines.is_empty());
        for line in lines {
            let j = Json::parse(line).expect("each snapshot line parses");
            assert!(j.get("counters").is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
