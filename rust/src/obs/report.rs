//! The single writer every report/trajectory JSON goes through.
//!
//! Commands and benches register run metadata once ([`set_meta`]) —
//! engine, thread budget, look-ahead shape — and every document written
//! via [`write_json`] is stamped with a `run_meta` header that includes
//! a hash of the metadata, so a `BENCH_*.json` found in CI artifacts is
//! attributable to the exact configuration that produced it.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

use crate::util::fxhash::fxhash;
use crate::util::json::Json;

static META: OnceLock<Mutex<BTreeMap<String, Json>>> = OnceLock::new();

fn meta() -> &'static Mutex<BTreeMap<String, Json>> {
    META.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record one run-metadata key (last write wins). Standard keys:
/// `engine`, `threads`, `gather_threads`, `lookahead_depth`,
/// `lookahead_workers`; callers may add their own.
pub fn set_meta(key: &str, value: impl Into<Json>) {
    meta().lock().unwrap().insert(key.to_string(), value.into());
}

/// Stamp the standard keys from a run configuration in one call.
pub fn set_run_config_meta(cfg: &crate::config::RunConfig) {
    set_meta("threads", cfg.threads);
    set_meta("gather_threads", cfg.gather_threads);
    set_meta("lookahead_depth", cfg.lookahead_depth);
    set_meta("lookahead_workers", cfg.lookahead_workers);
    set_meta("config_json", cfg.to_json().to_string());
}

/// The run-metadata header: every key set so far plus `config_hash`, a
/// hash over the canonical serialization of those keys. Two documents
/// with equal hashes came from identical configurations.
pub fn run_meta() -> Json {
    let m = meta().lock().unwrap();
    let mut out = Json::obj();
    for (k, v) in m.iter() {
        out.set(k, v.clone());
    }
    let hash = fxhash(&out.to_string());
    out.set("config_hash", format!("{hash:016x}"));
    out
}

/// Write a report/trajectory object to `path`, injecting the `run_meta`
/// header. `root` must be a JSON object.
pub fn write_json(path: &Path, mut root: Json) -> std::io::Result<()> {
    root.set("run_meta", run_meta());
    std::fs::write(path, root.to_pretty() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_is_stamped_and_hashed() {
        set_meta("test_report_key", "v1");
        let dir = std::env::temp_dir().join(format!("gg_obs_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mut doc = Json::obj();
        doc.set("payload", 42u64);
        write_json(&path, doc).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("payload").unwrap().as_u64(), Some(42));
        let rm = back.get("run_meta").expect("run_meta header present");
        assert_eq!(rm.get("test_report_key").unwrap().as_str(), Some("v1"));
        let hash = rm.get("config_hash").unwrap().as_str().unwrap();
        assert_eq!(hash.len(), 16);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
