//! Unified runtime observability: span tracing, metrics registry and
//! Chrome-trace export.
//!
//! The pipeline runs five layers of concurrency (work-pool scan tasks,
//! out-of-order wave look-ahead, the gather pool, the spill
//! flusher/prefetcher pair and the training queue), and end-of-run
//! counters alone cannot attribute a bubble to its cause. This module
//! gives every layer a *track* on a shared clock:
//!
//! * [`trace`] — thread-local ring buffers of `(track, name, t_start,
//!   t_end, seq, args)` events recorded through an RAII [`trace::SpanGuard`],
//!   plus instant events for point-in-time decisions (depth-controller
//!   steps, stall classifications, admission credits, cache evictions).
//!   Drained into Chrome trace-event JSON that loads in Perfetto or
//!   `chrome://tracing`.
//! * [`metrics`] — process-global named atomic counters/gauges and
//!   [`crate::util::stats::LogHistogram`] latency histograms, registered
//!   once and snapshotted as JSON lines (`--obs-snapshot-secs`).
//! * [`report`] — the single writer every `BENCH_*.json` / report dump
//!   goes through, stamping a run-metadata header (engine, threads,
//!   look-ahead shape, config hash) so perf trajectories are attributable.
//!
//! # Overhead contract
//!
//! Everything is gated on one process-global flag read with a relaxed
//! atomic load ([`enabled`]). While disabled, instrumented code performs
//! **no clock reads and no allocations** — `span()` returns an inert
//! guard, `instant()` returns immediately, and the steady-state
//! zero-alloc assertions in `tests/pipeline_overlap.rs` hold with obs
//! compiled in. While enabled, recording one event costs one clock read
//! at open, one at close, and a push into a pre-registered thread-local
//! ring (an uncontended mutex: the owning thread pushes, only drains
//! contend). Events are fixed-size (`&'static str` names, numeric args),
//! so steady-state recording allocates only on ring growth up to the
//! per-thread cap.

pub mod metrics;
pub mod report;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The single hot-path gate: one relaxed atomic load. Instrumentation
/// sites check this before touching the clock or any buffer.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on. Pins the trace epoch on first call so all tracks
/// share one clock.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn tracing off. Already-recorded events stay buffered until drained.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Microseconds since the trace epoch. Only called on enabled paths.
#[inline]
pub(crate) fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Per-run observability session: enables tracing/snapshotting from the
/// run config and flushes outputs on drop, so traces survive error paths.
pub struct ObsSession {
    trace_out: Option<PathBuf>,
    snapshotter: Option<metrics::Snapshotter>,
}

impl ObsSession {
    /// Start a session. `trace_out` empty and `snapshot_secs == 0` leave
    /// observability disabled (the zero-overhead default).
    pub fn start(trace_out: &str, snapshot_secs: u64, snapshot_path: &str) -> ObsSession {
        let trace_out = if trace_out.is_empty() {
            None
        } else {
            enable();
            Some(PathBuf::from(trace_out))
        };
        let snapshotter = if snapshot_secs > 0 {
            enable();
            Some(metrics::Snapshotter::spawn(
                Path::new(snapshot_path),
                std::time::Duration::from_secs(snapshot_secs),
            ))
        } else {
            None
        };
        ObsSession { trace_out, snapshotter }
    }

    /// Flush outputs now (also runs on drop; explicit call surfaces I/O
    /// errors to the caller).
    pub fn finish(&mut self) -> std::io::Result<()> {
        if let Some(s) = self.snapshotter.take() {
            s.stop();
        }
        if let Some(path) = self.trace_out.take() {
            trace::write_chrome_trace(&path)?;
            log::info!("wrote trace timeline to {}", path.display());
        }
        Ok(())
    }
}

impl Drop for ObsSession {
    fn drop(&mut self) {
        if let Err(e) = self.finish() {
            log::warn!("obs: failed to flush trace output: {e}");
        }
    }
}
