//! Sharded on-disk subgraph store for the offline (GraphGen) baseline.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::sampler::Subgraph;

/// Shard target size before rotation (pre-compression).
const SHARD_BYTES: usize = 4 << 20;

/// I/O accounting for one store lifetime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpillReport {
    pub subgraphs: u64,
    pub shards: u32,
    /// Logical (uncompressed) bytes.
    pub logical_bytes: u64,
    /// Bytes on disk (after optional compression).
    pub disk_bytes: u64,
    pub write_time: Duration,
    pub read_time: Duration,
}

/// Writer/reader for sharded subgraph spill files.
///
/// Format per shard: `u32` subgraph count, then concatenated
/// [`Subgraph::encode_into`] records; optionally the whole shard is
/// deflate-compressed (`.z` suffix).
pub struct SpillStore {
    dir: PathBuf,
    compress: bool,
    // write state
    buf: Vec<u8>,
    buf_count: u32,
    report: SpillReport,
}

impl SpillStore {
    /// Create (and wipe) a spill directory.
    pub fn create(dir: PathBuf, compress: bool) -> Result<Self> {
        if dir.exists() {
            std::fs::remove_dir_all(&dir).with_context(|| format!("wipe {}", dir.display()))?;
        }
        std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;
        Ok(Self { dir, compress, buf: Vec::with_capacity(SHARD_BYTES + 4096), buf_count: 0, report: SpillReport::default() })
    }

    /// Append one subgraph (buffered; shards rotate at ~4 MiB).
    pub fn write(&mut self, sg: &Subgraph) -> Result<()> {
        let t0 = Instant::now();
        sg.encode_into(&mut self.buf);
        self.buf_count += 1;
        self.report.subgraphs += 1;
        if self.buf.len() >= SHARD_BYTES {
            self.flush_shard()?;
        }
        self.report.write_time += t0.elapsed();
        Ok(())
    }

    fn shard_path(&self, idx: u32) -> PathBuf {
        let ext = if self.compress { "sg.z" } else { "sg" };
        self.dir.join(format!("shard-{idx:05}.{ext}"))
    }

    fn flush_shard(&mut self) -> Result<()> {
        if self.buf_count == 0 {
            return Ok(());
        }
        let path = self.shard_path(self.report.shards);
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(&self.buf_count.to_le_bytes())?;
        self.report.logical_bytes += self.buf.len() as u64 + 4;
        if self.compress {
            let mut enc = flate2::write::DeflateEncoder::new(w, flate2::Compression::fast());
            enc.write_all(&self.buf)?;
            enc.finish()?.flush()?;
        } else {
            w.write_all(&self.buf)?;
            w.flush()?;
        }
        self.report.disk_bytes += std::fs::metadata(&path)?.len();
        self.report.shards += 1;
        self.buf.clear();
        self.buf_count = 0;
        Ok(())
    }

    /// Flush pending writes; call once generation finishes.
    pub fn finish_writes(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.flush_shard()?;
        self.report.write_time += t0.elapsed();
        Ok(())
    }

    /// Read every stored subgraph back (in shard order), invoking `f`.
    pub fn read_all(&mut self, mut f: impl FnMut(Subgraph) -> Result<()>) -> Result<()> {
        let t0 = Instant::now();
        for idx in 0..self.report.shards {
            let path = self.shard_path(idx);
            let mut file = File::open(&path).with_context(|| format!("open {}", path.display()))?;
            let mut count_buf = [0u8; 4];
            file.read_exact(&mut count_buf)?;
            let count = u32::from_le_bytes(count_buf);
            let mut data = Vec::new();
            if self.compress {
                flate2::read::DeflateDecoder::new(file).read_to_end(&mut data)?;
            } else {
                file.read_to_end(&mut data)?;
            }
            let mut pos = 0usize;
            for _ in 0..count {
                f(Subgraph::decode_from(&data, &mut pos)?)?;
            }
            anyhow::ensure!(pos == data.len(), "trailing bytes in {}", path.display());
        }
        self.report.read_time += t0.elapsed();
        Ok(())
    }

    pub fn report(&self) -> &SpillReport {
        &self.report
    }

    /// Remove the spill directory.
    pub fn cleanup(self) -> Result<()> {
        std::fs::remove_dir_all(&self.dir).with_context(|| format!("rm {}", self.dir.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;

    fn sg(seed: NodeId, width: usize) -> Subgraph {
        Subgraph {
            seed,
            hop1: (0..width as NodeId).collect(),
            hop2: (0..width).map(|i| vec![seed + i as NodeId; width]).collect(),
        }
    }

    fn dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ggspill-{tag}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_uncompressed() {
        let mut store = SpillStore::create(dir("u"), false).unwrap();
        let subs: Vec<Subgraph> = (0..500).map(|i| sg(i, 8)).collect();
        for s in &subs {
            store.write(s).unwrap();
        }
        store.finish_writes().unwrap();
        assert_eq!(store.report().subgraphs, 500);
        assert!(store.report().disk_bytes > 0);
        let mut got = Vec::new();
        store.read_all(|s| {
            got.push(s);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, subs);
        store.cleanup().unwrap();
    }

    #[test]
    fn roundtrip_compressed_and_smaller() {
        let subs: Vec<Subgraph> = (0..2000).map(|i| sg(i % 10, 10)).collect();
        let mut plain = SpillStore::create(dir("p"), false).unwrap();
        let mut comp = SpillStore::create(dir("c"), true).unwrap();
        for s in &subs {
            plain.write(s).unwrap();
            comp.write(s).unwrap();
        }
        plain.finish_writes().unwrap();
        comp.finish_writes().unwrap();
        assert!(comp.report().disk_bytes < plain.report().disk_bytes);
        let mut got = Vec::new();
        comp.read_all(|s| {
            got.push(s);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, subs);
        plain.cleanup().unwrap();
        comp.cleanup().unwrap();
    }

    #[test]
    fn shard_rotation() {
        let mut store = SpillStore::create(dir("r"), false).unwrap();
        // Each subgraph ~ (1+64)*... make them chunky to force >1 shard.
        for i in 0..3000 {
            store.write(&sg(i, 20)).unwrap();
        }
        store.finish_writes().unwrap();
        assert!(store.report().shards > 1, "expected rotation, got 1 shard");
        let mut n = 0;
        store.read_all(|_| {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 3000);
        store.cleanup().unwrap();
    }

    #[test]
    fn empty_store() {
        let mut store = SpillStore::create(dir("e"), false).unwrap();
        store.finish_writes().unwrap();
        assert_eq!(store.report().shards, 0);
        store.read_all(|_| panic!("no data")).unwrap();
        store.cleanup().unwrap();
    }
}
