//! Sharded on-disk subgraph store for the offline (GraphGen) baseline.
//!
//! The encoder is **double-buffered** the same way the wave lanes work:
//! the generation thread appends records into the active shard buffer,
//! and a full shard is handed to a background flusher that compresses and
//! writes it while the foreground fills the swapped-in spare — so the
//! offline engine's spill no longer serializes disk writes against the
//! wave loop. Shards keep their admission order (single FIFO flusher),
//! so the on-disk layout — and every read-back — is byte-identical to
//! the synchronous encoder's.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::OnceLock;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::sampler::Subgraph;

/// Shard target size before rotation (pre-compression).
const SHARD_BYTES: usize = 4 << 20;

/// Read-side readahead ring depth: decoded shards the prefetch thread may
/// queue ahead of the consumer. Depth 1 is the classic double buffer;
/// the default of 2 rides out one slow read (a compressed shard that
/// inflates long, a cold page) without starving the consumer, at a bounded
/// cost of `window × ~4 MiB` in-flight memory. `GG_SPILL_READAHEAD`
/// overrides, clamped to `1..=16`.
fn readahead_window() -> u32 {
    static W: OnceLock<u32> = OnceLock::new();
    *W.get_or_init(|| {
        std::env::var("GG_SPILL_READAHEAD")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
            .map(|v| v.clamp(1, 16))
            .unwrap_or(2)
    })
}

/// I/O accounting for one store lifetime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpillReport {
    pub subgraphs: u64,
    pub shards: u32,
    /// Logical (uncompressed) bytes.
    pub logical_bytes: u64,
    /// Bytes on disk (after optional compression).
    pub disk_bytes: u64,
    /// Foreground time: encoding records plus handing shards off.
    pub write_time: Duration,
    /// Background time the flusher spent compressing + writing shards
    /// (overlaps the wave loop; compare against `write_time` to see the
    /// disk work the double buffer hid).
    pub flush_time: Duration,
    /// Foreground time blocked handing a shard to a still-busy flusher —
    /// the encoder's own backpressure bubble. 0 = flushes fully hidden.
    pub flush_wait: Duration,
    /// Shards handed to the background flusher (compress+write runs off
    /// the wave loop while the foreground keeps encoding).
    pub overlapped_flushes: u32,
    pub read_time: Duration,
    /// Consumer-side time blocked waiting on the prefetch reader during
    /// read-back — the read bubble. 0 = reads fully hidden behind the
    /// previous shard's consumption.
    pub read_wait: Duration,
    /// Shards that were already read+inflated (delivered near-instantly)
    /// when the consumer asked — i.e. prefetches that genuinely hid the
    /// disk work behind the previous shard's consumption. A consumer
    /// faster than the disk legitimately reports 0 here with all the
    /// latency showing up in `read_wait` instead.
    pub overlapped_reads: u32,
    /// Readahead ring depth used for read-back (see [`readahead_window`]).
    pub readahead_window: u32,
    /// Most decoded shards ever queued ahead of the consumer (≤ window).
    /// Hitting the window means the disk ran ahead of the consumer and the
    /// ring, not the reader, was the bound.
    pub readahead_peak: u32,
    /// Mean ring occupancy sampled at each consumer request (latest
    /// read-back pass). Near 0 = consumer starved by the disk; near the
    /// window = disk fully hidden.
    pub readahead_mean: f64,
}

impl SpillReport {
    /// JSON view for the unified report writer
    /// ([`crate::obs::report::write_json`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("subgraphs", self.subgraphs)
            .set("shards", self.shards as u64)
            .set("logical_bytes", self.logical_bytes)
            .set("disk_bytes", self.disk_bytes)
            .set("write_time_s", self.write_time.as_secs_f64())
            .set("flush_time_s", self.flush_time.as_secs_f64())
            .set("flush_wait_s", self.flush_wait.as_secs_f64())
            .set("overlapped_flushes", self.overlapped_flushes as u64)
            .set("read_time_s", self.read_time.as_secs_f64())
            .set("read_wait_s", self.read_wait.as_secs_f64())
            .set("overlapped_reads", self.overlapped_reads as u64)
            .set("readahead_window", self.readahead_window as u64)
            .set("readahead_peak", self.readahead_peak as u64)
            .set("readahead_mean", self.readahead_mean);
        j
    }
}

/// One shard handed to the background flusher.
struct ShardJob {
    idx: u32,
    count: u32,
    buf: Vec<u8>,
}

/// What the flusher reports back at join time.
#[derive(Default)]
struct FlushOutcome {
    disk_bytes: u64,
    flush_time: Duration,
    flushed: u32,
}

struct Flusher {
    tx: Option<SyncSender<ShardJob>>,
    /// Drained buffers come back here for reuse (bounded ring).
    spare_rx: Receiver<Vec<u8>>,
    /// Shards handed to this flusher (checked against its outcome).
    sent: u32,
    handle: Option<JoinHandle<Result<FlushOutcome>>>,
}

/// Writer/reader for sharded subgraph spill files.
///
/// Format per shard: `u32` subgraph count, then concatenated
/// [`Subgraph::encode_into`] records; optionally the whole shard is
/// deflate-compressed (`.z` suffix).
pub struct SpillStore {
    dir: PathBuf,
    compress: bool,
    // write state
    buf: Vec<u8>,
    buf_count: u32,
    flusher: Option<Flusher>,
    report: SpillReport,
}

impl SpillStore {
    /// Create (and wipe) a spill directory.
    pub fn create(dir: PathBuf, compress: bool) -> Result<Self> {
        if dir.exists() {
            std::fs::remove_dir_all(&dir).with_context(|| format!("wipe {}", dir.display()))?;
        }
        std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;
        Ok(Self {
            dir,
            compress,
            buf: Vec::with_capacity(SHARD_BYTES + 4096),
            buf_count: 0,
            flusher: None,
            report: SpillReport::default(),
        })
    }

    /// Append one subgraph (buffered; shards rotate at ~4 MiB and flush
    /// in the background).
    pub fn write(&mut self, sg: &Subgraph) -> Result<()> {
        let t0 = Instant::now();
        sg.encode_into(&mut self.buf);
        self.buf_count += 1;
        self.report.subgraphs += 1;
        if self.buf.len() >= SHARD_BYTES {
            self.hand_off_shard()?;
        }
        self.report.write_time += t0.elapsed();
        Ok(())
    }

    fn shard_path(dir: &std::path::Path, compress: bool, idx: u32) -> PathBuf {
        let ext = if compress { "sg.z" } else { "sg" };
        dir.join(format!("shard-{idx:05}.{ext}"))
    }

    /// Compress + write one shard to disk (runs on the flusher thread).
    fn write_shard(dir: &std::path::Path, compress: bool, job: &ShardJob) -> Result<u64> {
        let path = Self::shard_path(dir, compress, job.idx);
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(&job.count.to_le_bytes())?;
        if compress {
            let mut enc = flate2::write::DeflateEncoder::new(w, flate2::Compression::fast());
            enc.write_all(&job.buf)?;
            enc.finish()?.flush()?;
        } else {
            w.write_all(&job.buf)?;
            w.flush()?;
        }
        Ok(std::fs::metadata(&path)?.len())
    }

    fn spawn_flusher(dir: PathBuf, compress: bool) -> Flusher {
        // Depth 1 = the double buffer: one shard in flight behind the one
        // being filled. A second hand-off blocks (`flush_wait`) until the
        // in-flight shard hits disk — bounded memory, in-order layout.
        let (tx, rx) = sync_channel::<ShardJob>(1);
        let (spare_tx, spare_rx) = sync_channel::<Vec<u8>>(2);
        let handle = std::thread::Builder::new()
            .name("gg-spill-flush".into())
            .spawn(move || -> Result<FlushOutcome> {
                crate::obs::trace::set_track(crate::obs::trace::Track::SpillFlush);
                let mut out = FlushOutcome::default();
                while let Ok(mut job) = rx.recv() {
                    let t0 = Instant::now();
                    let span = crate::obs::trace::span("spill.flush")
                        .arg("shard", job.idx as f64)
                        .arg("bytes", job.buf.len() as f64);
                    out.disk_bytes += Self::write_shard(&dir, compress, &job)?;
                    drop(span);
                    out.flush_time += t0.elapsed();
                    out.flushed += 1;
                    job.buf.clear();
                    // Ring full or foreground gone: drop the buffer.
                    let _ = spare_tx.try_send(job.buf);
                }
                Ok(out)
            })
            .expect("spawn spill flusher");
        Flusher { tx: Some(tx), spare_rx, sent: 0, handle: Some(handle) }
    }

    /// Hand the filled shard buffer to the background flusher, swapping
    /// in a recycled (or fresh) buffer for the foreground to keep
    /// encoding into.
    fn hand_off_shard(&mut self) -> Result<()> {
        if self.buf_count == 0 {
            return Ok(());
        }
        if self.flusher.is_none() {
            self.flusher = Some(Self::spawn_flusher(self.dir.clone(), self.compress));
        }
        let idx = self.report.shards;
        self.report.shards += 1;
        self.report.logical_bytes += self.buf.len() as u64 + 4;
        self.report.overlapped_flushes += 1;
        let flusher = self.flusher.as_mut().expect("flusher just ensured");
        let spare = flusher
            .spare_rx
            .try_recv()
            .unwrap_or_else(|_| Vec::with_capacity(SHARD_BYTES + 4096));
        let buf = std::mem::replace(&mut self.buf, spare);
        let job = ShardJob { idx, count: self.buf_count, buf };
        self.buf_count = 0;
        let tx = flusher.tx.as_ref().expect("flusher channel open");
        let mut flusher_died = false;
        match tx.try_send(job) {
            Ok(()) => flusher.sent += 1,
            Err(TrySendError::Full(job)) => {
                // Previous shard still writing: the double buffer is the
                // bound, so wait here and account the bubble.
                let t0 = Instant::now();
                let span = crate::obs::trace::span("spill.handoff_wait");
                if tx.send(job).is_err() {
                    flusher_died = true;
                } else {
                    flusher.sent += 1;
                }
                drop(span);
                let waited = t0.elapsed();
                self.report.flush_wait += waited;
                crate::obs::trace::instant(
                    "stall.flush_wait",
                    &[("wait_us", waited.as_micros() as f64)],
                );
            }
            Err(TrySendError::Disconnected(_)) => flusher_died = true,
        }
        if flusher_died {
            // The flusher hit an I/O error and exited; surface it.
            self.join_flusher()?;
            anyhow::bail!("spill flusher died before draining all shards");
        }
        Ok(())
    }

    /// Drain and join the flusher, folding its accounting into the report.
    fn join_flusher(&mut self) -> Result<()> {
        let Some(mut flusher) = self.flusher.take() else { return Ok(()) };
        drop(flusher.tx.take());
        let outcome = flusher
            .handle
            .take()
            .expect("flusher handle")
            .join()
            .map_err(|_| anyhow::anyhow!("spill flusher panicked"))??;
        self.report.disk_bytes += outcome.disk_bytes;
        self.report.flush_time += outcome.flush_time;
        anyhow::ensure!(
            outcome.flushed == flusher.sent,
            "spill flusher wrote {} of {} handed-off shards",
            outcome.flushed,
            flusher.sent
        );
        Ok(())
    }

    /// Flush pending writes and quiesce the background flusher; call once
    /// generation finishes (before any read-back).
    pub fn finish_writes(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.hand_off_shard()?;
        self.join_flusher()?;
        self.report.write_time += t0.elapsed();
        Ok(())
    }

    /// Read one shard from disk and inflate it (runs on the prefetch
    /// thread): record count plus the decompressed payload.
    fn read_shard(dir: &std::path::Path, compress: bool, idx: u32) -> Result<(u32, Vec<u8>)> {
        let path = Self::shard_path(dir, compress, idx);
        let mut file = File::open(&path).with_context(|| format!("open {}", path.display()))?;
        let mut count_buf = [0u8; 4];
        file.read_exact(&mut count_buf)?;
        let count = u32::from_le_bytes(count_buf);
        let mut data = Vec::new();
        if compress {
            flate2::read::DeflateDecoder::new(file).read_to_end(&mut data)?;
        } else {
            file.read_to_end(&mut data)?;
        }
        Ok((count, data))
    }

    /// Read every stored subgraph back (in shard order), invoking `f`.
    ///
    /// Read-back generalizes the write path's double buffer to a
    /// **readahead ring**: up to [`readahead_window`] shards are read
    /// **and inflated** on a background prefetch thread while shard `n`'s
    /// records are decoded and consumed here, so disk latency overlaps
    /// the consumer instead of serializing ahead of training — and one
    /// slow read no longer stalls the next request. The bounded channel
    /// caps memory at `window` decoded shards in flight; delivery stays
    /// in shard order, so the record stream is byte-identical to the
    /// serial reader's. `read_wait` accounts the residual consumer-side
    /// blocking; `overlapped_reads` counts shards that were already
    /// decoded when requested; `readahead_peak`/`readahead_mean` record
    /// how full the ring actually ran.
    pub fn read_all(&mut self, mut f: impl FnMut(Subgraph) -> Result<()>) -> Result<()> {
        let t0 = Instant::now();
        let shards = self.report.shards;
        let window = readahead_window();
        self.report.readahead_window = window;
        if shards == 0 {
            self.report.read_time += t0.elapsed();
            return Ok(());
        }
        let dir = self.dir.clone();
        let compress = self.compress;
        // Decoded shards enqueued so far; `sent - consumed` sampled at
        // each request is the ring occupancy. Lives outside the scope so
        // the prefetch thread may borrow it.
        let sent = AtomicU32::new(0);
        let mut peak = 0u32;
        let mut occ_sum = 0u64;
        let result = std::thread::scope(|s| -> Result<()> {
            let (tx, rx) = sync_channel::<Result<(u32, Vec<u8>)>>(window as usize);
            let sent_ref = &sent;
            s.spawn(move || {
                crate::obs::trace::set_track(crate::obs::trace::Track::SpillPrefetch);
                for idx in 0..shards {
                    let span = crate::obs::trace::span("spill.read").arg("shard", idx as f64);
                    let shard = Self::read_shard(&dir, compress, idx);
                    drop(span);
                    let failed = shard.is_err();
                    // Consumer gone (early error downstream) or this
                    // shard failed: either way the prefetcher is done.
                    if tx.send(shard).is_err() || failed {
                        return;
                    }
                    sent_ref.fetch_add(1, Ordering::Release);
                }
            });
            for idx in 0..shards {
                let occ = sent.load(Ordering::Acquire).saturating_sub(idx);
                peak = peak.max(occ);
                occ_sum += occ as u64;
                let wait = Instant::now();
                let shard = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("spill prefetch reader exited early"))?;
                let waited = wait.elapsed();
                self.report.read_wait += waited;
                // A near-instant delivery means the prefetcher had this
                // shard decoded before it was asked for: the disk work
                // was genuinely hidden behind the previous shard's
                // consumption. (The first shard has nothing to hide
                // behind; a blocking recv is the read bubble.)
                if idx > 0 && waited < Duration::from_millis(1) {
                    self.report.overlapped_reads += 1;
                }
                let (count, data) = shard?;
                let mut pos = 0usize;
                for _ in 0..count {
                    f(Subgraph::decode_from(&data, &mut pos)?)?;
                }
                anyhow::ensure!(
                    pos == data.len(),
                    "trailing bytes in {}",
                    Self::shard_path(&self.dir, compress, idx).display()
                );
            }
            Ok(())
        });
        self.report.readahead_peak = self.report.readahead_peak.max(peak);
        self.report.readahead_mean = occ_sum as f64 / shards as f64;
        crate::obs::metrics::gauge("spill.readahead_peak").set(self.report.readahead_peak as f64);
        crate::obs::metrics::gauge("spill.readahead_mean").set(self.report.readahead_mean);
        self.report.read_time += t0.elapsed();
        result
    }

    pub fn report(&self) -> &SpillReport {
        &self.report
    }

    /// Remove the spill directory.
    pub fn cleanup(mut self) -> Result<()> {
        self.join_flusher()?;
        std::fs::remove_dir_all(&self.dir).with_context(|| format!("rm {}", self.dir.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;

    fn sg(seed: NodeId, width: usize) -> Subgraph {
        Subgraph {
            seed,
            hop1: (0..width as NodeId).collect(),
            hop2: (0..width).map(|i| vec![seed + i as NodeId; width]).collect(),
        }
    }

    fn dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ggspill-{tag}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_uncompressed() {
        let mut store = SpillStore::create(dir("u"), false).unwrap();
        let subs: Vec<Subgraph> = (0..500).map(|i| sg(i, 8)).collect();
        for s in &subs {
            store.write(s).unwrap();
        }
        store.finish_writes().unwrap();
        assert_eq!(store.report().subgraphs, 500);
        assert!(store.report().disk_bytes > 0);
        let mut got = Vec::new();
        store.read_all(|s| {
            got.push(s);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, subs);
        store.cleanup().unwrap();
    }

    #[test]
    fn roundtrip_compressed_and_smaller() {
        let subs: Vec<Subgraph> = (0..2000).map(|i| sg(i % 10, 10)).collect();
        let mut plain = SpillStore::create(dir("p"), false).unwrap();
        let mut comp = SpillStore::create(dir("c"), true).unwrap();
        for s in &subs {
            plain.write(s).unwrap();
            comp.write(s).unwrap();
        }
        plain.finish_writes().unwrap();
        comp.finish_writes().unwrap();
        assert!(comp.report().disk_bytes < plain.report().disk_bytes);
        let mut got = Vec::new();
        comp.read_all(|s| {
            got.push(s);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, subs);
        plain.cleanup().unwrap();
        comp.cleanup().unwrap();
    }

    #[test]
    fn readahead_gauges_mirror_report() {
        let mut store = SpillStore::create(dir("g"), false).unwrap();
        for i in 0..3000 {
            store.write(&sg(i, 20)).unwrap();
        }
        store.finish_writes().unwrap();
        // The gauges are process-global and the other tests in this
        // module race their own read_all passes against ours — retry
        // until a pass observes its own values un-interleaved (settles
        // as soon as the parallel tests drain).
        let mut ok = false;
        for _ in 0..100 {
            store.read_all(|_| Ok(())).unwrap();
            let peak = crate::obs::metrics::gauge("spill.readahead_peak").get();
            let mean = crate::obs::metrics::gauge("spill.readahead_mean").get();
            if peak == store.report().readahead_peak as f64
                && mean == store.report().readahead_mean
            {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(ok, "readahead gauges never matched this store's report");
        store.cleanup().unwrap();
    }

    #[test]
    fn shard_rotation() {
        let mut store = SpillStore::create(dir("r"), false).unwrap();
        // Each subgraph ~ (1+64)*... make them chunky to force >1 shard.
        for i in 0..3000 {
            store.write(&sg(i, 20)).unwrap();
        }
        store.finish_writes().unwrap();
        assert!(store.report().shards > 1, "expected rotation, got 1 shard");
        // Every shard went through the background flusher, in order.
        assert_eq!(store.report().overlapped_flushes, store.report().shards);
        assert!(store.report().flush_time > Duration::ZERO);
        let mut n = 0;
        let mut prev_seed = None::<NodeId>;
        store.read_all(|s| {
            // In-order layout: seeds were written ascending.
            if let Some(p) = prev_seed {
                assert!(s.seed > p, "shard order broken: {p} then {}", s.seed);
            }
            prev_seed = Some(s.seed);
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 3000);
        // A fast consumer may or may not catch the prefetcher in time —
        // only the bound is machine-independent.
        assert!(store.report().overlapped_reads <= store.report().shards - 1);
        store.cleanup().unwrap();
    }

    #[test]
    fn read_prefetch_overlaps_slow_consumer_without_reordering() {
        // A consumer slower than the disk: the prefetcher should have the
        // next shard decoded and waiting, so the consumer's read_wait
        // stays a small fraction of total read time — and the record
        // stream is identical to a fast pass over the same store.
        let subs: Vec<Subgraph> = (0..12000).map(|i| sg(i, 20)).collect();
        let mut store = SpillStore::create(dir("ro"), true).unwrap();
        for s in &subs {
            store.write(s).unwrap();
        }
        store.finish_writes().unwrap();
        assert!(store.report().shards >= 4, "want several shards, got {}", store.report().shards);
        let mut fast = Vec::new();
        store.read_all(|s| {
            fast.push(s);
            Ok(())
        })
        .unwrap();
        let overlapped_before = store.report().overlapped_reads;
        let mut slow = Vec::new();
        let mut seen = 0u32;
        store.read_all(|s| {
            // Sleep a few times per shard's worth of records so the
            // consumer decisively trails the disk: every prefetch must
            // be ready (and counted as overlapped) by the time it's
            // requested.
            seen += 1;
            if seen % 500 == 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
            slow.push(s);
            Ok(())
        })
        .unwrap();
        assert_eq!(fast, subs, "prefetched read-back must preserve the record stream");
        assert_eq!(slow, subs, "overlap must not reorder records");
        assert_eq!(
            store.report().overlapped_reads - overlapped_before,
            store.report().shards - 1,
            "a slow consumer must find every prefetched shard ready: {:?}",
            store.report()
        );
        // The readahead ring ran ahead of the slow consumer: with the
        // default window of 2 the occupancy must have hit the window at
        // least once (and never exceeded it).
        let window = store.report().readahead_window;
        assert!(window >= 1, "window recorded: {:?}", store.report());
        assert!(
            store.report().readahead_peak >= window.min(2),
            "slow consumer should fill the ring: {:?}",
            store.report()
        );
        assert!(store.report().readahead_peak <= window);
        assert!(store.report().readahead_mean > 0.0);
        store.cleanup().unwrap();
    }

    #[test]
    fn read_error_in_consumer_does_not_hang_prefetcher() {
        let mut store = SpillStore::create(dir("rerr"), false).unwrap();
        for i in 0..3000 {
            store.write(&sg(i, 20)).unwrap();
        }
        store.finish_writes().unwrap();
        assert!(store.report().shards > 1);
        let mut n = 0;
        let r = store.read_all(|_| {
            n += 1;
            if n == 10 {
                anyhow::bail!("consumer bailed");
            }
            Ok(())
        });
        assert!(r.is_err(), "consumer error must surface");
        store.cleanup().unwrap();
    }

    #[test]
    fn double_buffer_matches_synchronous_bytes() {
        // The overlapped encoder must produce the exact same shard files
        // as a fully quiesced one: write in two batches with a full
        // quiesce between them, then compare against one streamed pass.
        let subs: Vec<Subgraph> = (0..2500).map(|i| sg(i, 20)).collect();
        let mut streamed = SpillStore::create(dir("db-a"), false).unwrap();
        for s in &subs {
            streamed.write(s).unwrap();
        }
        streamed.finish_writes().unwrap();
        let mut paced = SpillStore::create(dir("db-b"), false).unwrap();
        for s in &subs[..1000] {
            paced.write(s).unwrap();
        }
        // Let the flusher fully drain mid-stream, then continue.
        std::thread::sleep(Duration::from_millis(20));
        for s in &subs[1000..] {
            paced.write(s).unwrap();
        }
        paced.finish_writes().unwrap();
        assert_eq!(streamed.report().shards, paced.report().shards);
        for idx in 0..streamed.report().shards {
            let a = std::fs::read(SpillStore::shard_path(&dir("db-a"), false, idx)).unwrap();
            let b = std::fs::read(SpillStore::shard_path(&dir("db-b"), false, idx)).unwrap();
            assert_eq!(a, b, "shard {idx} bytes differ");
        }
        streamed.cleanup().unwrap();
        paced.cleanup().unwrap();
    }

    #[test]
    fn empty_store() {
        let mut store = SpillStore::create(dir("e"), false).unwrap();
        store.finish_writes().unwrap();
        assert_eq!(store.report().shards, 0);
        store.read_all(|_| panic!("no data")).unwrap();
        store.cleanup().unwrap();
    }
}
