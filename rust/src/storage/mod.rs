//! External subgraph storage — what GraphGen (the offline predecessor)
//! needs and GraphGen+ eliminates.
//!
//! The offline baseline precomputes every subgraph, serializes it to
//! sharded spill files (optionally deflate-compressed), and training later
//! reads the shards back. [`spill::SpillStore`] implements that store and
//! accounts bytes written/read plus wall time, feeding the E5 storage-
//! overhead experiment.

pub mod spill;
pub mod tier;

pub use spill::{SpillReport, SpillStore};
pub use tier::{PageCache, PageStore, PageStoreWriter, TierStats, PAGE_BYTES, PAGE_WORDS};
