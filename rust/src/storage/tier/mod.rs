//! Tiered hot/cold memory: a page-granular compressed cold tier on disk
//! beneath a CLOCK-managed hot tier in RAM.
//!
//! GraphScale's thesis (PAPERS.md) is that separating storage from
//! compute is what unlocks billion-node scale; DistDGL likewise keeps
//! only hot state resident per worker. This module is the storage half
//! of that hierarchy, shared by the out-of-core feature backend
//! ([`crate::featurestore::TieredStore`]) and the paged CSR adjacency
//! ([`crate::graph::csr::Csr::to_paged`]):
//!
//! * [`PageStore`] — the **cold tier**: fixed-target-size row-group
//!   pages of 4-byte words (f32 feature rows stored as bit patterns,
//!   u32 adjacency targets stored natively), deflate-compressed with
//!   the same codec the spill machinery uses, written **once** at load
//!   to an anonymous temp file and read back with positioned reads
//!   (`pread`) into pooled page buffers.
//! * [`PageCache`] — the **hot tier**: a CLOCK-replaced cache of
//!   decompressed pages under a byte budget. Pages are
//!   **promoted on access** (a miss faults the page in from the cold
//!   tier) and **write-once/read-many** — eviction never writes back,
//!   it just drops the buffer onto a freelist for the next fault.
//!
//! Faults are charged to the `tier.fault` span and the
//! `tier.{faults,promotions,evictions,fault_wait_ns}` metrics, and each
//! fault drops a marker on the dedicated
//! [`Track::TierFault`](crate::obs::trace::Track::TierFault) timeline
//! row so Perfetto shows paging stalls next to generation bubbles.
//!
//! The tier is **value-invariant** by construction: deflate is
//! lossless and pages are immutable, so a faulted page is always
//! byte-identical to the one written at load — the property the
//! equivalence tests in `tests/featurestore.rs` pin across memory
//! budgets and thread counts.

use std::fs::File;
use std::io::{Read, Write};
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::fxhash::FxHashMap;

/// Target uncompressed page size in bytes. Row groups are packed up to
/// this size; a single row (a hub's neighbor list, a very wide feature
/// row) larger than the target gets one oversized page of its own.
pub const PAGE_BYTES: usize = 64 * 1024;

/// [`PAGE_BYTES`] in 4-byte words (the cold tier's element unit).
pub const PAGE_WORDS: usize = PAGE_BYTES / 4;

fn faults_counter() -> &'static crate::obs::metrics::Counter {
    static C: OnceLock<crate::obs::metrics::Counter> = OnceLock::new();
    C.get_or_init(|| crate::obs::metrics::counter("tier.faults"))
}

fn promotions_counter() -> &'static crate::obs::metrics::Counter {
    static C: OnceLock<crate::obs::metrics::Counter> = OnceLock::new();
    C.get_or_init(|| crate::obs::metrics::counter("tier.promotions"))
}

fn evictions_counter() -> &'static crate::obs::metrics::Counter {
    static C: OnceLock<crate::obs::metrics::Counter> = OnceLock::new();
    C.get_or_init(|| crate::obs::metrics::counter("tier.evictions"))
}

fn fault_wait_hist() -> &'static crate::obs::metrics::Hist {
    static H: OnceLock<crate::obs::metrics::Hist> = OnceLock::new();
    H.get_or_init(|| crate::obs::metrics::histogram("tier.fault_wait_ns"))
}

/// Location and size of one compressed page in the cold-tier file.
#[derive(Debug, Clone, Copy)]
struct PageMeta {
    offset: u64,
    /// Compressed length in bytes.
    clen: u32,
    /// Uncompressed length in words.
    uwords: u32,
}

/// Write-once cold tier: compressed pages in an anonymous temp file.
///
/// The backing file is unlinked immediately after creation (the handle
/// keeps it alive), so cold-tier storage can never leak past process
/// exit regardless of how the process dies.
#[derive(Debug)]
pub struct PageStore {
    file: File,
    pages: Vec<PageMeta>,
    cold_bytes: u64,
    raw_bytes: u64,
}

/// Sequential page writer (the load-time half of [`PageStore`]).
#[derive(Debug)]
pub struct PageStoreWriter {
    file: File,
    pages: Vec<PageMeta>,
    offset: u64,
    scratch: Vec<u8>,
    raw_bytes: u64,
}

impl PageStoreWriter {
    /// Open a fresh anonymous cold-tier file.
    pub fn create() -> Result<Self> {
        static UNIQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "gg-tier-{}-{}.cold",
            std::process::id(),
            UNIQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("create cold tier {}", path.display()))?;
        // Unlink now; the open handle keeps the data reachable.
        let _ = std::fs::remove_file(&path);
        Ok(Self { file, pages: Vec::new(), offset: 0, scratch: Vec::new(), raw_bytes: 0 })
    }

    /// Compress and append one page of words; returns its page id.
    pub fn push_words(&mut self, words: &[u32]) -> Result<u32> {
        self.scratch.clear();
        let mut enc = flate2::write::DeflateEncoder::new(
            std::mem::take(&mut self.scratch),
            flate2::Compression::fast(),
        );
        for w in words {
            enc.write_all(&w.to_le_bytes())?;
        }
        self.scratch = enc.finish().context("compress cold page")?;
        self.file
            .write_all_at(&self.scratch, self.offset)
            .context("write cold page")?;
        let id = self.pages.len() as u32;
        self.pages.push(PageMeta {
            offset: self.offset,
            clen: self.scratch.len() as u32,
            uwords: words.len() as u32,
        });
        self.offset += self.scratch.len() as u64;
        self.raw_bytes += words.len() as u64 * 4;
        Ok(id)
    }

    /// Freeze into the read-only store.
    pub fn finish(self) -> PageStore {
        PageStore {
            file: self.file,
            pages: self.pages,
            cold_bytes: self.offset,
            raw_bytes: self.raw_bytes,
        }
    }
}

thread_local! {
    /// Per-thread compressed-read scratch, reused across faults so the
    /// steady-state fault path allocates nothing once warm.
    static READ_SCRATCH: std::cell::RefCell<Vec<u8>> = const { std::cell::RefCell::new(Vec::new()) };
}

impl PageStore {
    pub fn num_pages(&self) -> usize {
        self.pages.len()
    }

    /// Uncompressed size of page `id` in words.
    pub fn page_words(&self, id: u32) -> usize {
        self.pages[id as usize].uwords as usize
    }

    /// Compressed bytes on disk across all pages.
    pub fn cold_bytes(&self) -> u64 {
        self.cold_bytes
    }

    /// Uncompressed bytes across all pages.
    pub fn raw_bytes(&self) -> u64 {
        self.raw_bytes
    }

    /// Fault page `id` from the cold tier: positioned read of the
    /// compressed bytes, inflate, decode into `out` (cleared first).
    /// Charged to the `tier.fault` span / metrics by [`PageCache`]; this
    /// raw read is also usable directly (tests, prefetchers).
    pub fn read_page_into(&self, id: u32, out: &mut Vec<u32>) -> Result<()> {
        let meta = self.pages[id as usize];
        READ_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.resize(meta.clen as usize, 0);
            self.file
                .read_exact_at(&mut scratch, meta.offset)
                .context("read cold page")?;
            out.clear();
            out.reserve(meta.uwords as usize);
            let mut dec = flate2::read::DeflateDecoder::new(&scratch[..]);
            let mut word = [0u8; 4];
            for _ in 0..meta.uwords {
                dec.read_exact(&mut word).context("inflate cold page")?;
                out.push(u32::from_le_bytes(word));
            }
            Ok(())
        })
    }
}

/// Point-in-time hot-tier counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Hot-tier hits (page already resident).
    pub hits: u64,
    /// Cold-tier faults (page read + decompressed).
    pub faults: u64,
    /// Pages promoted into the hot tier (≤ faults: racing faults for
    /// the same page promote once).
    pub promotions: u64,
    /// Pages evicted by the CLOCK sweep (never written back — the cold
    /// tier is write-once).
    pub evictions: u64,
}

impl TierStats {
    /// Faults per access (0 when the tier was never touched).
    pub fn fault_rate(&self) -> f64 {
        let total = self.hits + self.faults;
        if total == 0 {
            0.0
        } else {
            self.faults as f64 / total as f64
        }
    }
}

struct CacheInner {
    map: FxHashMap<u32, u32>,
    /// Slot → page id, parallel to `refbit` and `slots`.
    page_of: Vec<u32>,
    refbit: Vec<bool>,
    slots: Vec<Arc<Vec<u32>>>,
    hand: usize,
    /// Reclaimed page buffers (pooled: eviction feeds the next fault).
    freelist: Vec<Vec<u32>>,
}

/// CLOCK-replaced hot tier over a [`PageStore`].
///
/// Readers hold pages by `Arc`, so a page a gather is still copying out
/// of survives its own eviction; the buffer returns to the freelist
/// when the last reader drops it (or is simply freed).
pub struct PageCache {
    cap: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    faults: AtomicU64,
    promotions: AtomicU64,
    evictions: AtomicU64,
}

impl std::fmt::Debug for PageCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageCache")
            .field("cap", &self.cap)
            .field("stats", &self.stats())
            .finish()
    }
}

impl PageCache {
    /// Cache holding at most `cap_pages` resident pages.
    pub fn new(cap_pages: usize) -> Self {
        Self {
            cap: cap_pages.max(1),
            inner: Mutex::new(CacheInner {
                map: FxHashMap::default(),
                page_of: Vec::new(),
                refbit: Vec::new(),
                slots: Vec::new(),
                hand: 0,
                freelist: Vec::new(),
            }),
            hits: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Size by byte budget: `budget_bytes / PAGE_BYTES` resident pages,
    /// clamped to `[1, total_pages]`. A budget of 0 means **unlimited**
    /// (every page may stay resident — the in-memory baseline).
    pub fn with_budget(budget_bytes: u64, total_pages: usize) -> Self {
        let total = total_pages.max(1);
        let cap = if budget_bytes == 0 {
            total
        } else {
            ((budget_bytes / PAGE_BYTES as u64) as usize).clamp(1, total)
        };
        Self::new(cap)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            faults: self.faults.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Resident bytes currently pinned by the hot tier.
    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.slots.iter().map(|s| s.len() as u64 * 4).sum()
    }

    /// Get page `page`, faulting it in from `store` on a miss
    /// (promotion-on-access). The fault's read+decompress runs **outside**
    /// the cache lock, so concurrent faults for different pages overlap;
    /// a racing fault for the same page is detected at insert and the
    /// duplicate decompress is simply discarded (pages are immutable, so
    /// either copy is correct).
    pub fn get(&self, page: u32, store: &PageStore) -> Result<Arc<Vec<u32>>> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(&slot) = inner.map.get(&page) {
                let s = slot as usize;
                inner.refbit[s] = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(inner.slots[s].clone());
            }
        }
        // Cold-tier fault: pooled buffer, positioned read, inflate.
        let t0 = Instant::now();
        let _span = crate::obs::trace::span("tier.fault").arg("page", page as f64);
        let mut buf = {
            let mut inner = self.inner.lock().unwrap();
            inner.freelist.pop().unwrap_or_default()
        };
        store.read_page_into(page, &mut buf)?;
        let wait_ns = t0.elapsed().as_nanos() as u64;
        self.faults.fetch_add(1, Ordering::Relaxed);
        faults_counter().inc();
        fault_wait_hist().record_ns(wait_ns);
        crate::obs::trace::instant_on(
            crate::obs::trace::Track::TierFault,
            "tier.fault",
            &[("page", page as f64), ("wait_us", wait_ns as f64 / 1e3)],
        );
        let arc = Arc::new(buf);
        let mut inner = self.inner.lock().unwrap();
        if let Some(&slot) = inner.map.get(&page) {
            // A racing fault promoted this page while we decompressed;
            // keep the resident copy, reclaim ours.
            let s = slot as usize;
            inner.refbit[s] = true;
            let resident = inner.slots[s].clone();
            if let Ok(buf) = Arc::try_unwrap(arc) {
                inner.freelist.push(buf);
            }
            return Ok(resident);
        }
        self.promotions.fetch_add(1, Ordering::Relaxed);
        promotions_counter().inc();
        if inner.slots.len() < self.cap {
            let s = inner.slots.len();
            inner.page_of.push(page);
            inner.refbit.push(true);
            inner.slots.push(arc.clone());
            inner.map.insert(page, s as u32);
        } else {
            let s = Self::evict(&mut inner, self.cap);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            evictions_counter().inc();
            inner.page_of[s] = page;
            inner.refbit[s] = true;
            let old = std::mem::replace(&mut inner.slots[s], arc.clone());
            // Reclaim the victim's buffer if no reader still holds it.
            if let Ok(buf) = Arc::try_unwrap(old) {
                inner.freelist.push(buf);
            }
            inner.map.insert(page, s as u32);
        }
        Ok(arc)
    }

    /// CLOCK sweep: advance the hand clearing reference bits until an
    /// unreferenced victim is found (terminates within two sweeps).
    fn evict(inner: &mut CacheInner, cap: usize) -> usize {
        loop {
            let s = inner.hand;
            inner.hand = (inner.hand + 1) % cap;
            if inner.refbit[s] {
                inner.refbit[s] = false;
            } else {
                let old = inner.page_of[s];
                inner.map.remove(&old);
                return s;
            }
        }
    }
}

/// Effective memory budget in MiB: the config value when set, else the
/// `GG_MEMORY_BUDGET_MB` environment opt-in, else 0 (unlimited —
/// everything stays resident, the pre-tier behaviour).
pub fn memory_budget_mb(config_mb: usize) -> usize {
    if config_mb > 0 {
        return config_mb;
    }
    std::env::var("GG_MEMORY_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with_pages(pages: &[Vec<u32>]) -> PageStore {
        let mut w = PageStoreWriter::create().unwrap();
        for p in pages {
            w.push_words(p).unwrap();
        }
        w.finish()
    }

    #[test]
    fn pages_roundtrip_byte_identically() {
        let pages: Vec<Vec<u32>> = (0..5u32)
            .map(|p| (0..100 + p * 37).map(|i| i.wrapping_mul(0x9e37_79b9) ^ p).collect())
            .collect();
        let store = store_with_pages(&pages);
        assert_eq!(store.num_pages(), 5);
        assert!(store.cold_bytes() > 0);
        let mut buf = Vec::new();
        for (i, expect) in pages.iter().enumerate() {
            store.read_page_into(i as u32, &mut buf).unwrap();
            assert_eq!(&buf, expect, "page {i} changed through the cold tier");
        }
        // Repeated and out-of-order reads stay identical (pread is
        // stateless).
        store.read_page_into(3, &mut buf).unwrap();
        assert_eq!(&buf, &pages[3]);
        store.read_page_into(0, &mut buf).unwrap();
        assert_eq!(&buf, &pages[0]);
    }

    #[test]
    fn float_bit_patterns_survive_the_tier() {
        let rows: Vec<u32> = [1.5f32, -0.0, 3.25e-30, f32::MIN_POSITIVE, 7.0e30]
            .iter()
            .map(|f| f.to_bits())
            .collect();
        let store = store_with_pages(&[rows.clone()]);
        let mut buf = Vec::new();
        store.read_page_into(0, &mut buf).unwrap();
        let back: Vec<f32> = buf.iter().map(|&w| f32::from_bits(w)).collect();
        let orig: Vec<f32> = rows.iter().map(|&w| f32::from_bits(w)).collect();
        assert_eq!(back, orig);
    }

    #[test]
    fn cache_promotes_hits_and_evicts_under_budget() {
        let pages: Vec<Vec<u32>> = (0..6u32).map(|p| vec![p; 64]).collect();
        let store = store_with_pages(&pages);
        let cache = PageCache::new(2);
        // First touch of each page faults + promotes.
        for p in 0..4u32 {
            let got = cache.get(p, &store).unwrap();
            assert_eq!(&*got, &pages[p as usize]);
        }
        let s = cache.stats();
        assert_eq!(s.faults, 4);
        assert_eq!(s.promotions, 4);
        assert_eq!(s.evictions, 2, "capacity 2 must evict to admit pages 3 and 4");
        // Page 3 was just promoted: a re-read is a hit.
        cache.get(3, &store).unwrap();
        assert_eq!(cache.stats().hits, 1);
        assert!(cache.stats().fault_rate() > 0.0 && cache.stats().fault_rate() < 1.0);
    }

    #[test]
    fn evicted_page_refaults_to_identical_bytes() {
        let pages: Vec<Vec<u32>> = (0..3u32)
            .map(|p| (0..500u32).map(|i| i.wrapping_mul(p + 11)).collect())
            .collect();
        let store = store_with_pages(&pages);
        let cache = PageCache::new(1);
        let first = cache.get(0, &store).unwrap().to_vec();
        cache.get(1, &store).unwrap(); // evicts 0
        cache.get(2, &store).unwrap(); // evicts 1
        assert!(cache.stats().evictions >= 2);
        let again = cache.get(0, &store).unwrap(); // re-fault
        assert_eq!(&*again, &first, "promoted-then-evicted page changed on re-fault");
    }

    #[test]
    fn with_budget_sizes_and_zero_means_unlimited() {
        assert_eq!(PageCache::with_budget(0, 100).capacity(), 100);
        assert_eq!(PageCache::with_budget(PAGE_BYTES as u64 * 7, 100).capacity(), 7);
        assert_eq!(PageCache::with_budget(1, 100).capacity(), 1, "tiny budget clamps to one page");
        assert_eq!(PageCache::with_budget(u64::MAX, 10).capacity(), 10, "cap never exceeds pages");
    }

    #[test]
    fn concurrent_readers_see_identical_pages() {
        let pages: Vec<Vec<u32>> = (0..8u32)
            .map(|p| (0..PAGE_WORDS as u32 / 8).map(|i| i ^ (p << 20)).collect())
            .collect();
        let store = store_with_pages(&pages);
        let cache = PageCache::new(2); // far below working set: constant churn
        std::thread::scope(|s| {
            for t in 0..4 {
                let (store, cache, pages) = (&store, &cache, &pages);
                s.spawn(move || {
                    for i in 0..64u32 {
                        let p = (i * (t + 1)) % 8;
                        let got = cache.get(p, store).unwrap();
                        assert_eq!(&*got, &pages[p as usize], "thread {t} page {p}");
                    }
                });
            }
        });
        assert!(cache.stats().evictions > 0);
    }

    #[test]
    fn budget_env_fallback() {
        // Config value wins outright; only 0 consults the environment.
        assert_eq!(memory_budget_mb(64), 64);
        // (The env branch is exercised by CI's GG_MEMORY_BUDGET_MB re-run;
        // don't mutate process env here — tests share the process.)
    }
}
