//! Sharded feature-store subsystem.
//!
//! Industrial GNN training fetches features from a feature store, and that
//! feature movement — not subgraph topology — dominates cross-worker
//! traffic at production scale. The seed modeled the store with a purely
//! procedural stand-in ([`crate::graph::features::FeatureStore`]), which
//! means feature bytes never crossed the simulated fabric at all. This
//! module makes feature placement and movement first-class:
//!
//! * [`FeatureBackend`] — the storage abstraction. Three implementations:
//!   the procedural store (replicated everywhere, zero traffic),
//!   [`ShardedStore`] ([`sharded`]) — dense partition-aligned shards
//!   materialized from the procedural source, byte-identical rows, but
//!   with per-row ownership so remote reads are chargeable — and
//!   [`TieredStore`] ([`tiered`]) — the same rows out-of-core, in
//!   compressed cold-tier pages under a CLOCK hot tier sized by
//!   `--memory-budget-mb`.
//! * [`fetch`] — the batched fetch planner: deduplicate a batch's node
//!   ids, split local vs remote, group remote ids by owner partition and
//!   issue **one bulk gather per (requester, owner) pair**, charging every
//!   remote byte to a [`crate::cluster::Fabric`].
//! * [`cache`] — a CLOCK hot-node cache seeded from high-degree nodes,
//!   with hit/miss/eviction counters.
//! * [`prefetch`] — overlaps the feature gather for batch *t+1* with
//!   training on batch *t* inside the concurrent pipeline.
//!
//! [`FeatureService`] composes backend + cache + fabric accounting and is
//! what the trainer, evaluator and pipeline driver consume. Backend choice
//! is invisible to training: all backends return byte-identical rows
//! (property-tested in `tests/featurestore.rs`), so the loss curve is
//! independent of feature placement — only the traffic accounting and
//! gather latency change. The E7 benchmark (`benches/e7_featurestore.rs`)
//! measures exactly that.

pub mod cache;
pub mod fetch;
pub mod prefetch;
pub mod sharded;
pub mod tiered;

pub use cache::{CacheStats, HotCache};
pub use fetch::{FetchPlan, FetchStats, Gathered};
pub use prefetch::{spawn_prefetcher, BatchFeed, WaveWarmer};
pub use sharded::ShardedStore;
pub use tiered::TieredStore;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cluster::{Fabric, FabricStats};
use crate::graph::features::FeatureStore;
use crate::graph::NodeId;
use crate::sampler::Subgraph;
use crate::train::meta::ModelSpec;
use crate::train::runtime::HostBatch;
use crate::util::fxhash::FxHashMap;

/// A feature/label storage backend.
///
/// Rows are `dim` f32s per node; labels are class ids. Implementations
/// must be deterministic: the same node always yields the same bytes, so
/// backends are interchangeable under training (the equivalence the
/// integration tests assert).
pub trait FeatureBackend: Send + Sync {
    fn dim(&self) -> usize;

    fn num_classes(&self) -> u32;

    fn label(&self, v: NodeId) -> u32;

    /// Write the feature row of `v` into `out` (len == dim).
    fn write_feature(&self, v: NodeId, out: &mut [f32]);

    /// Bulk row gather: writes the rows of `ids`, in order, contiguously
    /// into `out` (`ids.len() * dim` floats). Hot paths use this instead
    /// of per-node calls; backends override it when rows can be copied
    /// without per-row recomputation.
    fn gather_into(&self, ids: &[NodeId], out: &mut [f32]) {
        let d = self.dim();
        assert_eq!(out.len(), ids.len() * d, "gather buffer size mismatch");
        for (i, &v) in ids.iter().enumerate() {
            self.write_feature(v, &mut out[i * d..(i + 1) * d]);
        }
    }

    /// [`gather_into`](Self::gather_into) under an explicit worker-thread
    /// budget (see [`FeatureService::with_threads`]): a parallel backend
    /// must fan out over at most `threads` pool workers so gathers stop
    /// competing with generation hop scans for the whole pool. The
    /// default ignores the budget — serial backends have nothing to cap.
    /// Bytes written are identical at every budget.
    fn gather_into_budget(&self, ids: &[NodeId], out: &mut [f32], threads: usize) {
        let _ = threads;
        self.gather_into(ids, out)
    }

    /// Partition owning `v`'s row, or `None` when the row is computable
    /// locally on every worker (the procedural store) — such reads are
    /// never charged as traffic.
    fn owner_of(&self, _v: NodeId) -> Option<u32> {
        None
    }

    /// Number of partitions rows are sharded over (1 = unsharded).
    fn partitions(&self) -> usize {
        1
    }
}

/// The procedural store is a degenerate backend: every worker computes
/// identical rows locally, so nothing is ever remote.
impl FeatureBackend for FeatureStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_classes(&self) -> u32 {
        self.num_classes
    }

    fn label(&self, v: NodeId) -> u32 {
        // Method-call syntax resolves to the inherent method.
        FeatureStore::label(self, v)
    }

    fn write_feature(&self, v: NodeId, out: &mut [f32]) {
        FeatureStore::write_feature(self, v, out)
    }

    fn gather_into(&self, ids: &[NodeId], out: &mut [f32]) {
        FeatureStore::gather_into(self, ids, out)
    }
}

/// Backend selector for config / CLI (`--feature-backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Replicated procedural computation (the seed behaviour; no traffic).
    Procedural,
    /// Partition-aligned dense shards with remote-byte accounting.
    Sharded,
    /// Out-of-core shards: compressed cold-tier pages under a CLOCK hot
    /// tier sized by `--memory-budget-mb` (see [`TieredStore`]).
    Tiered,
}

impl std::str::FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "procedural" => Ok(Self::Procedural),
            "sharded" => Ok(Self::Sharded),
            "tiered" => Ok(Self::Tiered),
            other => Err(format!("unknown feature backend '{other}'")),
        }
    }
}

#[derive(Debug, Default)]
struct Counters {
    requested: AtomicU64,
    unique: AtomicU64,
    cache_hits: AtomicU64,
    local_rows: AtomicU64,
    remote_rows: AtomicU64,
    remote_bytes: AtomicU64,
    remote_msgs: AtomicU64,
    gathers: AtomicU64,
}

impl Counters {
    fn add(&self, s: &FetchStats) {
        self.requested.fetch_add(s.requested, Ordering::Relaxed);
        self.unique.fetch_add(s.unique, Ordering::Relaxed);
        self.cache_hits.fetch_add(s.cache_hits, Ordering::Relaxed);
        self.local_rows.fetch_add(s.local_rows, Ordering::Relaxed);
        self.remote_rows.fetch_add(s.remote_rows, Ordering::Relaxed);
        self.remote_bytes.fetch_add(s.remote_bytes, Ordering::Relaxed);
        self.remote_msgs.fetch_add(s.remote_msgs, Ordering::Relaxed);
        self.gathers.fetch_add(s.gathers, Ordering::Relaxed);
    }

    fn snapshot(&self) -> FetchStats {
        FetchStats {
            requested: self.requested.load(Ordering::Relaxed),
            unique: self.unique.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            local_rows: self.local_rows.load(Ordering::Relaxed),
            remote_rows: self.remote_rows.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
            remote_msgs: self.remote_msgs.load(Ordering::Relaxed),
            gathers: self.gathers.load(Ordering::Relaxed),
        }
    }
}

/// Shared feature-access front end: backend + optional hot-node cache +
/// fabric accounting. One service is shared by all training replicas
/// (it is `Sync`); per-gather work is lock-free except the cache.
pub struct FeatureService {
    backend: Arc<dyn FeatureBackend>,
    cache: Option<Mutex<HotCache>>,
    fabric: Fabric,
    counters: Counters,
    /// Worker-thread budget for gather fan-outs (scatter + bulk copies).
    gather_threads: usize,
    /// Reset-don't-free pool for assembled batches and id scratch.
    batches: crate::train::batch::BatchArena,
}

impl FeatureService {
    pub fn new(backend: Arc<dyn FeatureBackend>) -> Self {
        let parts = backend.partitions().max(1);
        Self {
            backend,
            cache: None,
            fabric: Fabric::new(parts),
            counters: Counters::default(),
            gather_threads: crate::util::workpool::default_threads(),
            batches: crate::train::batch::BatchArena::default(),
        }
    }

    /// Convenience constructor for the replicated procedural backend.
    pub fn procedural(store: FeatureStore) -> Self {
        Self::new(Arc::new(store))
    }

    /// Attach a hot-node cache (builder style).
    pub fn with_cache(mut self, cache: HotCache) -> Self {
        assert_eq!(cache.dim(), self.backend.dim(), "cache dim mismatch");
        self.cache = Some(Mutex::new(cache));
        self
    }

    /// Cap the pool share feature gathers may claim (builder style). The
    /// concurrent pipeline splits the machine between generation scans
    /// and gathers ([`crate::pipeline::split_pool_budget`]) so the two
    /// stop fighting over the same workers; gathered bytes are identical
    /// at every budget.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.gather_threads = threads.max(1);
        self
    }

    /// The gather-side worker budget currently in force.
    pub fn gather_threads(&self) -> usize {
        self.gather_threads
    }

    pub fn backend(&self) -> &dyn FeatureBackend {
        &*self.backend
    }

    pub fn dim(&self) -> usize {
        self.backend.dim()
    }

    pub fn num_classes(&self) -> u32 {
        self.backend.num_classes()
    }

    pub fn label(&self, v: NodeId) -> u32 {
        self.backend.label(v)
    }

    /// The fabric feature traffic is charged on (`partitions()` workers).
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    pub fn fabric_stats(&self) -> FabricStats {
        self.fabric.stats()
    }

    /// Cumulative fetch counters since construction (or the last
    /// [`Fabric::reset`]-style comparison via [`FetchStats::delta`]).
    pub fn stats(&self) -> FetchStats {
        self.counters.snapshot()
    }

    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.lock().unwrap().stats().clone())
    }

    /// Whether a hot-node cache is attached (cheap; no lock).
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Return a consumed batch's buffers for reuse by later
    /// [`materialize`](Self::materialize) calls (the trainer calls this
    /// after each gradient step).
    pub fn release_batch(&self, b: HostBatch) {
        self.batches.release(b);
    }

    /// Declare batch warm-up over, stocking `slack` spare shaped batches
    /// (see [`crate::train::batch::BatchArena::mark_warm`]).
    pub fn mark_batches_warm(&self, spec: ModelSpec, slack: usize) {
        self.batches.mark_warm(spec, slack);
    }

    /// Batch-buffer reuse counters since construction.
    pub fn batch_reuse(&self) -> crate::train::batch::BatchReuse {
        self.batches.stats()
    }

    /// Pre-populate the cache with `ids` (the graph's highest-degree
    /// nodes, or a whole generation wave's unique nodes — see
    /// [`prefetch::WaveWarmer`]). No-op without a cache; warming counts as
    /// insertions, not hits or misses. Missing rows are gathered in one
    /// bulk call — which fans out over the work pool for wave-sized id
    /// sets — and inserted under a single cache lock.
    pub fn warm_cache(&self, ids: &[NodeId]) {
        let Some(cache) = &self.cache else { return };
        let _span = crate::obs::trace::span("cache.warm").arg("ids", ids.len() as f64);
        let d = self.backend.dim();
        let mut missing: Vec<NodeId> = {
            let c = cache.lock().unwrap();
            // Never warm more than half the cache per call: an id set
            // larger than the cache would cycle the whole CLOCK ring,
            // evicting rows earlier warms inserted for batches that
            // haven't trained yet — worse than not warming at all. The
            // clamp keeps (at least) half the resident hot set intact;
            // the kept prefix is deterministic (ids arrive sorted).
            let budget = (c.capacity() / 2).max(1);
            ids.iter().copied().filter(|&v| !c.contains(v)).take(budget).collect()
        };
        missing.dedup();
        if missing.is_empty() {
            return;
        }
        let mut rows = vec![0.0f32; missing.len() * d];
        self.backend.gather_into_budget(&missing, &mut rows, self.gather_threads);
        let mut c = cache.lock().unwrap();
        for (j, &v) in missing.iter().enumerate() {
            if !c.contains(v) {
                c.insert(v, &rows[j * d..(j + 1) * d], self.backend.label(v));
            }
        }
    }

    /// Gather the rows of `ids` (duplicates welcome — they are fetched
    /// once) on behalf of partition-slot `requester`. Remote rows are
    /// charged to the fabric as one bulk message per owner partition.
    pub fn gather(&self, ids: &[NodeId], requester: u32) -> Gathered {
        let d = self.backend.dim();
        let unique = fetch::dedup_ids(ids);
        let n = unique.len();
        let _span = crate::obs::trace::span("gather")
            .arg("requested", ids.len() as f64)
            .arg("unique", n as f64);
        let mut feats = vec![0.0f32; n * d];
        let mut labels = vec![0u32; n];
        let mut index = FxHashMap::default();
        index.reserve(n);
        for (i, &v) in unique.iter().enumerate() {
            index.insert(v, i as u32);
        }
        let mut stats = FetchStats {
            requested: ids.len() as u64,
            unique: n as u64,
            gathers: 1,
            ..Default::default()
        };
        // 1. Serve what we can from the hot cache.
        let mut missing: Vec<NodeId> = Vec::new();
        if let Some(cache) = &self.cache {
            let mut c = cache.lock().unwrap();
            for (i, &v) in unique.iter().enumerate() {
                if let Some((row, label)) = c.get(v) {
                    feats[i * d..(i + 1) * d].copy_from_slice(row);
                    labels[i] = label;
                    stats.cache_hits += 1;
                } else {
                    missing.push(v);
                }
            }
        } else {
            missing = unique.clone();
        }
        // 2. Plan the misses: local vs one bulk group per remote owner.
        let plan = fetch::plan(&missing, requester, &*self.backend);
        let row_bytes = (d * 4 + 4) as u64; // feature row + label
        stats.local_rows += plan.local.len() as u64;
        for (owner, group) in &plan.remote {
            let bytes = group.len() as u64 * row_bytes;
            stats.remote_rows += group.len() as u64;
            stats.remote_bytes += bytes;
            stats.remote_msgs += 1;
            self.fabric.charge(
                *owner as usize % self.fabric.workers(),
                requester as usize % self.fabric.workers(),
                bytes,
            );
        }
        // One pool-parallel scatter over every missing row, chunked so no
        // job crosses an owner group (the bulk-per-owner fetch shape),
        // capped at the service's gather-thread budget.
        scatter_rows(&*self.backend, &plan, &index, &mut feats, &mut labels, self.gather_threads);
        // 3. Freshly fetched rows become cache candidates.
        if let Some(cache) = &self.cache {
            let mut c = cache.lock().unwrap();
            let fetched = plan.local.iter().chain(plan.remote.iter().flat_map(|(_, g)| g.iter()));
            for &v in fetched {
                let i = index[&v] as usize;
                c.insert(v, &feats[i * d..(i + 1) * d], labels[i]);
            }
        }
        self.counters.add(&stats);
        Gathered { dim: d, index, feats, labels, stats }
    }

    /// Assemble a training batch: collect the batch's node ids, gather
    /// them once (dedup + cache + bulk remote fetch), and fill the fixed
    /// tensor layout from the gathered frame. Byte-identical to
    /// [`crate::train::batch::BatchBuilder::build`] against the backend
    /// directly — only the access pattern (and its accounting) differs.
    pub fn materialize(
        &self,
        spec: ModelSpec,
        subgraphs: &[Subgraph],
        requester: u32,
    ) -> Result<HostBatch> {
        let _span = crate::obs::trace::span("materialize").arg("subgraphs", subgraphs.len() as f64);
        let mut ids = self.batches.acquire_ids();
        fetch::batch_ids_into(spec, subgraphs, &mut ids);
        let frame = self.gather(&ids, requester);
        self.batches.release_ids(ids);
        let fb = FrameBackend { frame: &frame, classes: self.num_classes() };
        let mut out = self.batches.acquire(spec);
        crate::train::batch::BatchBuilder::new(spec, &fb)
            .with_threads(self.gather_threads)
            .build_into(subgraphs, &mut out)?;
        Ok(out)
    }
}

/// Scatter every planned row (local + per-owner remote groups) into the
/// frame positions given by `index`, fanned out over the persistent work
/// pool. Jobs are owner-aligned id chunks; since planned ids are unique,
/// every frame row is written by exactly one job, so the parallel scatter
/// is write-disjoint and byte-identical to the serial one.
fn scatter_rows(
    backend: &dyn FeatureBackend,
    plan: &FetchPlan,
    index: &FxHashMap<NodeId, u32>,
    feats: &mut [f32],
    labels: &mut [u32],
    threads: usize,
) {
    let d = backend.dim().max(1);
    let groups: Vec<&[NodeId]> = std::iter::once(plan.local.as_slice())
        .chain(plan.remote.iter().map(|(_, g)| g.as_slice()))
        .filter(|g| !g.is_empty())
        .collect();
    let rows: usize = groups.iter().map(|g| g.len()).sum();
    if rows == 0 {
        return;
    }
    let threads = threads.max(1);
    const PAR_MIN_ROWS: usize = 512;
    if threads <= 1 || rows < PAR_MIN_ROWS {
        for g in groups {
            for &v in g {
                let i = index[&v] as usize;
                backend.write_feature(v, &mut feats[i * d..(i + 1) * d]);
                labels[i] = backend.label(v);
            }
        }
        return;
    }
    let chunk = rows.div_ceil(threads * 4).max(64);
    let mut jobs: Vec<&[NodeId]> = Vec::new();
    for g in groups {
        let mut lo = 0;
        while lo < g.len() {
            let hi = (lo + chunk).min(g.len());
            jobs.push(&g[lo..hi]);
            lo = hi;
        }
    }
    let fp = crate::util::workpool::RawParts(feats.as_mut_ptr());
    let lp = crate::util::workpool::RawParts(labels.as_mut_ptr());
    let (fp, lp) = (&fp, &lp);
    // The gather pool, not the generation pool: pools admit one job at a
    // time, so sharing a pool would serialize this scatter behind hop
    // scans regardless of the thread budget.
    crate::util::workpool::WorkPool::gather_global().run_labeled(
        jobs.len(),
        threads,
        1,
        "gather.scatter",
        |j| {
            for &v in jobs[j] {
                let i = index[&v] as usize;
                // SAFETY: ids are unique across the plan, so frame row `i`
                // is touched by exactly one job; both buffers outlive the
                // (blocking) pool call.
                let row = unsafe { std::slice::from_raw_parts_mut(fp.0.add(i * d), d) };
                backend.write_feature(v, row);
                unsafe { *lp.0.add(i) = backend.label(v) };
            }
        },
    );
}

/// Read-only backend view over an already-gathered frame: batch assembly
/// copies rows out of it without touching the real backend again.
struct FrameBackend<'a> {
    frame: &'a Gathered,
    classes: u32,
}

impl FeatureBackend for FrameBackend<'_> {
    fn dim(&self) -> usize {
        self.frame.dim
    }

    fn num_classes(&self) -> u32 {
        self.classes
    }

    fn label(&self, v: NodeId) -> u32 {
        self.frame.label_of(v)
    }

    fn write_feature(&self, v: NodeId, out: &mut [f32]) {
        out.copy_from_slice(self.frame.row(v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> FeatureStore {
        FeatureStore::with_labels(8, 3, (0..100).map(|i| i % 3).collect(), 11)
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!("procedural".parse::<BackendKind>().unwrap(), BackendKind::Procedural);
        assert_eq!("sharded".parse::<BackendKind>().unwrap(), BackendKind::Sharded);
        assert_eq!("tiered".parse::<BackendKind>().unwrap(), BackendKind::Tiered);
        assert!("csv".parse::<BackendKind>().is_err());
    }

    #[test]
    fn procedural_backend_matches_inherent_api() {
        let fs = store();
        let b: &dyn FeatureBackend = &fs;
        assert_eq!(b.dim(), 8);
        assert_eq!(b.num_classes(), 3);
        for v in [0u32, 7, 42, 99] {
            assert_eq!(b.label(v), fs.label(v));
            let mut via_trait = vec![0.0; 8];
            b.write_feature(v, &mut via_trait);
            assert_eq!(via_trait, fs.feature(v));
            assert_eq!(b.owner_of(v), None);
        }
        assert_eq!(b.partitions(), 1);
    }

    #[test]
    fn gather_dedups_and_indexes_every_id() {
        let svc = FeatureService::procedural(store());
        let ids = [5u32, 3, 5, 5, 7, 3];
        let g = svc.gather(&ids, 0);
        assert_eq!(g.stats.requested, 6);
        assert_eq!(g.stats.unique, 3);
        assert_eq!(g.stats.remote_rows, 0, "procedural is never remote");
        assert_eq!(g.stats.local_rows, 3);
        let fs = store();
        for v in [3u32, 5, 7] {
            assert_eq!(g.row(v), &fs.feature(v)[..]);
            assert_eq!(g.label_of(v), fs.label(v));
        }
        assert_eq!(svc.fabric_stats().total_bytes, 0);
    }

    #[test]
    fn service_counters_accumulate_across_gathers() {
        let svc = FeatureService::procedural(store());
        svc.gather(&[1, 2, 3], 0);
        svc.gather(&[4, 5], 0);
        let s = svc.stats();
        assert_eq!(s.gathers, 2);
        assert_eq!(s.requested, 5);
        assert_eq!(s.unique, 5);
    }

    #[test]
    fn cached_gather_serves_repeats_from_cache() {
        let svc = FeatureService::procedural(store()).with_cache(HotCache::new(16, 8));
        let a = svc.gather(&[1, 2, 3], 0);
        assert_eq!(a.stats.cache_hits, 0);
        let b = svc.gather(&[1, 2, 3, 4], 0);
        assert_eq!(b.stats.cache_hits, 3);
        // Cached rows are byte-identical to fresh ones.
        let fs = store();
        for v in 1..=4u32 {
            assert_eq!(b.row(v), &fs.feature(v)[..]);
        }
        let cs = svc.cache_stats().unwrap();
        assert_eq!(cs.hits, 3);
        assert_eq!(cs.insertions, 4);
    }

    #[test]
    fn gather_thread_budget_is_value_invariant() {
        let wide = FeatureService::procedural(store());
        let narrow = FeatureService::procedural(store()).with_threads(1);
        assert_eq!(narrow.gather_threads(), 1);
        let ids: Vec<u32> = (0..600u32).map(|i| (i * 13) % 100).collect();
        let a = wide.gather(&ids, 0);
        let b = narrow.gather(&ids, 0);
        assert_eq!(a.feats, b.feats, "budget must never change gathered bytes");
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn warm_cache_preloads_rows() {
        let svc = FeatureService::procedural(store()).with_cache(HotCache::new(8, 8));
        svc.warm_cache(&[10, 11]);
        let g = svc.gather(&[10, 11], 0);
        assert_eq!(g.stats.cache_hits, 2);
    }
}
