//! Batched fetch planning: dedup → owner grouping → bulk gather.
//!
//! A 2-hop subgraph batch references the same hub nodes many times (across
//! slots and across subgraphs). Fetching per occurrence — what a naive
//! trainer does — multiplies feature traffic by the duplication factor and
//! pays one round trip per node. The planner instead:
//!
//! 1. deduplicates the batch's node ids,
//! 2. splits them into local rows and remote rows grouped by owner
//!    partition, and
//! 3. issues **one bulk gather per (requester, owner) pair**, so the
//!    fabric sees `#owners` messages instead of `#ids`.
//!
//! The stats produced here are the E7 benchmark's raw material.

use crate::graph::NodeId;
use crate::sampler::Subgraph;
use crate::train::meta::ModelSpec;
use crate::util::fxhash::FxHashMap;

use super::FeatureBackend;

/// Where each requested row must come from.
#[derive(Debug, Clone, Default)]
pub struct FetchPlan {
    /// Rows computable/owned locally by the requester (no traffic).
    pub local: Vec<NodeId>,
    /// Remote rows grouped by owner partition, one bulk gather each.
    /// Sorted by owner for deterministic fabric charging.
    pub remote: Vec<(u32, Vec<NodeId>)>,
}

impl FetchPlan {
    pub fn remote_rows(&self) -> usize {
        self.remote.iter().map(|(_, g)| g.len()).sum()
    }
}

/// Counters for one gather (or, summed, for a whole run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FetchStats {
    /// Ids requested, counting duplicates.
    pub requested: u64,
    /// Distinct ids actually fetched or served.
    pub unique: u64,
    /// Unique ids served by the hot cache.
    pub cache_hits: u64,
    /// Unique ids served locally (owner == requester, or replicated).
    pub local_rows: u64,
    /// Unique ids pulled from a remote partition.
    pub remote_rows: u64,
    /// Bytes charged to the fabric for remote rows.
    pub remote_bytes: u64,
    /// Bulk messages (one per contacted owner partition).
    pub remote_msgs: u64,
    /// Gather operations performed.
    pub gathers: u64,
}

impl FetchStats {
    /// Fraction of unique ids served by the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.unique == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.unique as f64
        }
    }

    /// Dedup leverage: requested occurrences per fetched row.
    pub fn dedup_factor(&self) -> f64 {
        if self.unique == 0 {
            1.0
        } else {
            self.requested as f64 / self.unique as f64
        }
    }

    /// Counter-wise difference vs an earlier snapshot (for per-run
    /// reporting off cumulative service counters).
    pub fn delta(&self, earlier: &FetchStats) -> FetchStats {
        FetchStats {
            requested: self.requested.saturating_sub(earlier.requested),
            unique: self.unique.saturating_sub(earlier.unique),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            local_rows: self.local_rows.saturating_sub(earlier.local_rows),
            remote_rows: self.remote_rows.saturating_sub(earlier.remote_rows),
            remote_bytes: self.remote_bytes.saturating_sub(earlier.remote_bytes),
            remote_msgs: self.remote_msgs.saturating_sub(earlier.remote_msgs),
            gathers: self.gathers.saturating_sub(earlier.gathers),
        }
    }

    pub fn render(&self) -> String {
        use crate::util::bytes::fmt_bytes;
        format!(
            "rows={} unique={} (dedup {:.2}x) cache_hits={} ({:.0}%) remote={} rows / {} / {} msgs",
            self.requested,
            self.unique,
            self.dedup_factor(),
            self.cache_hits,
            self.cache_hit_rate() * 100.0,
            self.remote_rows,
            fmt_bytes(self.remote_bytes),
            self.remote_msgs,
        )
    }
}

/// Sorted, deduplicated copy of `ids`.
pub fn dedup_ids(ids: &[NodeId]) -> Vec<NodeId> {
    let mut out = ids.to_vec();
    out.sort_unstable();
    out.dedup();
    out
}

/// Classify already-unique `ids` for partition-slot `requester`.
pub fn plan(ids: &[NodeId], requester: u32, backend: &dyn FeatureBackend) -> FetchPlan {
    let parts = backend.partitions().max(1) as u32;
    let local_slot = requester % parts;
    let mut local = Vec::new();
    // BTreeMap keeps owner order deterministic.
    let mut groups: std::collections::BTreeMap<u32, Vec<NodeId>> = Default::default();
    for &v in ids {
        match backend.owner_of(v) {
            None => local.push(v),
            Some(o) if o == local_slot => local.push(v),
            Some(o) => groups.entry(o).or_default().push(v),
        }
    }
    FetchPlan { local, remote: groups.into_iter().collect() }
}

/// Every node id a batch's tensor layout will touch, duplicates included,
/// truncated exactly as batch assembly truncates (`f1`/`f2` per hop).
pub fn batch_ids(spec: ModelSpec, subgraphs: &[Subgraph]) -> Vec<NodeId> {
    let mut ids = Vec::new();
    batch_ids_into(spec, subgraphs, &mut ids);
    ids
}

/// [`batch_ids`] into a reusable buffer (cleared first) — the
/// zero-allocation path used with pooled id scratch
/// ([`crate::train::batch::BatchArena::acquire_ids`]).
pub fn batch_ids_into(spec: ModelSpec, subgraphs: &[Subgraph], ids: &mut Vec<NodeId>) {
    ids.clear();
    ids.reserve(subgraphs.len() * (1 + spec.f1 + spec.f1 * spec.f2));
    for sg in subgraphs {
        ids.push(sg.seed);
        for (i, &v) in sg.hop1.iter().take(spec.f1).enumerate() {
            ids.push(v);
            if let Some(group) = sg.hop2.get(i) {
                ids.extend(group.iter().take(spec.f2));
            }
        }
    }
}

/// Gathered feature frame: each unique node's row and label, with an
/// id → row index so batch assembly can copy rows out by node.
#[derive(Debug, Clone)]
pub struct Gathered {
    pub dim: usize,
    pub index: FxHashMap<NodeId, u32>,
    pub feats: Vec<f32>,
    pub labels: Vec<u32>,
    pub stats: FetchStats,
}

impl Gathered {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn contains(&self, v: NodeId) -> bool {
        self.index.contains_key(&v)
    }

    /// Feature row of `v`. Panics if `v` was not gathered (the planner
    /// always gathers every id the batch references).
    pub fn row(&self, v: NodeId) -> &[f32] {
        let i = self.index[&v] as usize;
        &self.feats[i * self.dim..(i + 1) * self.dim]
    }

    pub fn label_of(&self, v: NodeId) -> u32 {
        self.labels[self.index[&v] as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurestore::ShardedStore;
    use crate::graph::features::FeatureStore;

    fn spec() -> ModelSpec {
        ModelSpec { batch: 2, f1: 3, f2: 2, dim: 4, hidden: 8, classes: 3 }
    }

    #[test]
    fn dedup_sorts_and_uniquifies() {
        assert_eq!(dedup_ids(&[9, 1, 9, 4, 1]), vec![1, 4, 9]);
        assert!(dedup_ids(&[]).is_empty());
    }

    #[test]
    fn batch_ids_match_tensor_truncation() {
        let sgs = [
            Subgraph { seed: 0, hop1: vec![1, 2, 3, 4], hop2: vec![vec![5, 6, 7], vec![], vec![8], vec![9]] },
            Subgraph { seed: 10, hop1: vec![], hop2: vec![] },
        ];
        // f1=3 keeps hop1 [1,2,3]; hop2 group 0 truncated to [5,6]; node 4
        // and its group [9] fall outside the layout entirely.
        let ids = batch_ids(spec(), &sgs);
        assert_eq!(ids, vec![0, 1, 5, 6, 2, 3, 8, 10]);
    }

    #[test]
    fn plan_groups_by_owner_and_keeps_local() {
        let source = FeatureStore::hashed(4, 3, 7);
        let sharded = ShardedStore::build(&source, 64, 4, 0xbeef);
        let ids = dedup_ids(&(0..64).collect::<Vec<_>>());
        let requester = 1u32;
        let p = plan(&ids, requester, &sharded);
        // Every id lands exactly once, in its owner's group or local.
        let mut seen: Vec<NodeId> = p.local.clone();
        for (owner, group) in &p.remote {
            assert_ne!(*owner, requester);
            for &v in group {
                assert_eq!(sharded.owner_of(v), Some(*owner));
                seen.push(v);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, ids);
        for &v in &p.local {
            assert_eq!(sharded.owner_of(v), Some(requester));
        }
        assert!(p.remote.len() <= 3, "at most partitions-1 owner groups");
    }

    #[test]
    fn procedural_plan_is_all_local() {
        let fs = FeatureStore::hashed(4, 3, 7);
        let p = plan(&[1, 2, 3], 0, &fs);
        assert_eq!(p.local, vec![1, 2, 3]);
        assert!(p.remote.is_empty());
        assert_eq!(p.remote_rows(), 0);
    }

    #[test]
    fn stats_rates_and_delta() {
        let a = FetchStats { requested: 100, unique: 25, cache_hits: 20, ..Default::default() };
        assert!((a.dedup_factor() - 4.0).abs() < 1e-12);
        assert!((a.cache_hit_rate() - 0.8).abs() < 1e-12);
        let later = FetchStats { requested: 150, unique: 40, cache_hits: 30, ..Default::default() };
        let d = later.delta(&a);
        assert_eq!(d.requested, 50);
        assert_eq!(d.unique, 15);
        assert_eq!(d.cache_hits, 10);
        assert_eq!(FetchStats::default().cache_hit_rate(), 0.0);
        assert_eq!(FetchStats::default().dedup_factor(), 1.0);
    }
}
