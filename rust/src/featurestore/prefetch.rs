//! Feature prefetching: gather batch *t+1* while batch *t* trains, and
//! warm the hot-node cache a whole generation **wave** ahead.
//!
//! In the concurrent pipeline the trainer's critical path per iteration is
//! `materialize(batch) → grad → allreduce → apply`. Materialization is
//! pure feature work (dedup, cache probes, bulk remote gathers) with no
//! dependence on model state, so it can run one batch ahead on a side
//! thread: a bounded rendezvous channel of depth 1 holds the prepared
//! [`HostBatch`] while the worker trains on the previous one. Batches are
//! delivered in submission order, so training trajectories are unchanged —
//! prefetching only moves gather latency off the critical path.
//!
//! [`WaveWarmer`] extends the same idea from one batch to one wave: the
//! generation side announces each completed wave's unique node ids
//! (via [`crate::engines::SubgraphSink::wave_complete`] /
//! [`crate::engines::common::WaveSlots::unique_nodes`]) and the warmer
//! bulk-gathers them into the cache **on the generator thread** — so by
//! the time the wave's subgraphs drain through the queue into batch
//! assembly, their rows are already resident. Cache rows are
//! byte-identical to backend rows, so training trajectories are unchanged
//! here too; only where the gather latency lands changes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::Scope;

use anyhow::Result;

use crate::graph::NodeId;
use crate::sampler::Subgraph;
use crate::train::meta::ModelSpec;
use crate::train::runtime::HostBatch;

use super::FeatureService;

/// Wave-ahead cache warming (see module docs). Counters are atomic so the
/// generation thread can warm while the driver later reads totals.
pub struct WaveWarmer<'a> {
    service: &'a FeatureService,
    waves: AtomicU64,
    nodes: AtomicU64,
    skipped: AtomicU64,
}

impl<'a> WaveWarmer<'a> {
    pub fn new(service: &'a FeatureService) -> Self {
        Self {
            service,
            waves: AtomicU64::new(0),
            nodes: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
        }
    }

    /// Push one wave's unique node ids into the service's cache.
    pub fn warm(&self, ids: &[NodeId]) {
        self.waves.fetch_add(1, Ordering::Relaxed);
        self.nodes.fetch_add(ids.len() as u64, Ordering::Relaxed);
        self.service.warm_cache(ids);
    }

    /// Record a wave whose warming was clamped because it completed
    /// outside the backpressure window (deep look-ahead ran far ahead of
    /// consumption — inserting its rows would churn the resident hot
    /// set; see [`crate::pipeline::QueueSink`]).
    pub fn note_skipped(&self) {
        self.skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// `(waves, node ids)` pushed through the warmer so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.waves.load(Ordering::Relaxed), self.nodes.load(Ordering::Relaxed))
    }

    /// Waves whose warming was clamped by backpressure.
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }
}

/// Where a training worker's batches come from: materialized inline on
/// the worker thread, or prepared ahead by a prefetch thread.
pub enum BatchFeed {
    Inline {
        rx: Receiver<Vec<Subgraph>>,
        spec: ModelSpec,
        worker: u32,
    },
    Prefetched(Receiver<Result<HostBatch>>),
}

impl BatchFeed {
    /// Next materialized batch; `None` once the upstream closed.
    pub fn next(&self, service: &FeatureService) -> Option<Result<HostBatch>> {
        match self {
            BatchFeed::Inline { rx, spec, worker } => rx
                .recv()
                .ok()
                .map(|subs| service.materialize(*spec, &subs, *worker)),
            BatchFeed::Prefetched(rx) => rx.recv().ok(),
        }
    }
}

/// Spawn a prefetch thread in `scope` that drains subgraph groups from
/// `rx`, materializes them through `service` on behalf of `worker`, and
/// hands batches over a bounded channel of `depth` (≥ 1). With depth 1
/// the gather for iteration t+1 overlaps training on iteration t.
pub fn spawn_prefetcher<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    service: &'env FeatureService,
    spec: ModelSpec,
    worker: u32,
    rx: Receiver<Vec<Subgraph>>,
    depth: usize,
) -> Receiver<Result<HostBatch>> {
    let (tx, out) = sync_channel(depth.max(1));
    scope.spawn(move || {
        while let Ok(subs) = rx.recv() {
            let batch = service.materialize(spec, &subs, worker);
            let failed = batch.is_err();
            // A closed receiver (worker gone) or a materialization error
            // both end the feed; the error is delivered first if possible.
            if tx.send(batch).is_err() || failed {
                break;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurestore::FeatureService;
    use crate::graph::features::FeatureStore;
    use std::sync::mpsc::channel;

    fn spec() -> ModelSpec {
        ModelSpec { batch: 2, f1: 2, f2: 2, dim: 4, hidden: 8, classes: 3 }
    }

    fn groups() -> Vec<Vec<Subgraph>> {
        (0..5u32)
            .map(|g| {
                (0..2)
                    .map(|b| Subgraph {
                        seed: g * 2 + b,
                        hop1: vec![(g + b) % 10, (g + b + 1) % 10],
                        hop2: vec![vec![b % 10], vec![]],
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn prefetched_batches_equal_inline_in_order() {
        let svc = FeatureService::procedural(FeatureStore::hashed(4, 3, 2));
        let expected: Vec<HostBatch> = groups()
            .iter()
            .map(|g| svc.materialize(spec(), g, 0).unwrap())
            .collect();
        let (tx, rx) = channel::<Vec<Subgraph>>();
        let got: Vec<HostBatch> = std::thread::scope(|scope| {
            let hb_rx = spawn_prefetcher(scope, &svc, spec(), 0, rx, 1);
            for g in groups() {
                tx.send(g).unwrap();
            }
            drop(tx); // close the feed → prefetcher exits
            std::iter::from_fn(|| hb_rx.recv().ok())
                .map(|r| r.unwrap())
                .collect()
        });
        assert_eq!(got, expected);
    }

    #[test]
    fn error_is_delivered_then_feed_stops() {
        // Wrong group size → materialize errors on the first group.
        let svc = FeatureService::procedural(FeatureStore::hashed(4, 3, 2));
        let (tx, rx) = channel::<Vec<Subgraph>>();
        std::thread::scope(|scope| {
            let hb_rx = spawn_prefetcher(scope, &svc, spec(), 0, rx, 1);
            tx.send(vec![Subgraph::new(1)]).unwrap(); // 1 != batch(2)
            let first = hb_rx.recv().unwrap();
            assert!(first.is_err());
            drop(tx);
            assert!(hb_rx.recv().is_err(), "feed must close after an error");
        });
    }

    #[test]
    fn inline_feed_matches_direct_materialization() {
        let svc = FeatureService::procedural(FeatureStore::hashed(4, 3, 2));
        let (tx, rx) = channel::<Vec<Subgraph>>();
        let feed = BatchFeed::Inline { rx, spec: spec(), worker: 0 };
        let g = &groups()[0];
        tx.send(g.clone()).unwrap();
        let got = feed.next(&svc).unwrap().unwrap();
        assert_eq!(got, svc.materialize(spec(), g, 0).unwrap());
        drop(tx);
        assert!(feed.next(&svc).is_none());
    }
}
