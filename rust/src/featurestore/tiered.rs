//! Out-of-core feature shards: the tiered-memory sibling of
//! [`ShardedStore`](super::ShardedStore).
//!
//! Feature rows live in compressed cold-tier pages
//! ([`crate::storage::tier::PageStore`]) in global node order —
//! `rows_per_page` consecutive rows per page, each `f32` stored as its
//! bit pattern — under a CLOCK-managed hot tier
//! ([`crate::storage::tier::PageCache`]) sized by the feature half of
//! `--memory-budget-mb`. Labels and the ownership hash stay resident
//! (4 bytes/node — they are the "offsets" of the feature tier, exactly
//! as CSR offsets stay resident over paged adjacency).
//!
//! The backend contract is unchanged: every row faulted back out of the
//! cold tier is byte-identical to what the procedural source computes,
//! at every memory budget and thread count (property-tested in
//! `tests/featurestore.rs`), so training cannot tell the tiers exist —
//! only the `tier.*` metrics and the `tier-fault` trace row can.

use std::sync::Arc;

use crate::graph::features::FeatureStore;
use crate::graph::NodeId;
use crate::storage::tier::{PageCache, PageStore, PageStoreWriter, TierStats, PAGE_WORDS};
use crate::util::rng::mix2;

use super::FeatureBackend;

/// Feature store with resident labels over cold-tier feature pages.
#[derive(Debug)]
pub struct TieredStore {
    dim: usize,
    num_classes: u32,
    partitions: usize,
    part_seed: u64,
    num_nodes: usize,
    rows_per_page: usize,
    store: PageStore,
    cache: PageCache,
    labels: Vec<u32>,
}

impl TieredStore {
    /// Materialize the cold tier for nodes `0..num_nodes` from the
    /// procedural `source` (write-once), sizing the hot tier to
    /// `budget_bytes` (0 = unlimited: behaves like a resident store
    /// after first touch).
    pub fn build(
        source: &FeatureStore,
        num_nodes: NodeId,
        partitions: usize,
        part_seed: u64,
        budget_bytes: u64,
    ) -> Self {
        let n = num_nodes as usize;
        let d = source.dim;
        let rows_per_page = (PAGE_WORDS / d.max(1)).max(1);
        let mut writer = PageStoreWriter::create().expect("create feature cold tier");
        let mut labels = vec![0u32; n];
        let mut row = vec![0.0f32; d];
        let mut page = Vec::with_capacity(rows_per_page * d);
        for v in 0..n {
            source.write_feature(v as NodeId, &mut row);
            page.extend(row.iter().map(|f| f.to_bits()));
            labels[v] = source.label(v as NodeId);
            if page.len() == rows_per_page * d {
                writer.push_words(&page).expect("write feature page");
                page.clear();
            }
        }
        if !page.is_empty() {
            writer.push_words(&page).expect("write feature page");
        }
        let store = writer.finish();
        let cache = PageCache::with_budget(budget_bytes, store.num_pages());
        Self {
            dim: d,
            num_classes: source.num_classes,
            partitions: partitions.max(1),
            part_seed,
            num_nodes: n,
            rows_per_page,
            store,
            cache,
            labels,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_pages(&self) -> usize {
        self.store.num_pages()
    }

    /// Hot-tier capacity in pages.
    pub fn hot_capacity_pages(&self) -> usize {
        self.cache.capacity()
    }

    /// Compressed cold-tier bytes on disk.
    pub fn cold_bytes(&self) -> u64 {
        self.store.cold_bytes()
    }

    /// Resident bytes: labels plus the hot tier's current pages.
    pub fn memory_bytes(&self) -> u64 {
        self.labels.len() as u64 * 4 + self.cache.resident_bytes()
    }

    pub fn tier_stats(&self) -> TierStats {
        self.cache.stats()
    }

    #[inline]
    fn page_of(&self, v: NodeId) -> u32 {
        let vi = v as usize;
        assert!(vi < self.num_nodes, "node {v} outside tiered store");
        (vi / self.rows_per_page) as u32
    }

    /// Fault (or hit) the page holding `v`; returns the page and the
    /// word offset of `v`'s row within it.
    #[inline]
    fn row_page(&self, v: NodeId) -> (Arc<Vec<u32>>, usize) {
        let page = self.page_of(v);
        let arc = self.cache.get(page, &self.store).expect("cold tier fault");
        let off = (v as usize % self.rows_per_page) * self.dim;
        (arc, off)
    }

    #[inline]
    fn copy_row(words: &[u32], out: &mut [f32]) {
        for (o, &w) in out.iter_mut().zip(words) {
            *o = f32::from_bits(w);
        }
    }
}

impl FeatureBackend for TieredStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_classes(&self) -> u32 {
        self.num_classes
    }

    fn label(&self, v: NodeId) -> u32 {
        let vi = v as usize;
        assert!(vi < self.num_nodes, "node {v} outside tiered store");
        self.labels[vi]
    }

    fn write_feature(&self, v: NodeId, out: &mut [f32]) {
        let (page, off) = self.row_page(v);
        Self::copy_row(&page[off..off + self.dim], out);
    }

    fn gather_into(&self, ids: &[NodeId], out: &mut [f32]) {
        self.gather_into_budget(ids, out, crate::util::workpool::default_threads())
    }

    fn gather_into_budget(&self, ids: &[NodeId], out: &mut [f32], threads: usize) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d, "gather buffer size mismatch");
        let threads = threads.max(1);
        // Same fan-out shape as ShardedStore: big gathers split into row
        // chunks on the gather pool so cold-page faults (read + inflate)
        // overlap across workers instead of serializing behind one
        // thread. A one-entry page memo per chunk keeps the common case
        // (batch ids clustered in a page) at one cache probe per run of
        // same-page rows.
        const PAR_MIN_FLOATS: usize = 1 << 15;
        if threads > 1 && out.len() >= PAR_MIN_FLOATS {
            let chunk_rows = ids.len().div_ceil(threads * 4).max(64);
            crate::util::workpool::WorkPool::gather_global().run_row_chunks_labeled(
                out,
                d,
                threads,
                chunk_rows,
                "gather.rows",
                |row0, sub| {
                    let rows = sub.len() / d;
                    let mut memo: Option<(u32, Arc<Vec<u32>>)> = None;
                    for (j, &v) in ids[row0..row0 + rows].iter().enumerate() {
                        let p = self.page_of(v);
                        let arc = match &memo {
                            Some((mp, a)) if *mp == p => a.clone(),
                            _ => {
                                let a = self.cache.get(p, &self.store).expect("cold tier fault");
                                memo = Some((p, a.clone()));
                                a
                            }
                        };
                        let off = (v as usize % self.rows_per_page) * d;
                        Self::copy_row(&arc[off..off + d], &mut sub[j * d..(j + 1) * d]);
                    }
                },
            );
            return;
        }
        let mut memo: Option<(u32, Arc<Vec<u32>>)> = None;
        for (i, &v) in ids.iter().enumerate() {
            let p = self.page_of(v);
            let arc = match &memo {
                Some((mp, a)) if *mp == p => a.clone(),
                _ => {
                    let a = self.cache.get(p, &self.store).expect("cold tier fault");
                    memo = Some((p, a.clone()));
                    a
                }
            };
            let off = (v as usize % self.rows_per_page) * d;
            Self::copy_row(&arc[off..off + d], &mut out[i * d..(i + 1) * d]);
        }
    }

    fn owner_of(&self, v: NodeId) -> Option<u32> {
        // Stateless ownership hash — identical to ShardedStore's, so
        // fabric traffic accounting is backend-invariant.
        Some((mix2(self.part_seed ^ 0xfea7_5702e, v as u64) % self.partitions as u64) as u32)
    }

    fn partitions(&self) -> usize {
        self.partitions
    }
}

#[cfg(test)]
mod tests {
    use super::super::ShardedStore;
    use super::*;

    fn source() -> FeatureStore {
        FeatureStore::with_labels(6, 4, (0..200).map(|i| i % 4).collect(), 3)
    }

    #[test]
    fn rows_are_byte_identical_to_source() {
        let src = source();
        let st = TieredStore::build(&src, 200, 5, 42, 0);
        let mut a = vec![0.0f32; 6];
        for v in 0..200u32 {
            st.write_feature(v, &mut a);
            assert_eq!(a, src.feature(v), "row {v} differs through the tier");
            assert_eq!(FeatureBackend::label(&st, v), src.label(v));
        }
    }

    #[test]
    fn ownership_matches_sharded_store() {
        let src = source();
        let sharded = ShardedStore::build(&src, 200, 5, 42);
        let tiered = TieredStore::build(&src, 200, 5, 42, 0);
        for v in 0..200u32 {
            assert_eq!(tiered.owner_of(v), sharded.owner_of(v), "owner of {v} diverged");
        }
        assert_eq!(tiered.partitions(), sharded.partitions());
    }

    #[test]
    fn tiny_budget_still_gathers_identical_bytes() {
        let src = FeatureStore::with_labels(32, 4, (0..4000).map(|i| i % 4).collect(), 3);
        // One hot page for a multi-page working set: every chunk churns.
        let st = TieredStore::build(&src, 4000, 4, 3, 1);
        assert!(st.num_pages() > 1, "test needs a multi-page store");
        assert_eq!(st.hot_capacity_pages(), 1);
        let ids: Vec<u32> = (0..6000u32).map(|i| i.wrapping_mul(2654435761) % 4000).collect();
        let mut got = vec![0.0f32; ids.len() * 32];
        st.gather_into_budget(&ids, &mut got, 8);
        let mut one = vec![0.0f32; 32];
        for (i, &v) in ids.iter().enumerate() {
            src.write_feature(v, &mut one);
            assert_eq!(&got[i * 32..(i + 1) * 32], &one[..], "row {i} (node {v})");
        }
        let s = st.tier_stats();
        assert!(s.evictions > 0, "1-page budget must evict: {s:?}");
    }
}
