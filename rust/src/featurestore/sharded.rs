//! Dense partition-aligned feature shards.
//!
//! The production systems this models (GraphScale, DistDGL) keep node
//! features in a KV/tensor store sharded across workers, separate from
//! graph topology. [`ShardedStore`] reproduces that layout in-process:
//! each partition owns a dense `rows × dim` block plus its label column,
//! materialized once from the procedural source so rows stay
//! **byte-identical** to what the procedural backend computes — backend
//! choice must be invisible to training.
//!
//! Ownership is a stateless hash of the node id (the same scheme as
//! [`crate::graph::partition::Strategy::Hash`]), so every worker can
//! compute any row's owner without a directory lookup.

use crate::graph::features::FeatureStore;
use crate::graph::NodeId;
use crate::util::rng::mix2;

use super::FeatureBackend;

/// One partition's dense block.
#[derive(Debug, Clone)]
struct Shard {
    feats: Vec<f32>,
    labels: Vec<u32>,
}

/// Partition-sharded dense feature store.
#[derive(Debug, Clone)]
pub struct ShardedStore {
    dim: usize,
    num_classes: u32,
    partitions: usize,
    part_seed: u64,
    /// Owner partition per node.
    owner: Vec<u32>,
    /// Row index within the owner's shard, per node.
    row: Vec<u32>,
    shards: Vec<Shard>,
}

impl ShardedStore {
    /// Materialize shards for nodes `0..num_nodes` from the procedural
    /// `source`, hashed over `partitions` owners with `part_seed`.
    pub fn build(
        source: &FeatureStore,
        num_nodes: NodeId,
        partitions: usize,
        part_seed: u64,
    ) -> Self {
        let partitions = partitions.max(1);
        let n = num_nodes as usize;
        let d = source.dim;
        let mut owner = vec![0u32; n];
        let mut row = vec![0u32; n];
        let mut counts = vec![0u32; partitions];
        for v in 0..n {
            let o = (mix2(part_seed ^ 0xfea7_5702e, v as u64) % partitions as u64) as u32;
            owner[v] = o;
            row[v] = counts[o as usize];
            counts[o as usize] += 1;
        }
        let mut shards: Vec<Shard> = counts
            .iter()
            .map(|&c| Shard {
                feats: vec![0.0; c as usize * d],
                labels: vec![0; c as usize],
            })
            .collect();
        for v in 0..n {
            let (o, r) = (owner[v] as usize, row[v] as usize);
            source.write_feature(v as NodeId, &mut shards[o].feats[r * d..(r + 1) * d]);
            shards[o].labels[r] = source.label(v as NodeId);
        }
        Self {
            dim: d,
            num_classes: source.num_classes,
            partitions,
            part_seed,
            owner,
            row,
            shards,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.owner.len()
    }

    pub fn part_seed(&self) -> u64 {
        self.part_seed
    }

    /// Rows materialized in partition `p`.
    pub fn shard_rows(&self, p: usize) -> usize {
        self.shards[p].labels.len()
    }

    /// Resident bytes across all shards (the memory the procedural store
    /// avoids and a per-worker deployment would split `partitions` ways).
    pub fn memory_bytes(&self) -> u64 {
        let rows: u64 = self.shards.iter().map(|s| s.labels.len() as u64).sum();
        rows * (self.dim as u64 * 4 + 4) + self.owner.len() as u64 * 8
    }

    #[inline]
    fn loc(&self, v: NodeId) -> (usize, usize) {
        let vi = v as usize;
        assert!(vi < self.owner.len(), "node {v} outside sharded store");
        (self.owner[vi] as usize, self.row[vi] as usize)
    }
}

impl FeatureBackend for ShardedStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_classes(&self) -> u32 {
        self.num_classes
    }

    fn label(&self, v: NodeId) -> u32 {
        let (o, r) = self.loc(v);
        self.shards[o].labels[r]
    }

    fn write_feature(&self, v: NodeId, out: &mut [f32]) {
        let (o, r) = self.loc(v);
        out.copy_from_slice(&self.shards[o].feats[r * self.dim..(r + 1) * self.dim]);
    }

    fn gather_into(&self, ids: &[NodeId], out: &mut [f32]) {
        self.gather_into_budget(ids, out, crate::util::workpool::default_threads())
    }

    fn gather_into_budget(&self, ids: &[NodeId], out: &mut [f32], threads: usize) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d, "gather buffer size mismatch");
        let threads = threads.max(1);
        // Big bulk gathers (whole-wave warms, batch frames) fan out over
        // the persistent work pool — capped at the caller's gather budget
        // so copies never crowd out generation scans: contiguous id
        // ranges write disjoint row ranges of `out`. Small gathers stay
        // serial — dispatch would cost more than the copies.
        const PAR_MIN_FLOATS: usize = 1 << 15;
        if threads > 1 && out.len() >= PAR_MIN_FLOATS {
            let chunk_rows = ids.len().div_ceil(threads * 4).max(64);
            // Gather pool: bulk copies must not occupy the generation
            // pool's single job slot (see `WorkPool::gather_global`).
            crate::util::workpool::WorkPool::gather_global().run_row_chunks_labeled(
                out,
                d,
                threads,
                chunk_rows,
                "gather.rows",
                |row0, sub| {
                    let rows = sub.len() / d;
                    for (j, &v) in ids[row0..row0 + rows].iter().enumerate() {
                        let (o, r) = self.loc(v);
                        sub[j * d..(j + 1) * d]
                            .copy_from_slice(&self.shards[o].feats[r * d..(r + 1) * d]);
                    }
                },
            );
            return;
        }
        for (i, &v) in ids.iter().enumerate() {
            let (o, r) = self.loc(v);
            out[i * d..(i + 1) * d].copy_from_slice(&self.shards[o].feats[r * d..(r + 1) * d]);
        }
    }

    fn owner_of(&self, v: NodeId) -> Option<u32> {
        Some(self.owner[v as usize])
    }

    fn partitions(&self) -> usize {
        self.partitions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn source() -> FeatureStore {
        FeatureStore::with_labels(6, 4, (0..200).map(|i| i % 4).collect(), 3)
    }

    #[test]
    fn rows_are_byte_identical_to_source() {
        let src = source();
        let st = ShardedStore::build(&src, 200, 5, 42);
        let mut a = vec![0.0f32; 6];
        for v in 0..200u32 {
            st.write_feature(v, &mut a);
            assert_eq!(a, src.feature(v), "row {v} differs");
            assert_eq!(FeatureBackend::label(&st, v), src.label(v));
        }
    }

    #[test]
    fn every_node_owned_once_and_rows_dense() {
        let st = ShardedStore::build(&source(), 200, 7, 1);
        let total: usize = (0..7).map(|p| st.shard_rows(p)).sum();
        assert_eq!(total, 200);
        // Row indices within each shard are a permutation of 0..rows.
        let mut seen: Vec<Vec<bool>> = (0..7).map(|p| vec![false; st.shard_rows(p)]).collect();
        for v in 0..200u32 {
            let o = st.owner_of(v).unwrap() as usize;
            let r = st.row[v as usize] as usize;
            assert!(!seen[o][r], "duplicate row ({o},{r})");
            seen[o][r] = true;
        }
        assert!(seen.iter().flatten().all(|&x| x));
    }

    #[test]
    fn ownership_is_deterministic_and_seeded() {
        let a = ShardedStore::build(&source(), 200, 4, 9);
        let b = ShardedStore::build(&source(), 200, 4, 9);
        let c = ShardedStore::build(&source(), 200, 4, 10);
        assert_eq!(a.owner, b.owner);
        assert_ne!(a.owner, c.owner, "seed must move ownership");
    }

    #[test]
    fn bulk_gather_matches_per_row() {
        let st = ShardedStore::build(&source(), 200, 3, 5);
        let ids = [7u32, 3, 199, 0, 7];
        let mut bulk = vec![0.0f32; ids.len() * 6];
        st.gather_into(&ids, &mut bulk);
        let mut one = vec![0.0f32; 6];
        for (i, &v) in ids.iter().enumerate() {
            st.write_feature(v, &mut one);
            assert_eq!(&bulk[i * 6..(i + 1) * 6], &one[..]);
        }
    }

    #[test]
    fn parallel_bulk_gather_matches_serial_per_row() {
        // Large enough to cross the pool-parallel threshold (ids×dim ≥ 2^15).
        let st = ShardedStore::build(&source(), 200, 4, 3);
        let ids: Vec<u32> = (0..6000u32).map(|i| (i * 7) % 200).collect();
        let mut bulk = vec![0.0f32; ids.len() * 6];
        st.gather_into(&ids, &mut bulk);
        let mut one = vec![0.0f32; 6];
        for (i, &v) in ids.iter().enumerate() {
            st.write_feature(v, &mut one);
            assert_eq!(&bulk[i * 6..(i + 1) * 6], &one[..], "row {i} (node {v})");
        }
    }

    #[test]
    fn budgeted_gather_matches_default_at_every_budget() {
        let st = ShardedStore::build(&source(), 200, 4, 3);
        let ids: Vec<u32> = (0..6000u32).map(|i| (i * 11) % 200).collect();
        let mut reference = vec![0.0f32; ids.len() * 6];
        st.gather_into(&ids, &mut reference);
        for threads in [1usize, 2, 8] {
            let mut got = vec![0.0f32; ids.len() * 6];
            st.gather_into_budget(&ids, &mut got, threads);
            assert_eq!(got, reference, "budget {threads} changed gathered bytes");
        }
    }

    #[test]
    fn single_partition_is_all_local_to_slot_zero() {
        let st = ShardedStore::build(&source(), 50, 1, 0);
        for v in 0..50u32 {
            assert_eq!(st.owner_of(v), Some(0));
        }
        assert!(st.memory_bytes() > 0);
    }
}
