//! Hot-node feature cache (CLOCK replacement).
//!
//! Industrial graphs are heavy-tailed: a small set of hub nodes appears in
//! a large fraction of sampled subgraphs, so caching their rows converts
//! most remote feature traffic into local copies. CLOCK approximates LRU
//! with one reference bit per slot and no per-access reordering, which
//! keeps the (mutex-guarded) hot path a hash probe plus a bit set.
//!
//! The cache is typically seeded with the graph's highest-degree nodes
//! (see [`crate::featurestore::FeatureService::warm_cache`]) — the same
//! hub set the balance table and tree reduction exist to tame.

use crate::graph::NodeId;
use crate::util::fxhash::FxHashMap;

/// Process-global eviction counter (one registry lookup for the process,
/// shared by every cache instance).
fn evictions_counter() -> &'static crate::obs::metrics::Counter {
    static C: std::sync::OnceLock<crate::obs::metrics::Counter> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::obs::metrics::counter("cache.evictions"))
}

/// Hit/miss/eviction counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Fixed-capacity feature-row cache with CLOCK replacement.
pub struct HotCache {
    dim: usize,
    cap: usize,
    map: FxHashMap<NodeId, u32>,
    /// Slot → node, parallel to `refbit`, `labels` and `feats` rows.
    node_of: Vec<NodeId>,
    refbit: Vec<bool>,
    feats: Vec<f32>,
    labels: Vec<u32>,
    hand: usize,
    stats: CacheStats,
}

impl HotCache {
    pub fn new(cap_rows: usize, dim: usize) -> Self {
        assert!(cap_rows >= 1, "cache needs at least one row");
        assert!(dim >= 1);
        Self {
            dim,
            cap: cap_rows,
            map: FxHashMap::default(),
            node_of: Vec::new(),
            refbit: Vec::new(),
            feats: Vec::new(),
            labels: Vec::new(),
            hand: 0,
            stats: CacheStats::default(),
        }
    }

    /// Size the cache by a memory budget (the `--feature-cache-mb` knob).
    pub fn from_mb(mb: usize, dim: usize) -> Self {
        // Per row: dim f32s + node id + label + slot bookkeeping.
        let row_bytes = dim * 4 + 16;
        let cap = (mb.max(1) * (1 << 20)) / row_bytes;
        Self::new(cap.max(1), dim)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.node_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node_of.is_empty()
    }

    pub fn contains(&self, v: NodeId) -> bool {
        self.map.contains_key(&v)
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Look up `v`, counting a hit or miss and marking the slot recently
    /// used. Returns the cached row and label.
    pub fn get(&mut self, v: NodeId) -> Option<(&[f32], u32)> {
        match self.map.get(&v) {
            Some(&slot) => {
                let s = slot as usize;
                self.refbit[s] = true;
                self.stats.hits += 1;
                Some((&self.feats[s * self.dim..(s + 1) * self.dim], self.labels[s]))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert a row, evicting via CLOCK when full. Re-inserting a present
    /// node is a no-op (rows are immutable — backends are deterministic).
    pub fn insert(&mut self, v: NodeId, row: &[f32], label: u32) {
        assert_eq!(row.len(), self.dim, "row width mismatch");
        if self.map.contains_key(&v) {
            return;
        }
        self.stats.insertions += 1;
        if self.node_of.len() < self.cap {
            let s = self.node_of.len();
            self.node_of.push(v);
            self.refbit.push(true);
            self.feats.extend_from_slice(row);
            self.labels.push(label);
            self.map.insert(v, s as u32);
            return;
        }
        let s = self.evict();
        self.node_of[s] = v;
        self.refbit[s] = true;
        self.feats[s * self.dim..(s + 1) * self.dim].copy_from_slice(row);
        self.labels[s] = label;
        self.map.insert(v, s as u32);
    }

    /// CLOCK sweep: advance the hand, clearing reference bits, until an
    /// unreferenced victim is found (terminates within two sweeps).
    fn evict(&mut self) -> usize {
        loop {
            let s = self.hand;
            self.hand = (self.hand + 1) % self.cap;
            if self.refbit[s] {
                self.refbit[s] = false;
            } else {
                let old = self.node_of[s];
                self.map.remove(&old);
                self.stats.evictions += 1;
                evictions_counter().inc();
                crate::obs::trace::instant("cache.evict", &[("node", old as f64)]);
                return s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: NodeId, dim: usize) -> Vec<f32> {
        (0..dim).map(|i| (v * 100 + i as u32) as f32).collect()
    }

    #[test]
    fn hit_returns_inserted_bytes() {
        let mut c = HotCache::new(4, 3);
        c.insert(7, &row(7, 3), 2);
        let (r, l) = c.get(7).unwrap();
        assert_eq!(r, &row(7, 3)[..]);
        assert_eq!(l, 2);
        assert!(c.get(8).is_none());
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn capacity_is_respected_and_evictions_counted() {
        let mut c = HotCache::new(3, 2);
        for v in 0..10u32 {
            c.insert(v, &row(v, 2), v);
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 7);
        assert_eq!(c.stats().insertions, 10);
        // Exactly 3 of the inserted nodes are resident.
        let resident = (0..10u32).filter(|&v| c.contains(v)).count();
        assert_eq!(resident, 3);
    }

    #[test]
    fn clock_prefers_evicting_unreferenced_slots() {
        let mut c = HotCache::new(2, 1);
        c.insert(1, &[1.0], 0); // slot 0, ref
        c.insert(2, &[2.0], 0); // slot 1, ref
        // Both bits set: the sweep clears them and evicts slot 0 (node 1).
        c.insert(3, &[3.0], 0);
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3));
        // Now node 3 is referenced (fresh insert) but node 2 is not: the
        // hand sits on node 2's slot and evicts it, sparing node 3.
        c.insert(4, &[4.0], 0);
        assert!(c.contains(3), "referenced row evicted before unreferenced one");
        assert!(!c.contains(2));
        assert!(c.contains(4));
    }

    #[test]
    fn reinsert_is_noop() {
        let mut c = HotCache::new(2, 1);
        c.insert(5, &[5.0], 1);
        c.insert(5, &[99.0], 9);
        let (r, l) = c.get(5).unwrap();
        assert_eq!(r, &[5.0][..]);
        assert_eq!(l, 1);
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn from_mb_sizes_by_budget() {
        let c = HotCache::from_mb(1, 64);
        // 1 MiB / (64*4 + 16) bytes ≈ 3855 rows.
        assert!(c.capacity() > 3000 && c.capacity() < 4100, "{}", c.capacity());
        assert!(HotCache::from_mb(0, 8).capacity() >= 1, "degenerate budget still caches");
    }
}
