//! In-memory MapReduce engine with hierarchical tree reduction.
//!
//! GraphGen+ (like GraphGen and AGL before it) phrases subgraph generation
//! as MapReduce rounds; this module is the execution substrate:
//!
//! * [`map_shuffle_reduce`] — generic map → hash-shuffle → fold, running
//!   map tasks on a thread pool and charging shuffle traffic to a
//!   [`crate::cluster::Fabric`].
//! * [`tree_reduce`] / [`flat_reduce`] — the two aggregation topologies
//!   compared in E4. The paper's hot-node fix organizes workers into a
//!   reduction *tree* where each non-leaf merges its children's partial
//!   results ("partially processes and aggregates ... before passing the
//!   results to its parent"); the flat variant funnels everything into a
//!   single aggregator.

pub mod engine;
pub mod tree;

pub use engine::{map_shuffle_reduce, MapReduceStats};
pub use tree::{flat_reduce, tree_reduce, tree_reduce_with_fabric};
