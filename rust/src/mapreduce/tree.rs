//! Hierarchical tree reduction vs. flat aggregation — the paper's hot-node
//! strategy (§2 step 3) and its E4 ablation partner.
//!
//! "Instead of having all workers communicate directly with a central
//! aggregator, we organize them into a hierarchical tree structure. Each
//! non-leaf worker partially processes and aggregates its assigned
//! subgraphs before passing the results to its parent."
//!
//! The merge operators used in this codebase (reservoir top-k, subgraph
//! accumulators) are associative + commutative, so `tree_reduce` is exact.

use crate::cluster::Fabric;
use crate::util::workpool::WorkPool;

/// Flat aggregation: a single aggregator consumes every partial result
/// sequentially — the serial hot-spot the paper replaces. If `fabric` is
/// given, each partial is charged as a transfer from its producer to
/// worker 0 with `size_of` bytes.
pub fn flat_reduce<T>(
    mut items: Vec<T>,
    merge: impl Fn(T, T) -> T,
    fabric: Option<(&Fabric, &dyn Fn(&T) -> u64)>,
) -> Option<T> {
    if let Some((f, size_of)) = fabric {
        let w = f.workers();
        for (i, it) in items.iter().enumerate() {
            let src = i % w;
            if src != 0 {
                f.charge(src, 0, size_of(it));
            }
        }
    }
    let mut it = items.drain(..);
    let first = it.next()?;
    Some(it.fold(first, merge))
}

/// Hierarchical tree reduction with the given `arity`: items are merged in
/// rounds of `arity`-sized groups, each group's merge running in parallel
/// (each group is an independent non-leaf "worker"). Returns `None` for
/// empty input.
pub fn tree_reduce<T: Send>(
    items: Vec<T>,
    arity: usize,
    merge: impl Fn(T, T) -> T + Sync,
) -> Option<T> {
    tree_reduce_with_fabric(items, arity, merge, None)
}

/// [`tree_reduce`] with fabric accounting: at every round, each group's
/// non-first members are charged as transfers to the group leader. Worker
/// identity for item `i` at round r is its current slot index modulo the
/// fabric's worker count.
pub fn tree_reduce_with_fabric<T: Send>(
    items: Vec<T>,
    arity: usize,
    merge: impl Fn(T, T) -> T + Sync,
    fabric: Option<(&Fabric, &(dyn Fn(&T) -> u64 + Sync))>,
) -> Option<T> {
    assert!(arity >= 2, "tree arity must be >= 2");
    if items.is_empty() {
        return None;
    }
    let threads = crate::util::workpool::default_threads();
    let mut level: Vec<T> = items;
    while level.len() > 1 {
        if let Some((f, size_of)) = fabric {
            let w = f.workers();
            for (i, it) in level.iter().enumerate() {
                if i % arity != 0 {
                    let src = i % w;
                    let dst = (i - i % arity) % w;
                    if src != dst {
                        f.charge(src, dst, size_of(it));
                    }
                }
            }
        }
        // Group into arity-sized chunks and merge each group in parallel.
        let mut groups: Vec<Vec<T>> = Vec::with_capacity(level.len().div_ceil(arity));
        let mut cur: Vec<T> = Vec::with_capacity(arity);
        for item in level {
            cur.push(item);
            if cur.len() == arity {
                groups.push(std::mem::replace(&mut cur, Vec::with_capacity(arity)));
            }
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        level = parallel_merge(groups, threads, &merge);
    }
    level.pop()
}

fn parallel_merge<T: Send>(
    groups: Vec<Vec<T>>,
    threads: usize,
    merge: &(impl Fn(T, T) -> T + Sync),
) -> Vec<T> {
    // Move groups into Options so pool workers can take them by index
    // (each index is claimed exactly once by the work loop).
    let slots: Vec<std::sync::Mutex<Option<Vec<T>>>> =
        groups.into_iter().map(|g| std::sync::Mutex::new(Some(g))).collect();
    WorkPool::global().map_collect(slots.len(), threads, 1, |i| {
        let group = slots[i].lock().unwrap().take().expect("group taken once");
        let mut it = group.into_iter();
        let first = it.next().expect("non-empty group");
        it.fold(first, merge)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Cases;

    #[test]
    fn tree_equals_flat_for_sums() {
        let items: Vec<u64> = (1..=100).collect();
        let flat = flat_reduce(items.clone(), |a, b| a + b, None).unwrap();
        for arity in [2, 3, 8] {
            let tree = tree_reduce(items.clone(), arity, |a, b| a + b).unwrap();
            assert_eq!(tree, flat);
        }
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(tree_reduce(Vec::<u64>::new(), 2, |a, b| a + b), None);
        assert_eq!(tree_reduce(vec![7u64], 2, |a, b| a + b), Some(7));
        assert_eq!(flat_reduce(Vec::<u64>::new(), |a, b| a + b, None), None);
    }

    #[test]
    fn property_tree_equals_flat_for_reservoirs() {
        use crate::sampler::reservoir::TopK;
        Cases::new("tree == flat for TopK merge", 50).run(|rng| {
            let k = 1 + rng.gen_range(6) as usize;
            let parts: Vec<TopK> = (0..1 + rng.gen_range(20) as usize)
                .map(|_| {
                    let mut r = TopK::new(k);
                    for _ in 0..rng.gen_range(10) {
                        r.insert(rng.next_u64(), rng.gen_range(100) as u32);
                    }
                    r
                })
                .collect();
            let merge = |mut a: TopK, b: TopK| {
                a.merge(&b);
                a
            };
            let flat = flat_reduce(parts.clone(), merge, None);
            let arity = 2 + rng.gen_range(3) as usize;
            let tree = tree_reduce(parts, arity, merge);
            assert_eq!(flat, tree);
        });
    }

    #[test]
    fn fabric_accounting_tree_flattens_fan_in() {
        let fabric_flat = Fabric::new(8);
        let fabric_tree = Fabric::new(8);
        let items: Vec<u64> = (0..64).collect();
        let size: &(dyn Fn(&u64) -> u64 + Sync) = &|_| 1000;
        flat_reduce(items.clone(), |a, b| a + b, Some((&fabric_flat, &|_| 1000)));
        tree_reduce_with_fabric(items, 2, |a, b| a + b, Some((&fabric_tree, size)));
        let flat_hot = *fabric_flat.stats().per_worker_recv.iter().max().unwrap();
        let tree_hot = *fabric_tree.stats().per_worker_recv.iter().max().unwrap();
        assert!(
            tree_hot < flat_hot,
            "tree should flatten the aggregator hot spot: {tree_hot} vs {flat_hot}"
        );
    }
}
