//! Generic map → shuffle → reduce over in-memory partitions. Map and
//! reduce tasks run on the persistent [`WorkPool`] — no per-round thread
//! spawns.

use std::hash::{Hash, Hasher};
use std::sync::atomic::Ordering;
use std::sync::Mutex;

use crate::cluster::Fabric;
use crate::util::workpool::WorkPool;

/// Execution statistics for one MapReduce round.
#[derive(Debug, Clone, Default)]
pub struct MapReduceStats {
    pub map_tasks: usize,
    pub reduce_tasks: usize,
    pub emitted_pairs: u64,
    pub shuffled_bytes: u64,
}

fn key_hash<K: Hash>(k: &K) -> u64 {
    // FxHash-style: cheap and deterministic (std RandomState is seeded per
    // process, which would make reducer assignment nondeterministic).
    struct FxHasher(u64);
    impl Hasher for FxHasher {
        fn finish(&self) -> u64 {
            self.0
        }
        fn write(&mut self, bytes: &[u8]) {
            for &b in bytes {
                self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
        }
        fn write_u32(&mut self, v: u32) {
            self.0 = (self.0 ^ v as u64).wrapping_mul(0x100_0000_01b3);
        }
        fn write_u64(&mut self, v: u64) {
            self.0 = (self.0 ^ v).wrapping_mul(0x100_0000_01b3);
        }
        fn write_usize(&mut self, v: usize) {
            self.write_u64(v as u64);
        }
    }
    let mut h = FxHasher(0xcbf2_9ce4_8422_2325);
    k.hash(&mut h);
    crate::util::rng::mix64(h.finish())
}

/// Run one MapReduce round.
///
/// * `inputs` — one entry per map task (e.g. an edge partition).
/// * `map_fn(task_idx, input, emit)` — calls `emit(key, value)`.
/// * `wire_bytes(key, value)` — serialized size for shuffle accounting.
/// * `init()` / `fold(acc, key, value)` — reducer state per reduce task.
///
/// Keys are routed to reducer `hash(key) % reduce_tasks`. Map tasks run on
/// the persistent work pool (up to `threads` wide); each keeps per-reducer
/// local buffers (combiner style) that are handed to reducers after the
/// map barrier, then reducers fold in parallel. Shuffle traffic is charged
/// on `fabric` with map task `t` acting as worker `t % fabric.workers()`.
#[allow(clippy::too_many_arguments)]
pub fn map_shuffle_reduce<I, K, V, A>(
    inputs: &[I],
    reduce_tasks: usize,
    threads: usize,
    fabric: &Fabric,
    map_fn: impl Fn(usize, &I, &mut dyn FnMut(K, V)) + Sync,
    wire_bytes: impl Fn(&K, &V) -> u64 + Sync,
    init: impl Fn() -> A + Sync,
    fold: impl Fn(&mut A, K, V) + Sync,
) -> (Vec<A>, MapReduceStats)
where
    I: Sync,
    K: Hash + Send,
    V: Send,
    A: Send,
{
    assert!(reduce_tasks >= 1);
    let w = fabric.workers();
    // --- map phase: per-task emission into per-reducer buckets ----------
    let emitted = std::sync::atomic::AtomicU64::new(0);
    let shuffled = std::sync::atomic::AtomicU64::new(0);
    // buckets[r] collects (K, V) destined for reducer r, from all tasks.
    let buckets: Vec<Mutex<Vec<(K, V)>>> = (0..reduce_tasks).map(|_| Mutex::new(Vec::new())).collect();
    WorkPool::global().run_labeled(inputs.len(), threads.max(1), 1, "mr.map", |t| {
        let mut local: Vec<Vec<(K, V)>> = (0..reduce_tasks).map(|_| Vec::new()).collect();
        let mut count = 0u64;
        let mut bytes = 0u64;
        {
            let mut emit = |k: K, v: V| {
                let r = (key_hash(&k) % reduce_tasks as u64) as usize;
                bytes += wire_bytes(&k, &v);
                count += 1;
                local[r].push((k, v));
            };
            map_fn(t, &inputs[t], &mut emit);
        }
        emitted.fetch_add(count, Ordering::Relaxed);
        shuffled.fetch_add(bytes, Ordering::Relaxed);
        // Charge shuffle: mapper worker → reducer worker.
        let src = t % w;
        for (r, chunk) in local.into_iter().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            let dst = r % w;
            if src != dst {
                let b: u64 = chunk.iter().map(|(k, v)| wire_bytes(k, v)).sum();
                fabric.charge(src, dst, b);
            }
            buckets[r].lock().unwrap().extend(chunk);
        }
    });
    // --- reduce phase ----------------------------------------------------
    let accs: Vec<A> =
        WorkPool::global().map_collect_labeled(reduce_tasks, threads.max(1), 1, "mr.reduce", |r| {
            let pairs = std::mem::take(&mut *buckets[r].lock().unwrap());
            let mut acc = init();
            for (k, v) in pairs {
                fold(&mut acc, k, v);
            }
            acc
        });
    let stats = MapReduceStats {
        map_tasks: inputs.len(),
        reduce_tasks,
        emitted_pairs: emitted.load(Ordering::Relaxed),
        shuffled_bytes: shuffled.load(Ordering::Relaxed),
    };
    (accs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Word-count style: count occurrences of u32 keys.
    #[test]
    fn word_count_matches_sequential() {
        let inputs: Vec<Vec<u32>> = (0..16)
            .map(|t| (0..100).map(|i| ((t * 31 + i * 7) % 13) as u32).collect())
            .collect();
        let fabric = Fabric::new(4);
        let (accs, stats) = map_shuffle_reduce(
            &inputs,
            4,
            4,
            &fabric,
            |_, input: &Vec<u32>, emit| {
                for &x in input {
                    emit(x, 1u64);
                }
            },
            |_, _| 12,
            HashMap::<u32, u64>::new,
            |acc, k, v| *acc.entry(k).or_default() += v,
        );
        // Merge reducer outputs.
        let mut merged: HashMap<u32, u64> = HashMap::new();
        for a in accs {
            for (k, v) in a {
                *merged.entry(k).or_default() += v;
            }
        }
        // Sequential reference.
        let mut want: HashMap<u32, u64> = HashMap::new();
        for input in &inputs {
            for &x in input {
                *want.entry(x).or_default() += 1;
            }
        }
        assert_eq!(merged, want);
        assert_eq!(stats.emitted_pairs, 1600);
        assert_eq!(stats.shuffled_bytes, 1600 * 12);
        assert!(fabric.stats().total_bytes <= stats.shuffled_bytes);
        assert!(fabric.stats().total_bytes > 0);
    }

    #[test]
    fn key_routing_is_consistent() {
        // Same key must always land in the same reducer: fold per reducer
        // into a set of keys, then check disjointness.
        let inputs: Vec<Vec<u32>> = vec![(0..50).collect(), (0..50).collect()];
        let fabric = Fabric::new(2);
        let (accs, _) = map_shuffle_reduce(
            &inputs,
            3,
            2,
            &fabric,
            |_, input: &Vec<u32>, emit| {
                for &x in input {
                    emit(x, ());
                }
            },
            |_, _| 4,
            std::collections::HashSet::<u32>::new,
            |acc, k, _| {
                acc.insert(k);
            },
        );
        for i in 0..accs.len() {
            for j in (i + 1)..accs.len() {
                assert!(accs[i].is_disjoint(&accs[j]), "key in two reducers");
            }
        }
        let total: usize = accs.iter().map(|a| a.len()).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let inputs: Vec<Vec<u32>> = (0..8).map(|t| vec![t as u32; 10]).collect();
        let run = |threads| {
            let fabric = Fabric::new(2);
            let (accs, _) = map_shuffle_reduce(
                &inputs,
                4,
                threads,
                &fabric,
                |_, input: &Vec<u32>, emit| {
                    for &x in input {
                        emit(x, 1u64);
                    }
                },
                |_, _| 1,
                HashMap::<u32, u64>::new,
                |acc, k, v| *acc.entry(k).or_default() += v,
            );
            accs.into_iter().map(|a| {
                let mut v: Vec<_> = a.into_iter().collect();
                v.sort_unstable();
                v
            }).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(8));
    }

    #[test]
    fn empty_inputs() {
        let inputs: Vec<Vec<u32>> = vec![];
        let fabric = Fabric::new(1);
        let (accs, stats) = map_shuffle_reduce(
            &inputs,
            2,
            4,
            &fabric,
            |_, _: &Vec<u32>, _| {},
            |_, _| 0,
            || 0u64,
            |acc, _k: u32, _v: ()| *acc += 1,
        );
        assert_eq!(accs, vec![0, 0]);
        assert_eq!(stats.emitted_pairs, 0);
    }
}
