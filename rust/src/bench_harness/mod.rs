//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed-iteration measurement with summary statistics,
//! throughput reporting, and rendering to aligned-markdown tables — the
//! format used by the `benches/e*_*.rs` targets to regenerate the paper's
//! evaluation rows. Also emits machine-readable JSON next to the human
//! table when `GG_BENCH_JSON` points at a directory.

use std::time::{Duration, Instant};

use crate::util::bytes::{fmt_count, fmt_secs};
use crate::util::json::Json;
use crate::util::stats::Samples;

/// Measurement settings. Tuned down automatically for slow benchmarks: a
/// run stops early once both `min_iters` and `min_time` are satisfied.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub max_iters: u32,
    pub min_time: Duration,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            min_time: Duration::from_millis(300),
            max_time: Duration::from_secs(10),
        }
    }
}

impl BenchConfig {
    /// Quick settings for CI / `GG_BENCH_FAST=1`.
    pub fn fast() -> Self {
        Self {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            min_time: Duration::ZERO,
            max_time: Duration::from_secs(2),
        }
    }

    pub fn from_env() -> Self {
        if std::env::var("GG_BENCH_FAST").is_ok() {
            Self::fast()
        } else {
            Self::default()
        }
    }
}

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time samples (seconds).
    pub secs: Samples,
    /// Work items processed per iteration (for throughput), if reported.
    pub items_per_iter: Option<f64>,
    pub item_unit: String,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.secs.mean()
    }

    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.secs.mean())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let mut secs = self.secs.clone();
        o.set("name", self.name.clone())
            .set("iters", self.secs.len())
            .set("mean_s", self.mean_secs())
            .set("p50_s", secs.percentile(50.0))
            .set("min_s", self.secs.min())
            .set("max_s", self.secs.max())
            .set("stddev_s", self.secs.stddev());
        if let Some(t) = self.throughput() {
            o.set("throughput_per_s", t).set("item_unit", self.item_unit.clone());
        }
        o
    }
}

/// Named group of measurements = one experiment table.
pub struct Bench {
    pub group: String,
    pub config: BenchConfig,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        crate::util::logging::init();
        Self { group: group.to_string(), config: BenchConfig::from_env(), results: Vec::new() }
    }

    /// Measure `f` (whole-iteration timing). `items` is the amount of work
    /// per iteration for throughput reporting, with its unit name.
    pub fn measure<T>(
        &mut self,
        name: &str,
        items: Option<(f64, &str)>,
        mut f: impl FnMut() -> T,
    ) -> &Measurement {
        let cfg = &self.config;
        for _ in 0..cfg.warmup_iters {
            std::hint::black_box(f());
        }
        let mut secs = Samples::new();
        let t_start = Instant::now();
        let mut iters = 0u32;
        loop {
            let t0 = Instant::now();
            std::hint::black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
            iters += 1;
            let total = t_start.elapsed();
            let enough = iters >= cfg.min_iters && total >= cfg.min_time;
            if enough || iters >= cfg.max_iters || total >= cfg.max_time {
                break;
            }
        }
        let m = Measurement {
            name: name.to_string(),
            secs,
            items_per_iter: items.map(|(n, _)| n),
            item_unit: items.map(|(_, u)| u.to_string()).unwrap_or_default(),
        };
        log::info!(
            target: "bench",
            "{}/{name}: mean {} ({} iters){}",
            self.group,
            fmt_secs(m.mean_secs()),
            m.secs.len(),
            m.throughput()
                .map(|t| format!(", {} {}/s", fmt_count(t), m.item_unit))
                .unwrap_or_default()
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Mean seconds of a previously measured entry (by name).
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|m| m.name == name).map(|m| m.mean_secs())
    }

    /// Render the group as an aligned markdown table; `baseline` (if given
    /// and present) adds a speedup-vs-baseline column.
    pub fn render_table(&self, baseline: Option<&str>) -> String {
        let base = baseline.and_then(|b| self.mean_of(b));
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut header = vec![
            "variant".to_string(),
            "mean".to_string(),
            "min".to_string(),
            "iters".to_string(),
        ];
        let has_tp = self.results.iter().any(|m| m.items_per_iter.is_some());
        if has_tp {
            header.push("throughput".to_string());
        }
        if base.is_some() {
            header.push("speedup".to_string());
        }
        for m in &self.results {
            let mut row = vec![
                m.name.clone(),
                fmt_secs(m.mean_secs()),
                fmt_secs(m.secs.min()),
                format!("{}", m.secs.len()),
            ];
            if has_tp {
                row.push(
                    m.throughput()
                        .map(|t| format!("{} {}/s", fmt_count(t), m.item_unit))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            if let Some(b) = base {
                row.push(format!("{:.2}x", b / m.mean_secs()));
            }
            rows.push(row);
        }
        render_markdown(&self.group, &header, &rows)
    }

    /// Print the table and optionally write JSON (GG_BENCH_JSON=dir).
    /// JSON goes through the unified report writer
    /// ([`crate::obs::report::write_json`]), so every group document
    /// carries the `run_meta` header.
    pub fn report(&self, baseline: Option<&str>) {
        println!("\n{}", self.render_table(baseline));
        if let Ok(dir) = std::env::var("GG_BENCH_JSON") {
            let mut o = Json::obj();
            o.set("group", self.group.clone()).set(
                "results",
                Json::Arr(self.results.iter().map(|m| m.to_json()).collect()),
            );
            let path = std::path::Path::new(&dir).join(format!("{}.json", self.group));
            let _ = std::fs::create_dir_all(&dir);
            if let Err(e) = crate::obs::report::write_json(&path, o) {
                log::warn!("failed to write {}: {e}", path.display());
            }
        }
    }
}

/// Render an aligned markdown table with a title line.
pub fn render_markdown(title: &str, header: &[String], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = format!("### {title}\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for i in 0..cols {
            let cell = cells.get(i).map(String::as_str).unwrap_or("");
            let pad = widths[i] - cell.chars().count();
            line.push(' ');
            line.push_str(cell);
            line.push_str(&" ".repeat(pad + 1));
            line.push('|');
        }
        line
    };
    out.push_str(&fmt_row(header, &widths));
    out.push('\n');
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&"-".repeat(w + 2));
        sep.push('|');
    }
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_records_iterations() {
        let mut b = Bench::new("unit");
        b.config = BenchConfig::fast();
        let m = b.measure("noop", Some((100.0, "items")), || 1 + 1);
        assert!(m.secs.len() >= 1);
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn speedup_column_uses_baseline() {
        let mut b = Bench::new("unit2");
        b.config = BenchConfig::fast();
        b.measure("slow", None, || std::thread::sleep(Duration::from_millis(4)));
        b.measure("fastv", None, || std::thread::sleep(Duration::from_micros(100)));
        let table = b.render_table(Some("slow"));
        assert!(table.contains("speedup"), "{table}");
        assert!(table.contains("1.00x"), "{table}");
        // fast variant should show >1x speedup vs slow baseline
        let fast_line = table.lines().find(|l| l.contains("fastv")).unwrap();
        let x: f64 = fast_line
            .split('|')
            .rev()
            .nth(1)
            .unwrap()
            .trim()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(x > 1.0, "{table}");
    }

    #[test]
    fn markdown_alignment() {
        let t = render_markdown(
            "t",
            &["a".into(), "bb".into()],
            &[vec!["xxx".into(), "y".into()]],
        );
        assert!(t.contains("| a   | bb |"));
        assert!(t.contains("| xxx | y  |"));
    }

    #[test]
    fn json_roundtrip() {
        let mut b = Bench::new("unit3");
        b.config = BenchConfig::fast();
        b.measure("x", Some((10.0, "u")), || ());
        let j = b.results[0].to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("x"));
        assert!(parsed.get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
