//! Collective communication among simulated workers: ring AllReduce (the
//! gradient-sync primitive of Alg. 1 line 28) and binary-tree AllReduce
//! (the ablation partner). Each participating thread holds one
//! [`Collective`] handle; calls are bulk-synchronous (internal barrier per
//! operation), mirroring a synchronous data-parallel trainer.

use std::sync::{Arc, Barrier};

use super::mailbox::{Endpoint, Endpoints};
use super::Fabric;

/// Algorithm selector for [`Collective::allreduce_sum`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// Bandwidth-optimal ring: 2(n-1) steps, each moving |buf|/n elements.
    Ring,
    /// Binary-tree reduce + broadcast: 2·log2(n) rounds, |buf| per message.
    Tree,
}

impl std::str::FromStr for AllReduceAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" => Ok(Self::Ring),
            "tree" => Ok(Self::Tree),
            other => Err(format!("unknown allreduce algo '{other}'")),
        }
    }
}

/// Per-worker collective handle.
pub struct Collective {
    pub rank: usize,
    pub n: usize,
    ep: Endpoint<Vec<f32>>,
    barrier: Arc<Barrier>,
}

/// Create `n` handles sharing one fabric.
pub fn group(n: usize, fabric: &Fabric) -> Vec<Collective> {
    let barrier = Arc::new(Barrier::new(n));
    Endpoints::new(n, fabric)
        .into_vec()
        .into_iter()
        .map(|ep| Collective { rank: ep.rank, n, ep, barrier: barrier.clone() })
        .collect()
}

impl Collective {
    /// In-place sum-AllReduce of `buf` across all ranks. All ranks must
    /// call with equal-length buffers. Single-rank groups are a no-op.
    pub fn allreduce_sum(&self, buf: &mut [f32], algo: AllReduceAlgo) -> anyhow::Result<()> {
        if self.n == 1 {
            return Ok(());
        }
        match algo {
            AllReduceAlgo::Ring => self.ring(buf)?,
            AllReduceAlgo::Tree => self.tree(buf)?,
        }
        // One collective completes before the next starts (message streams
        // from different operations must not interleave in the mailboxes).
        self.barrier.wait();
        Ok(())
    }

    /// Mean-AllReduce — what gradient sync actually wants.
    pub fn allreduce_mean(&self, buf: &mut [f32], algo: AllReduceAlgo) -> anyhow::Result<()> {
        self.allreduce_sum(buf, algo)?;
        let inv = 1.0 / self.n as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }

    /// Ring allreduce: reduce-scatter then allgather over n chunks.
    fn ring(&self, buf: &mut [f32]) -> anyhow::Result<()> {
        let (n, rank) = (self.n, self.rank);
        let next = (rank + 1) % n;
        // Chunk boundaries (chunk c = ranges[c].0 .. ranges[c].1).
        let len = buf.len();
        let chunk_of = |c: usize| -> (usize, usize) {
            let base = len / n;
            let rem = len % n;
            let start = c * base + c.min(rem);
            let size = base + usize::from(c < rem);
            (start, start + size)
        };
        // Reduce-scatter: after n-1 steps, rank owns reduced chunk (rank+1)%n.
        for step in 0..n - 1 {
            let send_c = (rank + n - step) % n;
            let (s, e) = chunk_of(send_c);
            self.ep.send(next, buf[s..e].to_vec())?;
            let (_, data) = self.ep.recv()?;
            let recv_c = (rank + n - step - 1) % n;
            let (s, e) = chunk_of(recv_c);
            debug_assert_eq!(data.len(), e - s);
            for (dst, v) in buf[s..e].iter_mut().zip(&data) {
                *dst += v;
            }
        }
        // Allgather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_c = (rank + 1 + n - step) % n;
            let (s, e) = chunk_of(send_c);
            self.ep.send(next, buf[s..e].to_vec())?;
            let (_, data) = self.ep.recv()?;
            let recv_c = (rank + n - step) % n;
            let (s, e) = chunk_of(recv_c);
            debug_assert_eq!(data.len(), e - s);
            buf[s..e].copy_from_slice(&data);
        }
        Ok(())
    }

    /// Binary-tree allreduce rooted at rank 0: children send partial sums
    /// up, root broadcasts the total down the same tree.
    fn tree(&self, buf: &mut [f32]) -> anyhow::Result<()> {
        let (n, rank) = (self.n, self.rank);
        let left = 2 * rank + 1;
        let right = 2 * rank + 2;
        // Upward: receive from children (if any), add, send to parent.
        let mut expected = usize::from(left < n) + usize::from(right < n);
        while expected > 0 {
            let (_, data) = self.ep.recv()?;
            debug_assert_eq!(data.len(), buf.len());
            for (dst, v) in buf.iter_mut().zip(&data) {
                *dst += v;
            }
            expected -= 1;
        }
        if rank > 0 {
            let parent = (rank - 1) / 2;
            self.ep.send(parent, buf.to_vec())?;
            // Downward: wait for the broadcast value.
            let (_, data) = self.ep.recv()?;
            buf.copy_from_slice(&data);
        }
        // Broadcast to children.
        for child in [left, right] {
            if child < n {
                self.ep.send(child, buf.to_vec())?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Cases;

    fn run_allreduce(n: usize, len: usize, algo: AllReduceAlgo) -> (Vec<Vec<f32>>, Fabric) {
        let fabric = Fabric::new(n);
        let handles = group(n, &fabric);
        let mut results = Vec::new();
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for c in handles {
                joins.push(s.spawn(move || {
                    let mut buf: Vec<f32> =
                        (0..len).map(|i| (c.rank * 1000 + i) as f32).collect();
                    c.allreduce_mean(&mut buf, algo).unwrap();
                    buf
                }));
            }
            for j in joins {
                results.push(j.join().unwrap());
            }
        });
        (results, fabric)
    }

    fn expected(n: usize, len: usize) -> Vec<f32> {
        // mean over ranks of (rank*1000 + i)
        let mean_rank = (0..n).map(|r| r as f32).sum::<f32>() / n as f32;
        (0..len).map(|i| mean_rank * 1000.0 + i as f32).collect()
    }

    #[test]
    fn ring_matches_reference() {
        for n in [2, 3, 4, 7, 8] {
            let (results, _) = run_allreduce(n, 37, AllReduceAlgo::Ring);
            let want = expected(n, 37);
            for r in &results {
                for (a, b) in r.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "n={n}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn tree_matches_reference() {
        for n in [2, 3, 5, 8] {
            let (results, _) = run_allreduce(n, 16, AllReduceAlgo::Tree);
            let want = expected(n, 16);
            for r in &results {
                for (a, b) in r.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "n={n}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn single_rank_noop() {
        let (results, fabric) = run_allreduce(1, 8, AllReduceAlgo::Ring);
        assert_eq!(results[0], (0..8).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(fabric.stats().total_bytes, 0);
    }

    #[test]
    fn ring_is_bandwidth_optimal_vs_tree() {
        // Ring moves ~2·|buf| per worker regardless of n; tree moves
        // ~2·|buf|·log(n) through the root's subtree links.
        let (_, ring_fabric) = run_allreduce(8, 1024, AllReduceAlgo::Ring);
        let (_, tree_fabric) = run_allreduce(8, 1024, AllReduceAlgo::Tree);
        let ring_bottleneck = *ring_fabric.stats().per_worker_recv.iter().max().unwrap();
        let tree_bottleneck = *tree_fabric.stats().per_worker_recv.iter().max().unwrap();
        assert!(
            ring_bottleneck < tree_bottleneck,
            "ring {ring_bottleneck} vs tree {tree_bottleneck}"
        );
    }

    #[test]
    fn property_allreduce_sums_random_buffers() {
        Cases::new("allreduce random", 10).run(|rng| {
            let n = 2 + rng.gen_range(5) as usize;
            let len = 1 + rng.gen_range(64) as usize;
            let bufs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..len).map(|_| rng.gen_f32() - 0.5).collect()).collect();
            let mut want = vec![0.0f32; len];
            for b in &bufs {
                for (w, v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            let fabric = Fabric::new(n);
            let handles = group(n, &fabric);
            let algo = if rng.gen_bool(0.5) { AllReduceAlgo::Ring } else { AllReduceAlgo::Tree };
            std::thread::scope(|s| {
                for (c, b) in handles.into_iter().zip(bufs.clone()) {
                    let want = want.clone();
                    s.spawn(move || {
                        let mut buf = b;
                        c.allreduce_sum(&mut buf, algo).unwrap();
                        for (a, w) in buf.iter().zip(&want) {
                            assert!((a - w).abs() < 1e-3 * (1.0 + w.abs()));
                        }
                    });
                }
            });
        });
    }
}
