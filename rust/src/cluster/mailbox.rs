//! Typed point-to-point messaging between simulated workers, with every
//! transfer charged to the [`super::Fabric`].
//!
//! Also home of the shared delivery-failure vocabulary: the in-process
//! endpoints here and the real socket transport in [`super::proc`] both
//! surface [`MailboxError`], and both drive retries through the same
//! [`Backoff`] / [`retry_with_backoff`] helpers, so "timed out" vs "peer
//! is gone" mean the same thing on either side of a process boundary.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::Fabric;

/// Why a receive (or retried operation) failed. `Timeout` is transient —
/// the caller may retry, check liveness, or give up; `Disconnected` is
/// terminal — the peer closed its end and no message will ever arrive;
/// `Corrupt` means bytes arrived but failed their CRC-32 (or decoded to
/// nonsense) — the connection can no longer be trusted and must be torn
/// down and re-established, but the *peer* may be perfectly healthy, so
/// callers reconnect instead of declaring it lost.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum MailboxError {
    #[error("receive timed out after {0:?}")]
    Timeout(Duration),
    #[error("peer disconnected: {0}")]
    Disconnected(String),
    #[error("corrupt frame: {0}")]
    Corrupt(String),
}

impl MailboxError {
    pub fn is_timeout(&self) -> bool {
        matches!(self, MailboxError::Timeout(_))
    }

    pub fn is_corrupt(&self) -> bool {
        matches!(self, MailboxError::Corrupt(_))
    }
}

/// Exponential backoff schedule: delays start at `initial`, double each
/// step, and saturate at `cap`. Used between connect/send retries and
/// between receive polls (ISSUE 9's transport hardening).
#[derive(Debug, Clone)]
pub struct Backoff {
    next: Duration,
    cap: Duration,
    /// Deterministic jitter stream (0 = plain exponential). Seeded per
    /// caller (e.g. by rank) so a fleet of workers reconnecting after a
    /// coordinator restart doesn't thunder in lockstep.
    jitter: u64,
}

impl Backoff {
    pub fn new(initial: Duration, cap: Duration) -> Self {
        Self { next: initial.max(Duration::from_micros(50)), cap, jitter: 0 }
    }

    /// A sensible default for local-socket work: 1 ms doubling to 100 ms.
    pub fn for_transport() -> Self {
        Self::new(Duration::from_millis(1), Duration::from_millis(100))
    }

    /// Transport backoff with per-caller jitter: each step is stretched
    /// by a deterministic factor in `[1.0, 1.5)` drawn from a splitmix
    /// stream seeded with `salt`. Different salts (ranks) desynchronize;
    /// the same salt replays the same schedule, keeping retry timing
    /// reproducible under the chaos harness.
    pub fn for_transport_jittered(salt: u64) -> Self {
        let mut b = Self::for_transport();
        // Never zero, so jitter stays enabled for every salt.
        b.jitter = salt | (1 << 63);
        b
    }

    /// The delay to wait before the next attempt (and advance the
    /// schedule).
    pub fn step(&mut self) -> Duration {
        let d = self.next;
        self.next = (self.next * 2).min(self.cap);
        if self.jitter != 0 {
            let r = crate::util::rng::splitmix64(&mut self.jitter);
            // d * [1.0, 1.5): jitter spreads, never shortens below base.
            let extra = (d.as_nanos() * ((r >> 32) as u128)) >> 33;
            d + Duration::from_nanos(extra as u64)
        } else {
            d
        }
    }

    /// Sleep one backoff step, clamped so the caller never sleeps past
    /// `deadline`. Returns `false` when the deadline has already passed
    /// (nothing slept — the caller should stop retrying).
    pub fn sleep_before(&mut self, deadline: Instant) -> bool {
        let now = Instant::now();
        if now >= deadline {
            return false;
        }
        std::thread::sleep(self.step().min(deadline - now));
        true
    }
}

/// Drive `attempt` until it produces a value or `deadline` passes,
/// sleeping one [`Backoff`] step between tries. `attempt` returns
/// `Ok(Some(v))` on success, `Ok(None)` to retry (counted via `on_retry`,
/// e.g. the `cluster.send_retries` counter), or `Err` to abort — a
/// disconnect is never retried away.
pub fn retry_with_backoff<T>(
    deadline: Instant,
    backoff: &mut Backoff,
    mut on_retry: impl FnMut(),
    mut attempt: impl FnMut() -> Result<Option<T>, MailboxError>,
) -> Result<T, MailboxError> {
    let start = Instant::now();
    loop {
        if let Some(v) = attempt()? {
            return Ok(v);
        }
        on_retry();
        if !backoff.sleep_before(deadline) {
            return Err(MailboxError::Timeout(start.elapsed()));
        }
    }
}

/// Types that know their serialized wire size (for fabric accounting —
/// messages travel in-process, but the byte counts drive the cluster
/// traffic analysis in EXPERIMENTS.md).
pub trait Payload: Send {
    fn wire_bytes(&self) -> u64;
}

impl Payload for Vec<f32> {
    fn wire_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for Vec<u8> {
    fn wire_bytes(&self) -> u64 {
        self.len() as u64
    }
}

impl Payload for crate::sampler::Subgraph {
    fn wire_bytes(&self) -> u64 {
        self.encoded_len() as u64
    }
}

impl<T: Payload> Payload for Option<T> {
    fn wire_bytes(&self) -> u64 {
        // 1-byte tag + payload
        1 + self.as_ref().map(|t| t.wire_bytes()).unwrap_or(0)
    }
}

/// All-to-all endpoints for `n` workers: `Endpoints::new(n)` returns one
/// [`Endpoint`] per worker, each able to send to any rank and receive its
/// own mail. Dropping an endpoint closes its senders (receivers observe
/// disconnection).
pub struct Endpoints<M: Payload> {
    pub endpoints: Vec<Endpoint<M>>,
}

pub struct Endpoint<M: Payload> {
    pub rank: usize,
    fabric: Fabric,
    txs: Vec<Sender<(usize, M)>>,
    rx: Receiver<(usize, M)>,
}

impl<M: Payload> Endpoints<M> {
    pub fn new(n: usize, fabric: &Fabric) -> Self {
        assert_eq!(n, fabric.workers());
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint { rank, fabric: fabric.clone(), txs: txs.clone(), rx })
            .collect();
        Self { endpoints }
    }

    /// Take all endpoints (for distributing to worker threads).
    pub fn into_vec(self) -> Vec<Endpoint<M>> {
        self.endpoints
    }
}

impl<M: Payload> Endpoint<M> {
    /// Send `msg` to `dst`, charging the fabric. Sending to self is
    /// allowed and charged at zero bytes (local handoff).
    pub fn send(&self, dst: usize, msg: M) -> anyhow::Result<()> {
        if dst != self.rank {
            self.fabric.charge(self.rank, dst, msg.wire_bytes());
        }
        self.txs[dst]
            .send((self.rank, msg))
            .map_err(|_| anyhow::anyhow!("worker {dst} mailbox closed"))
    }

    /// Blocking receive: (source rank, message).
    pub fn recv(&self) -> anyhow::Result<(usize, M)> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("all senders to {} closed", self.rank))
    }

    /// Receive with timeout: typed [`MailboxError`] instead of the old
    /// ad-hoc `Ok(None)` / stringly-typed disconnect mix, so callers can
    /// branch on transient-vs-terminal without string matching.
    pub fn recv_timeout(&self, d: Duration) -> Result<(usize, M), MailboxError> {
        match self.rx.recv_timeout(d) {
            Ok(v) => Ok(v),
            Err(RecvTimeoutError::Timeout) => Err(MailboxError::Timeout(d)),
            Err(RecvTimeoutError::Disconnected) => {
                Err(MailboxError::Disconnected(format!("all senders to {} closed", self.rank)))
            }
        }
    }

    /// Receive until an absolute deadline, polling in backoff-paced
    /// slices so a caller can interleave liveness checks via `on_retry`
    /// (the coordinator's lease sweep uses exactly this shape).
    pub fn recv_deadline(
        &self,
        deadline: Instant,
        backoff: &mut Backoff,
        on_retry: impl FnMut(),
    ) -> Result<(usize, M), MailboxError> {
        retry_with_backoff(deadline, backoff, on_retry, || {
            match self.rx.recv_timeout(Duration::from_millis(1)) {
                Ok(v) => Ok(Some(v)),
                Err(RecvTimeoutError::Timeout) => Ok(None),
                Err(RecvTimeoutError::Disconnected) => Err(MailboxError::Disconnected(format!(
                    "all senders to {} closed",
                    self.rank
                ))),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery_and_accounting() {
        let fabric = Fabric::new(3);
        let eps = Endpoints::<Vec<f32>>::new(3, &fabric).into_vec();
        std::thread::scope(|s| {
            let mut it = eps.into_iter();
            let (e0, e1, e2) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            s.spawn(move || {
                e0.send(1, vec![1.0, 2.0]).unwrap();
                e0.send(2, vec![3.0]).unwrap();
            });
            s.spawn(move || {
                let (src, m) = e1.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(m, vec![1.0, 2.0]);
            });
            s.spawn(move || {
                let (src, m) = e2.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(m, vec![3.0]);
            });
        });
        let st = fabric.stats();
        assert_eq!(st.total_bytes, 8 + 4);
        assert_eq!(st.total_messages, 2);
    }

    #[test]
    fn self_send_is_free() {
        let fabric = Fabric::new(1);
        let eps = Endpoints::<Vec<u8>>::new(1, &fabric).into_vec();
        eps[0].send(0, vec![9; 100]).unwrap();
        let (src, m) = eps[0].recv().unwrap();
        assert_eq!((src, m.len()), (0, 100));
        assert_eq!(fabric.stats().total_bytes, 0);
    }

    #[test]
    fn timeout_is_typed_and_transient() {
        let fabric = Fabric::new(2);
        let eps = Endpoints::<Vec<u8>>::new(2, &fabric).into_vec();
        let err = eps[1].recv_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(err.is_timeout());
        // The message still arrives on a later attempt: timeout did not
        // poison the endpoint.
        eps[0].send(1, vec![7]).unwrap();
        let (src, m) = eps[1].recv_timeout(Duration::from_millis(100)).unwrap();
        assert_eq!((src, m), (0, vec![7]));
    }

    #[test]
    fn disconnect_is_typed_and_terminal() {
        let fabric = Fabric::new(2);
        let mut eps = Endpoints::<Vec<u8>>::new(2, &fabric).into_vec();
        let e1 = eps.pop().unwrap();
        drop(eps); // drop rank 0 → all senders to rank 1 close
        let err = e1.recv_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(!err.is_timeout(), "expected Disconnected, got {err:?}");
        assert!(matches!(err, MailboxError::Disconnected(_)));
    }

    #[test]
    fn recv_deadline_polls_with_backoff_until_delivery() {
        let fabric = Fabric::new(2);
        let eps = Endpoints::<Vec<u8>>::new(2, &fabric).into_vec();
        let mut it = eps.into_iter();
        let (e0, e1) = (it.next().unwrap(), it.next().unwrap());
        let mut polls = 0u32;
        std::thread::scope(|s| {
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                e0.send(1, vec![42]).unwrap();
            });
            let deadline = Instant::now() + Duration::from_secs(5);
            let mut backoff = Backoff::new(Duration::from_millis(1), Duration::from_millis(8));
            let (src, m) = e1.recv_deadline(deadline, &mut backoff, || polls += 1).unwrap();
            assert_eq!((src, m), (0, vec![42]));
        });
        assert!(polls > 0, "delivery was delayed, so at least one poll must have backed off");
    }

    #[test]
    fn recv_deadline_times_out_and_disconnects() {
        let fabric = Fabric::new(2);
        let mut eps = Endpoints::<Vec<u8>>::new(2, &fabric).into_vec();
        let e1 = eps.pop().unwrap();
        // Deadline path: senders alive, nothing sent.
        let mut backoff = Backoff::for_transport();
        let err = e1
            .recv_deadline(Instant::now() + Duration::from_millis(20), &mut backoff, || {})
            .unwrap_err();
        assert!(err.is_timeout());
        // Disconnect path: terminal immediately, deadline irrelevant.
        drop(eps);
        let err = e1
            .recv_deadline(Instant::now() + Duration::from_secs(30), &mut backoff, || {})
            .unwrap_err();
        assert!(matches!(err, MailboxError::Disconnected(_)));
    }

    #[test]
    fn backoff_doubles_to_cap() {
        let mut b = Backoff::new(Duration::from_millis(2), Duration::from_millis(7));
        assert_eq!(b.step(), Duration::from_millis(2));
        assert_eq!(b.step(), Duration::from_millis(4));
        assert_eq!(b.step(), Duration::from_millis(7));
        assert_eq!(b.step(), Duration::from_millis(7));
    }

    #[test]
    fn jittered_backoff_spreads_without_shortening() {
        let mut a = Backoff::for_transport_jittered(0);
        let mut b = Backoff::for_transport_jittered(1);
        let mut a2 = Backoff::for_transport_jittered(0);
        let mut plain = Backoff::for_transport();
        let mut diverged = false;
        for _ in 0..8 {
            let base = plain.step();
            let (da, db, da2) = (a.step(), b.step(), a2.step());
            // Jitter only ever stretches, bounded by 1.5x the base step.
            let cap = base * 3 / 2 + Duration::from_nanos(1);
            assert!(da >= base && da < cap, "{da:?} vs {base:?}");
            assert!(db >= base && db < cap, "{db:?} vs {base:?}");
            // Same salt replays the same schedule (chaos determinism).
            assert_eq!(da, da2);
            diverged |= da != db;
        }
        assert!(diverged, "distinct salts must desynchronize the schedules");
    }

    #[test]
    fn retry_with_backoff_counts_retries_and_respects_deadline() {
        let mut tries = 0;
        let mut retries = 0;
        let got = retry_with_backoff(
            Instant::now() + Duration::from_secs(5),
            &mut Backoff::new(Duration::from_micros(100), Duration::from_millis(1)),
            || retries += 1,
            || {
                tries += 1;
                Ok(if tries == 3 { Some(99) } else { None })
            },
        )
        .unwrap();
        assert_eq!((got, tries, retries), (99, 3, 2));

        // Exhausted deadline → Timeout.
        let err: Result<(), _> = retry_with_backoff(
            Instant::now() + Duration::from_millis(10),
            &mut Backoff::for_transport(),
            || {},
            || Ok(None),
        );
        assert!(err.unwrap_err().is_timeout());

        // Hard failure aborts immediately without retrying.
        let mut tries = 0;
        let err: Result<(), _> = retry_with_backoff(
            Instant::now() + Duration::from_secs(5),
            &mut Backoff::for_transport(),
            || {},
            || {
                tries += 1;
                Err(MailboxError::Disconnected("gone".into()))
            },
        );
        assert!(matches!(err.unwrap_err(), MailboxError::Disconnected(_)));
        assert_eq!(tries, 1);
    }
}
