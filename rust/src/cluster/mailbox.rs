//! Typed point-to-point messaging between simulated workers, with every
//! transfer charged to the [`super::Fabric`].

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::Fabric;

/// Types that know their serialized wire size (for fabric accounting —
/// messages travel in-process, but the byte counts drive the cluster
/// traffic analysis in EXPERIMENTS.md).
pub trait Payload: Send {
    fn wire_bytes(&self) -> u64;
}

impl Payload for Vec<f32> {
    fn wire_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for Vec<u8> {
    fn wire_bytes(&self) -> u64 {
        self.len() as u64
    }
}

impl Payload for crate::sampler::Subgraph {
    fn wire_bytes(&self) -> u64 {
        self.encoded_len() as u64
    }
}

impl<T: Payload> Payload for Option<T> {
    fn wire_bytes(&self) -> u64 {
        // 1-byte tag + payload
        1 + self.as_ref().map(|t| t.wire_bytes()).unwrap_or(0)
    }
}

/// All-to-all endpoints for `n` workers: `Endpoints::new(n)` returns one
/// [`Endpoint`] per worker, each able to send to any rank and receive its
/// own mail. Dropping an endpoint closes its senders (receivers observe
/// disconnection).
pub struct Endpoints<M: Payload> {
    pub endpoints: Vec<Endpoint<M>>,
}

pub struct Endpoint<M: Payload> {
    pub rank: usize,
    fabric: Fabric,
    txs: Vec<Sender<(usize, M)>>,
    rx: Receiver<(usize, M)>,
}

impl<M: Payload> Endpoints<M> {
    pub fn new(n: usize, fabric: &Fabric) -> Self {
        assert_eq!(n, fabric.workers());
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint { rank, fabric: fabric.clone(), txs: txs.clone(), rx })
            .collect();
        Self { endpoints }
    }

    /// Take all endpoints (for distributing to worker threads).
    pub fn into_vec(self) -> Vec<Endpoint<M>> {
        self.endpoints
    }
}

impl<M: Payload> Endpoint<M> {
    /// Send `msg` to `dst`, charging the fabric. Sending to self is
    /// allowed and charged at zero bytes (local handoff).
    pub fn send(&self, dst: usize, msg: M) -> anyhow::Result<()> {
        if dst != self.rank {
            self.fabric.charge(self.rank, dst, msg.wire_bytes());
        }
        self.txs[dst]
            .send((self.rank, msg))
            .map_err(|_| anyhow::anyhow!("worker {dst} mailbox closed"))
    }

    /// Blocking receive: (source rank, message).
    pub fn recv(&self) -> anyhow::Result<(usize, M)> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("all senders to {} closed", self.rank))
    }

    /// Receive with timeout, `Ok(None)` on timeout.
    pub fn recv_timeout(&self, d: Duration) -> anyhow::Result<Option<(usize, M)>> {
        match self.rx.recv_timeout(d) {
            Ok(v) => Ok(Some(v)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow::anyhow!("all senders to {} closed", self.rank))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery_and_accounting() {
        let fabric = Fabric::new(3);
        let eps = Endpoints::<Vec<f32>>::new(3, &fabric).into_vec();
        std::thread::scope(|s| {
            let mut it = eps.into_iter();
            let (e0, e1, e2) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            s.spawn(move || {
                e0.send(1, vec![1.0, 2.0]).unwrap();
                e0.send(2, vec![3.0]).unwrap();
            });
            s.spawn(move || {
                let (src, m) = e1.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(m, vec![1.0, 2.0]);
            });
            s.spawn(move || {
                let (src, m) = e2.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(m, vec![3.0]);
            });
        });
        let st = fabric.stats();
        assert_eq!(st.total_bytes, 8 + 4);
        assert_eq!(st.total_messages, 2);
    }

    #[test]
    fn self_send_is_free() {
        let fabric = Fabric::new(1);
        let eps = Endpoints::<Vec<u8>>::new(1, &fabric).into_vec();
        eps[0].send(0, vec![9; 100]).unwrap();
        let (src, m) = eps[0].recv().unwrap();
        assert_eq!((src, m.len()), (0, 100));
        assert_eq!(fabric.stats().total_bytes, 0);
    }

    #[test]
    fn timeout_returns_none() {
        let fabric = Fabric::new(2);
        let eps = Endpoints::<Vec<u8>>::new(2, &fabric).into_vec();
        let got = eps[1].recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_none());
        drop(eps);
    }
}
