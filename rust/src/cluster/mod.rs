//! Simulated worker cluster.
//!
//! Stands in for the paper's 256-container Docker cluster (DESIGN.md §2):
//! workers are OS threads, links are channels, and every transfer is
//! accounted on a [`fabric::Fabric`] (bytes + messages, with an optional
//! bandwidth/latency cost model for what-if analysis). The collective
//! operations used by training — ring/tree AllReduce — live in
//! [`collective`].
//!
//! [`proc`] layers a *real* multi-process transport on top: worker
//! processes over Unix-domain sockets with heartbeat liveness and
//! stale-wave recovery, byte-identical to the in-process path.

pub mod collective;
pub mod costmodel;
pub mod fabric;
pub mod mailbox;
pub mod proc;

pub use costmodel::{CostModel, SimBreakdown, WorkLedger, WorkUnits};
pub use fabric::{Fabric, FabricStats};
pub use mailbox::{Backoff, Endpoints, MailboxError, Payload};
