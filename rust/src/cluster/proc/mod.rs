//! Real multi-process distributed execution (PR 9).
//!
//! This layer promotes the simulated [`crate::cluster::Fabric`] into an
//! actual multi-process transport: a coordinator process spawns N
//! `gg-worker` processes, each of which deterministically rebuilds the
//! graph + balance table from a shared `config.json` and pulls wave
//! indices over a Unix-domain socket, returning encoded subgraph bytes.
//!
//! Module map:
//! - [`wire`] — CRC-checked length-prefixed framed messages over Unix
//!   sockets, with connect/send retry, exponential backoff and per-op
//!   deadlines (the retry machinery is [`crate::cluster::mailbox`]'s,
//!   shared with the in-process transport).
//! - [`heartbeat`] — per-process heartbeat files + content-based lease
//!   monitoring on a monotonic clock (fold-style liveness).
//! - [`ledger`] — the durable wave-ownership ledger that makes a killed
//!   worker's in-flight waves detectable as stale and reclaimable, with
//!   recovery markers and checkpoint-time compaction.
//! - [`checkpoint`] — atomic binary coordinator checkpoints under the
//!   run directory; a SIGKILLed coordinator relaunched with `--resume`
//!   finishes byte-identically to an uninterrupted run (PR 10).
//! - [`coordinator`] — spawn/assign/reorder/recover; emits waves FIFO so
//!   the multi-process run is byte-identical to the single-process
//!   oracle; respawns lost workers under a bounded budget and
//!   checkpoints/restarts itself.
//! - [`worker`] — the `gg-worker` process body: reconnects and resends
//!   across torn or corrupt connections and coordinator restarts.
//! - [`chaos`] — seeded deterministic fault injection (worker kills,
//!   wave stalls, frame corruption, heartbeat delays).
//!
//! The single-process path remains the deterministic oracle: same
//! subgraph bytes, same loss curve, at any process count, under any
//! chaos schedule.

pub mod chaos;
pub mod checkpoint;
pub mod coordinator;
pub mod heartbeat;
pub mod ledger;
pub mod wire;
pub mod worker;

pub use checkpoint::{Checkpoint, ConsumerCut};
pub use coordinator::{
    run_coordinator, run_coordinator_with, DistOptions, DistPlan, DistReport, SnapshotFn,
    WaveBytes,
};
pub use worker::{worker_main, EXIT_COORDINATOR_LOST, EXIT_OK, EXIT_PLAN_MISMATCH};
