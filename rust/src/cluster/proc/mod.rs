//! Real multi-process distributed execution (PR 9).
//!
//! This layer promotes the simulated [`crate::cluster::Fabric`] into an
//! actual multi-process transport: a coordinator process spawns N
//! `gg-worker` processes, each of which deterministically rebuilds the
//! graph + balance table from a shared `config.json` and pulls wave
//! indices over a Unix-domain socket, returning encoded subgraph bytes.
//!
//! Module map:
//! - [`wire`] — length-prefixed framed messages over Unix sockets, with
//!   connect/send retry, exponential backoff and per-op deadlines (the
//!   retry machinery is [`crate::cluster::mailbox`]'s, shared with the
//!   in-process transport).
//! - [`heartbeat`] — per-process heartbeat files + content-based lease
//!   monitoring (fold-style liveness).
//! - [`ledger`] — the durable wave-ownership ledger that makes a killed
//!   worker's in-flight waves detectable as stale and reclaimable.
//! - [`coordinator`] — spawn/assign/reorder/recover; emits waves FIFO so
//!   the multi-process run is byte-identical to the single-process
//!   oracle.
//! - [`worker`] — the `gg-worker` process body.
//!
//! The single-process path remains the deterministic oracle: same
//! subgraph bytes, same loss curve, at any process count.

pub mod coordinator;
pub mod heartbeat;
pub mod ledger;
pub mod wire;
pub mod worker;

pub use coordinator::{run_coordinator, DistOptions, DistPlan, DistReport, WaveBytes};
pub use worker::{worker_main, EXIT_COORDINATOR_LOST, EXIT_OK, EXIT_PLAN_MISMATCH};
