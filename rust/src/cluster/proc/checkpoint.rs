//! Durable coordinator checkpoints.
//!
//! A checkpoint is one little-endian binary file, `checkpoint.bin` in
//! the run directory, written atomically (tmp + rename) every
//! `--checkpoint-waves` emitted waves. Layout:
//!
//! ```text
//! magic    u64   "GGCKPT01"
//! seq      u64   checkpoint sequence number (monotonic within a run)
//! table_hash, config_hash, total_waves          u64 × 3   plan identity
//! next_emit                                     u64       coordinator emission frontier
//! resume_wave, skip_subgraphs, emitted_bytes    u64 × 3   consumer cut (see below)
//! subgraphs, sampled_nodes, result_bytes        u64 × 3   report counters at the cut
//! workers_lost, waves_reclaimed, heartbeats_missed,
//! checkpoints_written, coordinator_resumes,
//! workers_respawned, frames_corrupted           u64 × 7   recovery counters
//! waves_by_rank                                 u64 len + u64 × len
//! payload                                       u64 len + bytes (opaque consumer state)
//! crc32 of everything above                     u32
//! ```
//!
//! The **consumer cut** decouples the coordinator's emission frontier
//! from how far the consumer has durably absorbed the stream: a byte
//! dump absorbs instantly (`resume_wave == next_emit`, truncate the
//! file to `emitted_bytes` and append), while the training pipeline
//! cuts at its last completed iteration — `resume_wave` is the wave
//! holding that iteration's next subgraph and `skip_subgraphs` how far
//! into it the trainer already was; the `payload` carries the
//! serialized [`crate::train::TrainState`]. Regeneration is
//! deterministic, so re-emitting from the cut reproduces the exact
//! bytes the crashed run would have produced.

use std::path::{Path, PathBuf};

use crate::util::crc32::crc32;

const MAGIC: u64 = 0x3130_5450_4b43_4747; // "GGCKPT01" little-endian

/// Typed decode failures: recovery must distinguish "no checkpoint yet"
/// (fresh start) from "checkpoint exists but cannot be trusted" (abort
/// loudly rather than regenerate divergent state).
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum CheckpointError {
    #[error("checkpoint truncated at byte {0}")]
    Truncated(usize),
    #[error("bad checkpoint magic {0:#018x}")]
    BadMagic(u64),
    #[error("checkpoint CRC mismatch: stored {stored:#010x}, computed {computed:#010x}")]
    CrcMismatch { stored: u32, computed: u32 },
}

/// What the consumer of the emitted wave stream wants persisted at a
/// checkpoint — see the module docs for the two concrete consumers.
#[derive(Debug, Clone, Default)]
pub struct ConsumerCut {
    pub resume_wave: u64,
    pub skip_subgraphs: u64,
    pub emitted_bytes: u64,
    pub payload: Vec<u8>,
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Checkpoint {
    pub seq: u64,
    pub table_hash: u64,
    pub config_hash: u64,
    pub total_waves: u64,
    pub next_emit: u64,
    pub resume_wave: u64,
    pub skip_subgraphs: u64,
    pub emitted_bytes: u64,
    pub subgraphs: u64,
    pub sampled_nodes: u64,
    pub result_bytes: u64,
    pub workers_lost: u64,
    pub waves_reclaimed: u64,
    pub heartbeats_missed: u64,
    pub checkpoints_written: u64,
    pub coordinator_resumes: u64,
    pub workers_respawned: u64,
    pub frames_corrupted: u64,
    pub waves_by_rank: Vec<u64>,
    pub payload: Vec<u8>,
}

impl Checkpoint {
    pub fn path(run_dir: &Path) -> PathBuf {
        run_dir.join("checkpoint.bin")
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(200 + self.waves_by_rank.len() * 8 + self.payload.len());
        let mut w = |v: u64| out_extend(&mut out, v);
        w(MAGIC);
        w(self.seq);
        w(self.table_hash);
        w(self.config_hash);
        w(self.total_waves);
        w(self.next_emit);
        w(self.resume_wave);
        w(self.skip_subgraphs);
        w(self.emitted_bytes);
        w(self.subgraphs);
        w(self.sampled_nodes);
        w(self.result_bytes);
        w(self.workers_lost);
        w(self.waves_reclaimed);
        w(self.heartbeats_missed);
        w(self.checkpoints_written);
        w(self.coordinator_resumes);
        w(self.workers_respawned);
        w(self.frames_corrupted);
        w(self.waves_by_rank.len() as u64);
        for &v in &self.waves_by_rank {
            out_extend(&mut out, v);
        }
        out_extend(&mut out, self.payload.len() as u64);
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Self, CheckpointError> {
        if buf.len() < 4 {
            return Err(CheckpointError::Truncated(buf.len()));
        }
        let (body, tail) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes(tail.try_into().unwrap());
        let computed = crc32(body);
        if stored != computed {
            return Err(CheckpointError::CrcMismatch { stored, computed });
        }
        let mut pos = 0usize;
        let mut r = || -> Result<u64, CheckpointError> {
            let s = body.get(pos..pos + 8).ok_or(CheckpointError::Truncated(pos))?;
            pos += 8;
            Ok(u64::from_le_bytes(s.try_into().unwrap()))
        };
        let magic = r()?;
        if magic != MAGIC {
            return Err(CheckpointError::BadMagic(magic));
        }
        let mut c = Checkpoint {
            seq: r()?,
            table_hash: r()?,
            config_hash: r()?,
            total_waves: r()?,
            next_emit: r()?,
            resume_wave: r()?,
            skip_subgraphs: r()?,
            emitted_bytes: r()?,
            subgraphs: r()?,
            sampled_nodes: r()?,
            result_bytes: r()?,
            workers_lost: r()?,
            waves_reclaimed: r()?,
            heartbeats_missed: r()?,
            checkpoints_written: r()?,
            coordinator_resumes: r()?,
            workers_respawned: r()?,
            frames_corrupted: r()?,
            ..Default::default()
        };
        let n = r()? as usize;
        c.waves_by_rank.reserve(n);
        for _ in 0..n {
            c.waves_by_rank.push(r()?);
        }
        let plen = r()? as usize;
        let payload =
            body.get(pos..pos + plen).ok_or(CheckpointError::Truncated(pos))?.to_vec();
        pos += plen;
        if pos != body.len() {
            return Err(CheckpointError::Truncated(pos));
        }
        c.payload = payload;
        Ok(c)
    }

    /// Atomic persist: a crash mid-write leaves the previous checkpoint
    /// intact, never a half-written file.
    pub fn save(&self, run_dir: &Path) -> anyhow::Result<()> {
        let path = Self::path(run_dir);
        let tmp = path.with_extension("bin.tmp");
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// `Ok(None)` when no checkpoint exists (resume of a run that never
    /// reached its first checkpoint falls back to a fresh start).
    pub fn load(run_dir: &Path) -> anyhow::Result<Option<Self>> {
        let path = Self::path(run_dir);
        if !path.exists() {
            return Ok(None);
        }
        let buf = std::fs::read(&path)?;
        Ok(Some(Self::decode(&buf).map_err(|e| {
            anyhow::anyhow!("{e} (in {}; delete it to restart from scratch)", path.display())
        })?))
    }
}

fn out_extend(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            seq: 3,
            table_hash: 0xfeed_beef,
            config_hash: 42,
            total_waves: 16,
            next_emit: 8,
            resume_wave: 7,
            skip_subgraphs: 5,
            emitted_bytes: 12345,
            subgraphs: 224,
            sampled_nodes: 9001,
            result_bytes: 99999,
            workers_lost: 1,
            waves_reclaimed: 2,
            heartbeats_missed: 3,
            checkpoints_written: 3,
            coordinator_resumes: 1,
            workers_respawned: 2,
            frames_corrupted: 4,
            waves_by_rank: vec![3, 2, 3],
            payload: vec![9, 8, 7, 6],
        }
    }

    #[test]
    fn roundtrips_exactly() {
        let c = sample();
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
        let empty = Checkpoint::default();
        assert_eq!(Checkpoint::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn corruption_is_typed() {
        let c = sample();
        let mut buf = c.encode();
        // Flip one byte anywhere → CRC mismatch.
        buf[20] ^= 1;
        assert!(matches!(
            Checkpoint::decode(&buf).unwrap_err(),
            CheckpointError::CrcMismatch { .. }
        ));
        // Truncation.
        let buf = c.encode();
        assert!(matches!(
            Checkpoint::decode(&buf[..2]).unwrap_err(),
            CheckpointError::Truncated(_)
        ));
        // Wrong magic with a valid CRC.
        let mut body = c.encode();
        body.truncate(body.len() - 4);
        body[0] ^= 0xFF;
        let crc = crate::util::crc32::crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        assert!(matches!(Checkpoint::decode(&body).unwrap_err(), CheckpointError::BadMagic(_)));
    }

    #[test]
    fn save_load_is_atomic_per_directory() {
        let dir = std::env::temp_dir().join(format!("gg-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Checkpoint::load(&dir).unwrap().is_none());
        let c = sample();
        c.save(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir).unwrap().unwrap(), c);
        // Overwrite with a later checkpoint; loader sees only the newest.
        let mut c2 = c.clone();
        c2.seq = 4;
        c2.next_emit = 12;
        c2.save(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir).unwrap().unwrap().seq, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
