//! Heartbeat files and lease monitoring (fold-style liveness).
//!
//! Every process of a distributed run — each worker and the coordinator —
//! runs a [`HeartbeatWriter`] thread that bumps a monotonically
//! increasing beat counter into a file in the shared run directory (an
//! atomic tmp-file + rename, so readers never see a torn write). Peers
//! watch each other with a [`LeaseMonitor`]: staleness is decided by the
//! *content* not advancing for a whole lease — never by mtime, which
//! filesystems round coarsely and `utimes` can forge — so a SIGKILLed
//! process goes stale within one lease no matter what the file metadata
//! says.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The clock every lease/heartbeat decision reads: `Instant` is
/// monotonic (CLOCK_MONOTONIC on Linux), so an NTP step or an operator
/// setting the wall clock back can never spuriously expire a lease or
/// keep a dead peer "alive". Centralized here so the invariant is
/// auditable at the call sites instead of implied.
pub fn mono_now() -> Instant {
    Instant::now()
}

/// Write `content` so readers observe either the old or the new value,
/// never a partial line.
pub fn write_atomic(path: &Path, content: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, content)?;
    std::fs::rename(&tmp, path)
}

/// Background thread bumping `<beat> <pid>` into `path` every `period`.
/// Stops (and removes nothing — the last beat stays as evidence) when
/// dropped.
pub struct HeartbeatWriter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HeartbeatWriter {
    pub fn start(path: PathBuf, period: Duration) -> Self {
        Self::start_with_pause(path, period, None)
    }

    /// Like [`Self::start`], with an injected one-shot delay: before
    /// writing beat number `pause.0`, the writer freezes for `pause.1`.
    /// This is the chaos harness's "heartbeat delay" fault — a pause
    /// longer than the lease makes a perfectly healthy worker *look*
    /// dead, driving the coordinator's false-positive recovery path.
    pub fn start_with_pause(
        path: PathBuf,
        period: Duration,
        pause: Option<(u64, Duration)>,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("gg-heartbeat".into())
            .spawn(move || {
                let pid = std::process::id();
                let mut beat = 0u64;
                while !stop2.load(Ordering::Relaxed) {
                    beat += 1;
                    if let Some((at, delay)) = pause {
                        if beat == at {
                            let frozen_until = mono_now() + delay;
                            while !stop2.load(Ordering::Relaxed) && mono_now() < frozen_until {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                    // A full disk or vanished run dir must not kill the
                    // process that is trying to prove it is alive; the
                    // peer's lease expiring is the designed consequence.
                    let _ = write_atomic(&path, &format!("{beat} {pid}\n"));
                    // Sleep in slices so drop() never waits a full period.
                    let deadline = mono_now() + period;
                    while !stop2.load(Ordering::Relaxed) && mono_now() < deadline {
                        std::thread::sleep(Duration::from_millis(10).min(period));
                    }
                }
            })
            .expect("spawn heartbeat thread");
        Self { stop, handle: Some(handle) }
    }
}

impl Drop for HeartbeatWriter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Lease verdict for one peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lease {
    Alive,
    /// The beat has not advanced within the lease; `idle` is how long
    /// since the last observed change.
    Stale { idle: Duration },
}

impl Lease {
    pub fn is_stale(&self) -> bool {
        matches!(self, Lease::Stale { .. })
    }
}

/// Content-based staleness watcher over one heartbeat file. A missing
/// file counts as "not yet advanced": the monitor's construction time
/// starts the grace period, so a peer that never writes a single beat
/// still expires after one lease.
pub struct LeaseMonitor {
    path: PathBuf,
    lease: Duration,
    last_seen: Option<String>,
    last_change: Instant,
}

impl LeaseMonitor {
    pub fn new(path: PathBuf, lease: Duration) -> Self {
        Self { path, lease, last_seen: None, last_change: mono_now() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn check(&mut self) -> Lease {
        let current = std::fs::read_to_string(&self.path).ok();
        if current.is_some() && current != self.last_seen {
            self.last_seen = current;
            self.last_change = mono_now();
            return Lease::Alive;
        }
        let idle = self.last_change.elapsed();
        if idle > self.lease {
            Lease::Stale { idle }
        } else {
            Lease::Alive
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gg-hb-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writer_bumps_the_beat_and_monitor_stays_alive() {
        let d = dir("alive");
        let path = d.join("hb");
        let mut mon = LeaseMonitor::new(path.clone(), Duration::from_millis(300));
        let _writer = HeartbeatWriter::start(path.clone(), Duration::from_millis(20));
        let deadline = Instant::now() + Duration::from_secs(5);
        while std::fs::read_to_string(&path).is_err() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let first = std::fs::read_to_string(&path).unwrap();
        assert_eq!(mon.check(), Lease::Alive);
        // The beat advances.
        let deadline = Instant::now() + Duration::from_secs(5);
        while std::fs::read_to_string(&path).unwrap() == first && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_ne!(std::fs::read_to_string(&path).unwrap(), first);
        assert_eq!(mon.check(), Lease::Alive);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn stopped_writer_goes_stale_within_one_lease() {
        let d = dir("stale");
        let path = d.join("hb");
        {
            let _writer = HeartbeatWriter::start(path.clone(), Duration::from_millis(10));
            std::thread::sleep(Duration::from_millis(50));
        } // writer dropped — the process "died"
        let mut mon = LeaseMonitor::new(path.clone(), Duration::from_millis(80));
        assert_eq!(mon.check(), Lease::Alive); // first read observes the last beat
        std::thread::sleep(Duration::from_millis(150));
        assert!(mon.check().is_stale(), "beat frozen past the lease must be stale");
        // Revival: a fresh beat flips it back to alive.
        write_atomic(&path, "999999 1\n").unwrap();
        assert_eq!(mon.check(), Lease::Alive);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn injected_pause_freezes_the_beat_past_a_lease_then_revives() {
        let d = dir("pause");
        let path = d.join("hb");
        // Freeze for 300 ms before beat 3: a 100 ms lease must observe
        // staleness, then the resumed beat flips it back to alive.
        let _writer = HeartbeatWriter::start_with_pause(
            path.clone(),
            Duration::from_millis(20),
            Some((3, Duration::from_millis(300))),
        );
        let mut mon = LeaseMonitor::new(path.clone(), Duration::from_millis(100));
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut went_stale = false;
        while Instant::now() < deadline {
            if mon.check().is_stale() {
                went_stale = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(went_stale, "a paused heartbeat must expire its lease");
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut revived = false;
        while Instant::now() < deadline {
            if mon.check() == Lease::Alive {
                revived = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(revived, "the beat resumes after the injected pause");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_file_expires_after_grace() {
        let d = dir("missing");
        let mut mon = LeaseMonitor::new(d.join("never-written"), Duration::from_millis(60));
        assert_eq!(mon.check(), Lease::Alive);
        std::thread::sleep(Duration::from_millis(120));
        assert!(mon.check().is_stale());
        let _ = std::fs::remove_dir_all(&d);
    }
}
