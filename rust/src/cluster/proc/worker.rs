//! Worker-process side of a distributed run (the `gg-worker` subcommand).
//!
//! A worker owns its whole working set locally: it rebuilds the graph,
//! the feature-era seed list and the balance table deterministically from
//! the shared `config.json` — nothing positional travels on the wire
//! except wave *indices* — then pulls waves from the coordinator and
//! returns their encoded subgraphs. Liveness is symmetric: the worker
//! heartbeats `hb-worker-<rank>` for the coordinator's lease sweep, and
//! watches `hb-coordinator` itself so a dead coordinator means a prompt
//! clean exit (exit code [`EXIT_COORDINATOR_LOST`]) instead of a hang.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::mailbox::MailboxError;
use crate::cluster::{Fabric, WorkLedger};
use crate::config::RunConfig;
use crate::engines::common::{generate_wave, plan_waves, table_hash, ScratchArena};
use crate::engines::hop_fn_by_name;

use super::heartbeat::{HeartbeatWriter, LeaseMonitor};
use super::wire::{FramedStream, Msg};

/// Worker exit codes (the coordinator logs them; tests assert on them).
pub const EXIT_OK: i32 = 0;
pub const EXIT_PLAN_MISMATCH: i32 = 2;
pub const EXIT_COORDINATOR_LOST: i32 = 3;

/// Test-only fault hook: sleep this many milliseconds inside every wave,
/// so a SIGKILL injected "mid-wave" deterministically lands mid-wave.
pub const FAULT_SLOW_WAVE_ENV: &str = "GG_FAULT_SLOW_WAVE_MS";

/// Run one worker to completion. Returns the process exit code.
pub fn worker_main(run_dir: &Path, rank: u32) -> Result<i32> {
    let cfg = RunConfig::from_json_file(&run_dir.join("config.json"))
        .context("worker: load shared config")?;
    let ecfg = cfg.engine_config()?;
    let hop = hop_fn_by_name(&cfg.engine)?;
    let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(10));
    let lease = Duration::from_millis(cfg.lease_ms.max(cfg.heartbeat_ms * 2).max(100));
    let op_deadline = Duration::from_millis(cfg.op_deadline_ms.max(100));
    let slow_wave = std::env::var(FAULT_SLOW_WAVE_ENV)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis);

    // Deterministic local rebuild of the whole plan.
    let g = crate::graph::generator::from_spec(&cfg.graph, cfg.graph_seed)?.csr();
    let seeds = cfg.seeds(g.num_nodes());
    let (table, wave_ranges) = plan_waves(&seeds, &ecfg);
    let my_hash = table_hash(&table);

    // Prove liveness before connecting: the lease clock starts at spawn.
    let _hb = HeartbeatWriter::start(run_dir.join(format!("hb-worker-{rank}")), heartbeat);
    let mut coord = LeaseMonitor::new(run_dir.join("hb-coordinator"), lease);

    let socket = std::fs::read_to_string(run_dir.join("socket"))
        .context("worker: read socket path")?;
    let mut stream = FramedStream::connect(
        Path::new(socket.trim()),
        op_deadline,
        Instant::now() + op_deadline,
    )
    .map_err(|e| anyhow::anyhow!("worker {rank}: connect: {e}"))?;

    stream.send(&Msg::Hello { rank }).map_err(|e| anyhow::anyhow!("hello: {e}"))?;
    match recv_alive(&mut stream, &mut coord, heartbeat)? {
        Reply::Msg(Msg::Plan { waves, table_hash: their_hash }) => {
            if waves != wave_ranges.len() as u64 || their_hash != my_hash {
                // Diverged plan → generating anything would produce wrong
                // bytes. Tell the coordinator and stop.
                let _ = stream.send(&Msg::Abort {
                    reason: format!(
                        "plan mismatch: coordinator ({waves} waves, {their_hash:016x}) vs \
                         worker {rank} ({} waves, {my_hash:016x})",
                        wave_ranges.len()
                    ),
                });
                return Ok(EXIT_PLAN_MISMATCH);
            }
        }
        Reply::Msg(Msg::Abort { reason }) => {
            log::warn!("worker {rank}: coordinator aborted: {reason}");
            return Ok(EXIT_PLAN_MISMATCH);
        }
        Reply::Msg(other) => anyhow::bail!("worker {rank}: expected Plan, got {other:?}"),
        Reply::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
    }

    // Local generation state, reused across waves exactly like the
    // in-process engines reuse it across the wave loop.
    let fabric = Fabric::new(ecfg.workers);
    let mut work_ledger = WorkLedger::new(ecfg.workers);
    let mut scratch = ScratchArena::default();
    let mut first_wave = true;
    let mut bytes = Vec::new();

    loop {
        // A send failing with a disconnect is the coordinator dying, not
        // a worker bug — exit cleanly the same way the recv path does.
        if stream.send(&Msg::WaveRequest { rank }).is_err() {
            return Ok(EXIT_COORDINATOR_LOST);
        }
        let reply = match recv_alive(&mut stream, &mut coord, heartbeat)? {
            Reply::Msg(m) => m,
            Reply::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
        };
        match reply {
            Msg::WaveAssign { wave } => {
                let range = wave_ranges
                    .get(wave as usize)
                    .cloned()
                    .with_context(|| format!("worker {rank}: wave {wave} out of range"))?;
                if let Some(d) = slow_wave {
                    std::thread::sleep(d);
                }
                let slots = generate_wave(
                    &g,
                    &table,
                    range,
                    &ecfg,
                    hop,
                    &fabric,
                    &mut work_ledger,
                    &mut scratch,
                );
                if first_wave {
                    scratch.mark_warm();
                    first_wave = false;
                }
                bytes.clear();
                let (mut subgraphs, mut nodes) = (0u64, 0u64);
                for (_worker, sg) in slots.into_subgraphs() {
                    subgraphs += 1;
                    nodes += sg.num_nodes();
                    sg.encode_into(&mut bytes);
                }
                let result = Msg::WaveResult {
                    rank,
                    wave,
                    subgraphs,
                    nodes,
                    bytes: std::mem::take(&mut bytes),
                };
                if stream.send(&result).is_err() {
                    return Ok(EXIT_COORDINATOR_LOST);
                }
            }
            Msg::Done => return Ok(EXIT_OK),
            Msg::Abort { reason } => {
                log::warn!("worker {rank}: coordinator aborted: {reason}");
                return Ok(EXIT_PLAN_MISMATCH);
            }
            other => anyhow::bail!("worker {rank}: unexpected message {other:?}"),
        }
    }
}

enum Reply {
    Msg(Msg),
    CoordinatorLost,
}

/// Receive the next message, interleaving coordinator-liveness checks on
/// every idle poll slice: socket EOF *or* a frozen `hb-coordinator` beat
/// both resolve to `CoordinatorLost` so the worker exits within its
/// lease instead of hanging on a silent peer.
fn recv_alive(
    stream: &mut FramedStream,
    coord: &mut LeaseMonitor,
    poll: Duration,
) -> Result<Reply> {
    loop {
        match stream.recv(Instant::now() + poll.max(Duration::from_millis(20))) {
            Ok(m) => return Ok(Reply::Msg(m)),
            Err(MailboxError::Timeout(_)) => {
                if coord.check().is_stale() {
                    log::warn!("coordinator heartbeat stale; exiting");
                    return Ok(Reply::CoordinatorLost);
                }
            }
            Err(MailboxError::Disconnected(e)) => {
                log::warn!("coordinator connection lost ({e}); exiting");
                return Ok(Reply::CoordinatorLost);
            }
        }
    }
}
