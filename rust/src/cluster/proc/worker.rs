//! Worker-process side of a distributed run (the `gg-worker` subcommand).
//!
//! A worker owns its whole working set locally: it rebuilds the graph,
//! the feature-era seed list and the balance table deterministically from
//! the shared `config.json` — nothing positional travels on the wire
//! except wave *indices* — then pulls waves from the coordinator and
//! returns their encoded subgraphs. Liveness is symmetric: the worker
//! heartbeats `hb-worker-<rank>` for the coordinator's lease sweep, and
//! watches `hb-coordinator` itself so a dead coordinator means a prompt
//! clean exit (exit code [`EXIT_COORDINATOR_LOST`]) instead of a hang.
//!
//! ## Torn connections vs dead coordinator
//!
//! A connection can tear without anybody dying: the coordinator shuts
//! sockets whose frames fail CRC, and a restarting coordinator binds a
//! fresh socket. The worker therefore treats EOF as *detached, not
//! doomed*: while `hb-coordinator` keeps beating it re-reads the socket
//! pointer file (a resumed coordinator rewrites it), reconnects with
//! jittered backoff, re-verifies the plan, and resends its last
//! unacknowledged `WaveResult` — the coordinator absorbs duplicates
//! idempotently because regeneration is byte-identical. Only a frozen
//! coordinator heartbeat ends the worker.
//!
//! ## Chaos
//!
//! With a nonzero chaos seed (config `chaos` key or `GG_CHAOS_SEED`),
//! the worker injects its own faults from the seeded schedule
//! ([`super::chaos::Chaos`]): wave stalls, `abort()` before a result
//! (the coordinator must reclaim + respawn), one corrupted result frame
//! per drawn wave (the coordinator's CRC must reject it and this
//! worker must recover via reconnect + resend), and a one-shot
//! heartbeat freeze past the lease (false-positive recovery).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::mailbox::{Backoff, MailboxError};
use crate::cluster::{Fabric, WorkLedger};
use crate::config::RunConfig;
use crate::engines::common::{generate_wave, plan_waves, table_hash, ScratchArena};
use crate::engines::hop_fn_by_name;
use crate::util::fxhash::FxHashSet;

use super::chaos::Chaos;
use super::heartbeat::{HeartbeatWriter, LeaseMonitor};
use super::wire::{FramedStream, Msg};

/// Worker exit codes (the coordinator logs them; tests assert on them).
pub const EXIT_OK: i32 = 0;
pub const EXIT_PLAN_MISMATCH: i32 = 2;
pub const EXIT_COORDINATOR_LOST: i32 = 3;

/// Test-only fault hook: sleep this many milliseconds inside every wave,
/// so a SIGKILL injected "mid-wave" deterministically lands mid-wave.
pub const FAULT_SLOW_WAVE_ENV: &str = "GG_FAULT_SLOW_WAVE_MS";

/// Run one worker to completion. Returns the process exit code.
pub fn worker_main(run_dir: &Path, rank: u32) -> Result<i32> {
    let cfg = RunConfig::from_json_file(&run_dir.join("config.json"))
        .context("worker: load shared config")?;
    let ecfg = cfg.engine_config()?;
    let hop = hop_fn_by_name(&cfg.engine)?;
    let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(10));
    let lease = Duration::from_millis(cfg.lease_ms.max(cfg.heartbeat_ms * 2).max(100));
    let op_deadline = Duration::from_millis(cfg.op_deadline_ms.max(100));
    let slow_wave = std::env::var(FAULT_SLOW_WAVE_ENV)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis);
    let chaos = Chaos::from_env(cfg.chaos);

    // Deterministic local rebuild of the whole plan.
    let g = crate::graph::generator::from_spec(&cfg.graph, cfg.graph_seed)?.csr();
    let seeds = cfg.seeds(g.num_nodes());
    let (table, wave_ranges) = plan_waves(&seeds, &ecfg);
    let my_hash = table_hash(&table);
    let expect_waves = wave_ranges.len() as u64;

    // Prove liveness before connecting: the lease clock starts at spawn.
    // A drawn chaos heartbeat pause freezes the beat past the lease once,
    // making this healthy worker *look* dead to the coordinator.
    let hb_pause = chaos
        .and_then(|c| c.heartbeat_pause(rank, lease.as_millis() as u64))
        .map(|(beat, ms)| (beat, Duration::from_millis(ms)));
    let _hb = HeartbeatWriter::start_with_pause(
        run_dir.join(format!("hb-worker-{rank}")),
        heartbeat,
        hb_pause,
    );
    let mut coord = LeaseMonitor::new(run_dir.join("hb-coordinator"), lease);

    let session =
        open_session(run_dir, rank, op_deadline, heartbeat, &mut coord, expect_waves, my_hash)?;
    let mut stream = match session {
        Session::Ready(s) => s,
        Session::PlanMismatch => return Ok(EXIT_PLAN_MISMATCH),
        Session::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
    };

    // Local generation state, reused across waves exactly like the
    // in-process engines reuse it across the wave loop.
    let fabric = Fabric::new(ecfg.workers);
    let mut work_ledger = WorkLedger::new(ecfg.workers);
    let mut scratch = ScratchArena::default();
    let mut first_wave = true;
    let mut bytes = Vec::new();
    // The last result whose delivery is unconfirmed — resent once after
    // every reconnect (the coordinator drops duplicates).
    let mut last_result: Option<Msg> = None;
    // Waves whose result frame was already chaos-corrupted once by this
    // process: the resend goes out clean, so recovery terminates.
    let mut corrupted: FxHashSet<u64> = Default::default();

    loop {
        // A failing send is a torn connection, not necessarily a dead
        // coordinator: reattach (which resends `last_result`) and retry.
        if stream.send(&Msg::WaveRequest { rank }).is_err() {
            match reattach(
                run_dir,
                rank,
                op_deadline,
                heartbeat,
                &mut coord,
                expect_waves,
                my_hash,
                last_result.as_ref(),
            )? {
                Session::Ready(s) => stream = s,
                Session::PlanMismatch => return Ok(EXIT_PLAN_MISMATCH),
                Session::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
            }
            continue;
        }
        let reply = match recv_alive(&mut stream, &mut coord, heartbeat)? {
            Reply::Msg(m) => m,
            Reply::Torn => {
                match reattach(
                    run_dir,
                    rank,
                    op_deadline,
                    heartbeat,
                    &mut coord,
                    expect_waves,
                    my_hash,
                    last_result.as_ref(),
                )? {
                    Session::Ready(s) => stream = s,
                    Session::PlanMismatch => return Ok(EXIT_PLAN_MISMATCH),
                    Session::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
                }
                continue;
            }
            Reply::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
        };
        match reply {
            Msg::WaveAssign { wave } => {
                let range = wave_ranges
                    .get(wave as usize)
                    .cloned()
                    .with_context(|| format!("worker {rank}: wave {wave} out of range"))?;
                if let Some(c) = &chaos {
                    if let Some(ms) = c.wave_stall_ms(rank, wave) {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                if let Some(d) = slow_wave {
                    std::thread::sleep(d);
                }
                let slots = generate_wave(
                    &g,
                    &table,
                    range,
                    &ecfg,
                    hop,
                    &fabric,
                    &mut work_ledger,
                    &mut scratch,
                );
                if first_wave {
                    scratch.mark_warm();
                    first_wave = false;
                }
                bytes.clear();
                let (mut subgraphs, mut nodes) = (0u64, 0u64);
                for (_worker, sg) in slots.into_subgraphs() {
                    subgraphs += 1;
                    nodes += sg.num_nodes();
                    sg.encode_into(&mut bytes);
                }
                if let Some(c) = &chaos {
                    // Die with the wave claimed and the result unsent —
                    // the exact window recovery must cover. abort() skips
                    // destructors, so the heartbeat stops like a SIGKILL.
                    if c.kill_before_result(rank, wave) {
                        log::warn!("chaos: worker {rank} aborting before result of wave {wave}");
                        std::process::abort();
                    }
                    if c.corrupt_result(rank, wave) && corrupted.insert(wave) {
                        log::warn!("chaos: worker {rank} corrupting result frame of wave {wave}");
                        stream.corrupt_next_frame();
                    }
                }
                let result = Msg::WaveResult {
                    rank,
                    wave,
                    subgraphs,
                    nodes,
                    bytes: std::mem::take(&mut bytes),
                };
                // Stash before sending: if the send tears (or the frame
                // is rejected by the peer's CRC), the reattach resends it.
                last_result = Some(result);
                if stream.send(last_result.as_ref().unwrap()).is_err() {
                    match reattach(
                        run_dir,
                        rank,
                        op_deadline,
                        heartbeat,
                        &mut coord,
                        expect_waves,
                        my_hash,
                        last_result.as_ref(),
                    )? {
                        Session::Ready(s) => stream = s,
                        Session::PlanMismatch => return Ok(EXIT_PLAN_MISMATCH),
                        Session::CoordinatorLost => return Ok(EXIT_COORDINATOR_LOST),
                    }
                }
            }
            Msg::Done => return Ok(EXIT_OK),
            Msg::Abort { reason } => {
                log::warn!("worker {rank}: coordinator aborted: {reason}");
                return Ok(EXIT_PLAN_MISMATCH);
            }
            other => anyhow::bail!("worker {rank}: unexpected message {other:?}"),
        }
    }
}

enum Session {
    Ready(FramedStream),
    PlanMismatch,
    CoordinatorLost,
}

/// Establish (or re-establish) a verified session: connect to the socket
/// currently named by the run dir's pointer file, `Hello`, and check the
/// coordinator's `Plan` against the locally rebuilt one. Retries with
/// jittered backoff (salted by rank, so a herd of workers reconnecting
/// to a restarted coordinator spreads out) for as long as the
/// coordinator's heartbeat stays fresh.
fn open_session(
    run_dir: &Path,
    rank: u32,
    op_deadline: Duration,
    poll: Duration,
    coord: &mut LeaseMonitor,
    expect_waves: u64,
    my_hash: u64,
) -> Result<Session> {
    let mut backoff = Backoff::for_transport_jittered(rank as u64 + 1);
    loop {
        if coord.check().is_stale() {
            log::warn!("worker {rank}: coordinator heartbeat stale; giving up connecting");
            return Ok(Session::CoordinatorLost);
        }
        // Re-read the socket path every attempt: a resumed coordinator
        // binds a fresh socket and rewrites the pointer file.
        let Ok(socket) = std::fs::read_to_string(run_dir.join("socket")) else {
            std::thread::sleep(backoff.step());
            continue;
        };
        let connect_deadline = Instant::now() + poll.max(Duration::from_millis(100));
        let Ok(mut stream) =
            FramedStream::connect(Path::new(socket.trim()), op_deadline, connect_deadline)
        else {
            std::thread::sleep(backoff.step());
            continue;
        };
        if stream.send(&Msg::Hello { rank }).is_err() {
            std::thread::sleep(backoff.step());
            continue;
        }
        match recv_alive(&mut stream, coord, poll)? {
            Reply::Msg(Msg::Plan { waves, table_hash: their_hash }) => {
                if waves != expect_waves || their_hash != my_hash {
                    // Diverged plan → generating anything would produce
                    // wrong bytes. Tell the coordinator and stop.
                    let _ = stream.send(&Msg::Abort {
                        reason: format!(
                            "plan mismatch: coordinator ({waves} waves, {their_hash:016x}) vs \
                             worker {rank} ({expect_waves} waves, {my_hash:016x})"
                        ),
                    });
                    return Ok(Session::PlanMismatch);
                }
                return Ok(Session::Ready(stream));
            }
            Reply::Msg(Msg::Abort { reason }) => {
                log::warn!("worker {rank}: coordinator aborted: {reason}");
                return Ok(Session::PlanMismatch);
            }
            Reply::Msg(other) => anyhow::bail!("worker {rank}: expected Plan, got {other:?}"),
            Reply::Torn => {
                std::thread::sleep(backoff.step());
                continue;
            }
            Reply::CoordinatorLost => return Ok(Session::CoordinatorLost),
        }
    }
}

/// [`open_session`] + resend of the last unacknowledged result. The
/// resend may race a survivor's regeneration of the same wave — the
/// coordinator deduplicates, and the bytes are identical either way.
#[allow(clippy::too_many_arguments)]
fn reattach(
    run_dir: &Path,
    rank: u32,
    op_deadline: Duration,
    poll: Duration,
    coord: &mut LeaseMonitor,
    expect_waves: u64,
    my_hash: u64,
    last_result: Option<&Msg>,
) -> Result<Session> {
    log::warn!("worker {rank}: connection torn; reconnecting");
    match open_session(run_dir, rank, op_deadline, poll, coord, expect_waves, my_hash)? {
        Session::Ready(mut s) => {
            crate::obs::metrics::counter("cluster.worker_reconnects").inc();
            if let Some(r) = last_result {
                // If this send tears too, the caller's next send fails
                // and lands back here — no progress is lost.
                let _ = s.send(r);
            }
            Ok(Session::Ready(s))
        }
        other => Ok(other),
    }
}

enum Reply {
    Msg(Msg),
    /// The connection is gone (EOF or a corrupt inbound frame) but the
    /// coordinator's heartbeat was fresh at the last check — reconnect.
    Torn,
    CoordinatorLost,
}

/// Receive the next message, interleaving coordinator-liveness checks on
/// every idle poll slice: a frozen `hb-coordinator` beat resolves to
/// `CoordinatorLost` so the worker exits within its lease instead of
/// hanging on a silent peer, while a mere connection tear (EOF, or an
/// inbound frame failing its CRC) resolves to `Torn` for reconnect.
fn recv_alive(
    stream: &mut FramedStream,
    coord: &mut LeaseMonitor,
    poll: Duration,
) -> Result<Reply> {
    loop {
        match stream.recv(Instant::now() + poll.max(Duration::from_millis(20))) {
            Ok(m) => return Ok(Reply::Msg(m)),
            Err(MailboxError::Timeout(_)) => {
                if coord.check().is_stale() {
                    log::warn!("coordinator heartbeat stale; exiting");
                    return Ok(Reply::CoordinatorLost);
                }
            }
            Err(MailboxError::Disconnected(e)) => {
                if coord.check().is_stale() {
                    log::warn!("coordinator connection lost ({e}) and heartbeat stale; exiting");
                    return Ok(Reply::CoordinatorLost);
                }
                log::warn!("connection torn ({e}); will reconnect");
                return Ok(Reply::Torn);
            }
            Err(MailboxError::Corrupt(e)) => {
                // Inbound bytes failed their CRC: this connection cannot
                // be trusted any further in either direction.
                stream.shutdown();
                log::warn!("corrupt inbound frame ({e}); will reconnect");
                return Ok(Reply::Torn);
            }
        }
    }
}
