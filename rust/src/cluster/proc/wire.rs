//! Framed message transport over Unix-domain sockets.
//!
//! Frames are a `u64` little-endian length prefix, a `u32` CRC-32 of the
//! body, then a tag-byte message body — the same fixed-width LE
//! vocabulary as [`Subgraph::encode_into`] (`crate::sampler::Subgraph`),
//! so the whole protocol stays byte-inspectable without a serialization
//! dependency. Failure handling reuses the mailbox vocabulary:
//! [`MailboxError::Timeout`] is transient (retry/poll again),
//! [`MailboxError::Disconnected`] is terminal, and
//! [`MailboxError::Corrupt`] means the bytes arrived but failed their
//! checksum — the connection is untrustworthy and must be re-established
//! (the peer itself may be healthy), counted on
//! `cluster.frames_corrupted`.
//!
//! Robustness contract (ISSUE 9):
//! - **connect**: retried with exponential backoff up to a deadline
//!   (workers may race the coordinator's `bind`);
//! - **send**: position-tracked write loop — a short write never
//!   restarts the frame, so retries cannot duplicate or corrupt bytes —
//!   with backoff between `WouldBlock`/timeout slices, bounded by the
//!   per-op deadline; every backoff step counts `cluster.send_retries`;
//! - **recv**: waiting for the *start* of a frame times out softly (the
//!   caller interleaves liveness checks and polls again), while a stall
//!   *mid-frame* for a whole op-deadline means a half-written peer and is
//!   terminal.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::cluster::mailbox::{retry_with_backoff, Backoff, MailboxError};
use crate::util::crc32::crc32;

/// Hard ceiling on a frame body (4 GiB): anything larger is a corrupt
/// length prefix, not a real message.
pub const MAX_FRAME: u64 = 1 << 32;

/// Frame header bytes: `u64` body length + `u32` CRC-32 of the body.
pub const FRAME_HEADER: usize = 12;

/// The coordinator/worker protocol. One message per frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Msg {
    /// Worker `rank` introduces itself on a fresh connection.
    Hello { rank: u32 },
    /// Coordinator's reply: the wave plan this run executes. Workers
    /// verify both fields against their locally rebuilt plan and abort
    /// on mismatch rather than generate divergent bytes.
    Plan { waves: u64, table_hash: u64 },
    /// Worker asks for its next wave (pull-based assignment: a slow or
    /// dead rank simply stops pulling, and the remaining seed ranges
    /// rebalance onto survivors for free).
    WaveRequest { rank: u32 },
    /// Coordinator assigns wave index `wave` to the requester.
    WaveAssign { wave: u64 },
    /// Worker returns wave `wave`: `bytes` is the concatenation of the
    /// wave's subgraphs in slot order ([`Subgraph::encode_into`]), with
    /// the counts the coordinator's report needs without re-decoding.
    WaveResult { rank: u32, wave: u64, subgraphs: u64, nodes: u64, bytes: Vec<u8> },
    /// No more waves: the worker exits cleanly.
    Done,
    /// Unrecoverable disagreement (plan mismatch); peer should stop.
    Abort { reason: String },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Plan { .. } => 2,
            Msg::WaveRequest { .. } => 3,
            Msg::WaveAssign { .. } => 4,
            Msg::WaveResult { .. } => 5,
            Msg::Done => 6,
            Msg::Abort { .. } => 7,
        }
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        out.push(self.tag());
        match self {
            Msg::Hello { rank } => out.extend_from_slice(&rank.to_le_bytes()),
            Msg::Plan { waves, table_hash } => {
                out.extend_from_slice(&waves.to_le_bytes());
                out.extend_from_slice(&table_hash.to_le_bytes());
            }
            Msg::WaveRequest { rank } => out.extend_from_slice(&rank.to_le_bytes()),
            Msg::WaveAssign { wave } => out.extend_from_slice(&wave.to_le_bytes()),
            Msg::WaveResult { rank, wave, subgraphs, nodes, bytes } => {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&wave.to_le_bytes());
                out.extend_from_slice(&subgraphs.to_le_bytes());
                out.extend_from_slice(&nodes.to_le_bytes());
                out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                out.extend_from_slice(bytes);
            }
            Msg::Done => {}
            Msg::Abort { reason } => {
                let b = reason.as_bytes();
                out.extend_from_slice(&(b.len() as u32).to_le_bytes());
                out.extend_from_slice(b);
            }
        }
    }

    fn decode_body(buf: &[u8]) -> anyhow::Result<Msg> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
            let s = buf.get(*pos..*pos + n).ok_or_else(|| anyhow::anyhow!("truncated frame"))?;
            *pos += n;
            Ok(s)
        };
        let u32_at = |pos: &mut usize| -> anyhow::Result<u32> {
            Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
        };
        let u64_at = |pos: &mut usize| -> anyhow::Result<u64> {
            Ok(u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()))
        };
        let tag = *buf.first().ok_or_else(|| anyhow::anyhow!("empty frame"))?;
        pos += 1;
        let msg = match tag {
            1 => Msg::Hello { rank: u32_at(&mut pos)? },
            2 => Msg::Plan { waves: u64_at(&mut pos)?, table_hash: u64_at(&mut pos)? },
            3 => Msg::WaveRequest { rank: u32_at(&mut pos)? },
            4 => Msg::WaveAssign { wave: u64_at(&mut pos)? },
            5 => {
                let rank = u32_at(&mut pos)?;
                let wave = u64_at(&mut pos)?;
                let subgraphs = u64_at(&mut pos)?;
                let nodes = u64_at(&mut pos)?;
                let len = u64_at(&mut pos)? as usize;
                let bytes = take(&mut pos, len)?.to_vec();
                Msg::WaveResult { rank, wave, subgraphs, nodes, bytes }
            }
            6 => Msg::Done,
            7 => {
                let len = u32_at(&mut pos)? as usize;
                let reason = String::from_utf8_lossy(take(&mut pos, len)?).into_owned();
                Msg::Abort { reason }
            }
            other => anyhow::bail!("unknown message tag {other}"),
        };
        anyhow::ensure!(pos == buf.len(), "trailing bytes in frame");
        Ok(msg)
    }
}

/// Wire size for fabric accounting: frames really are this many bytes.
impl crate::cluster::Payload for Msg {
    fn wire_bytes(&self) -> u64 {
        let body = match self {
            Msg::Hello { .. } | Msg::WaveRequest { .. } => 1 + 4,
            Msg::Plan { .. } => 1 + 16,
            Msg::WaveAssign { .. } => 1 + 8,
            Msg::WaveResult { bytes, .. } => 1 + 4 + 8 * 3 + 8 + bytes.len() as u64,
            Msg::Done => 1,
            Msg::Abort { reason } => 1 + 4 + reason.len() as u64,
        };
        FRAME_HEADER as u64 + body
    }
}

fn map_io(e: std::io::Error) -> MailboxError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            MailboxError::Timeout(Duration::ZERO)
        }
        _ => MailboxError::Disconnected(e.to_string()),
    }
}

/// One framed connection. Read/write timeouts are sliced at
/// `POLL_SLICE` so deadlines and liveness checks stay responsive.
pub struct FramedStream {
    stream: UnixStream,
    op_deadline: Duration,
    buf: Vec<u8>,
    /// Fault injection for the chaos harness: when set, the next sent
    /// frame has one body byte flipped *after* its CRC is computed, so
    /// the receiver's checksum is guaranteed to reject it.
    corrupt_next: bool,
}

const POLL_SLICE: Duration = Duration::from_millis(50);

impl FramedStream {
    /// Connect with exponential-backoff retry until `connect_deadline`
    /// (the listener may not be bound yet when a worker starts).
    pub fn connect(
        path: &Path,
        op_deadline: Duration,
        connect_deadline: Instant,
    ) -> Result<Self, MailboxError> {
        let retries = crate::obs::metrics::counter("cluster.send_retries");
        let stream = retry_with_backoff(
            connect_deadline,
            &mut Backoff::for_transport(),
            || retries.inc(),
            || match UnixStream::connect(path) {
                Ok(s) => Ok(Some(s)),
                // Not-yet-bound / stale-path races are retryable; real
                // permission or path errors still retry until the
                // deadline, which is the honest behaviour during startup.
                Err(_) => Ok(None),
            },
        )?;
        Self::from_stream(stream, op_deadline)
    }

    pub fn from_stream(stream: UnixStream, op_deadline: Duration) -> Result<Self, MailboxError> {
        stream.set_read_timeout(Some(POLL_SLICE)).map_err(map_io)?;
        stream.set_write_timeout(Some(POLL_SLICE)).map_err(map_io)?;
        Ok(Self { stream, op_deadline, buf: Vec::new(), corrupt_next: false })
    }

    pub fn try_clone(&self) -> Result<Self, MailboxError> {
        Ok(Self {
            stream: self.stream.try_clone().map_err(map_io)?,
            op_deadline: self.op_deadline,
            buf: Vec::new(),
            corrupt_next: false,
        })
    }

    /// Chaos-harness hook: flip one byte of the next outgoing frame's
    /// body after checksumming, so the peer's CRC detects it.
    pub fn corrupt_next_frame(&mut self) {
        self.corrupt_next = true;
    }

    /// Send one frame within the op deadline. The write position is
    /// tracked across retries, so a timeout slice mid-frame resumes
    /// exactly where it left off — never duplicating bytes.
    pub fn send(&mut self, msg: &Msg) -> Result<(), MailboxError> {
        self.buf.clear();
        self.buf.extend_from_slice(&[0u8; FRAME_HEADER]);
        msg.encode_body(&mut self.buf);
        let body_len = (self.buf.len() - FRAME_HEADER) as u64;
        let crc = crc32(&self.buf[FRAME_HEADER..]);
        self.buf[..8].copy_from_slice(&body_len.to_le_bytes());
        self.buf[8..FRAME_HEADER].copy_from_slice(&crc.to_le_bytes());
        if std::mem::take(&mut self.corrupt_next) && self.buf.len() > FRAME_HEADER {
            // Injected fault: the CRC above no longer covers this body.
            self.buf[FRAME_HEADER] ^= 0x55;
        }

        let deadline = Instant::now() + self.op_deadline;
        let retries = crate::obs::metrics::counter("cluster.send_retries");
        let mut backoff = Backoff::for_transport();
        let mut off = 0usize;
        while off < self.buf.len() {
            match self.stream.write(&self.buf[off..]) {
                Ok(0) => return Err(MailboxError::Disconnected("peer closed (write 0)".into())),
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    retries.inc();
                    if !backoff.sleep_before(deadline) {
                        return Err(MailboxError::Timeout(self.op_deadline));
                    }
                }
                Err(e) => return Err(MailboxError::Disconnected(e.to_string())),
            }
        }
        Ok(())
    }

    /// Receive one frame. Waits up to `idle_deadline` for the frame to
    /// *begin* (timing out softly so the caller can run liveness checks
    /// and call again); once the first byte has arrived, the rest must
    /// land within the op deadline or the peer is declared gone.
    pub fn recv(&mut self, idle_deadline: Instant) -> Result<Msg, MailboxError> {
        let mut header = [0u8; FRAME_HEADER];
        self.read_exact_deadline(&mut header, idle_deadline, true)?;
        let len = u64::from_le_bytes(header[..8].try_into().unwrap());
        let want_crc = u32::from_le_bytes(header[8..].try_into().unwrap());
        if len > MAX_FRAME {
            crate::obs::metrics::counter("cluster.frames_corrupted").inc();
            return Err(MailboxError::Corrupt(format!("frame length {len} exceeds ceiling")));
        }
        self.buf.clear();
        self.buf.resize(len as usize, 0);
        let frame_deadline = Instant::now() + self.op_deadline;
        let mut body = std::mem::take(&mut self.buf);
        let res = self.read_exact_deadline(&mut body, frame_deadline, false);
        self.buf = body;
        res?;
        let got_crc = crc32(&self.buf);
        if got_crc != want_crc {
            crate::obs::metrics::counter("cluster.frames_corrupted").inc();
            return Err(MailboxError::Corrupt(format!(
                "body CRC {got_crc:#010x} != header {want_crc:#010x} ({len}-byte frame)"
            )));
        }
        Msg::decode_body(&self.buf).map_err(|e| {
            // Checksum passed but the body doesn't parse: a protocol-level
            // corruption (e.g. version skew), same recovery as a bad CRC.
            crate::obs::metrics::counter("cluster.frames_corrupted").inc();
            MailboxError::Corrupt(e.to_string())
        })
    }

    /// Read exactly `out.len()` bytes by `deadline`. With `soft_start`,
    /// timing out before *any* byte arrived is a soft `Timeout`; once
    /// bytes have arrived (or for `soft_start = false`), missing the
    /// deadline is terminal — a half-frame cannot be resumed by the
    /// caller.
    fn read_exact_deadline(
        &mut self,
        out: &mut [u8],
        deadline: Instant,
        soft_start: bool,
    ) -> Result<(), MailboxError> {
        let mut off = 0usize;
        let mut frame_deadline = deadline;
        while off < out.len() {
            match self.stream.read(&mut out[off..]) {
                Ok(0) => return Err(MailboxError::Disconnected("peer closed".into())),
                Ok(n) => {
                    if soft_start && off == 0 {
                        // Frame under way: switch from the caller's idle
                        // budget to the per-op deadline.
                        frame_deadline = Instant::now() + self.op_deadline;
                    }
                    off += n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    let now = Instant::now();
                    if off == 0 && soft_start {
                        if now >= deadline {
                            return Err(MailboxError::Timeout(self.op_deadline));
                        }
                    } else if now >= frame_deadline {
                        return Err(MailboxError::Disconnected(
                            "peer stalled mid-frame past the op deadline".into(),
                        ));
                    }
                }
                Err(e) => return Err(MailboxError::Disconnected(e.to_string())),
            }
        }
        Ok(())
    }

    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Payload;
    use std::os::unix::net::UnixListener;

    fn sock_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gg-wire-{tag}-{}.sock", std::process::id()))
    }

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        msg.encode_body(&mut buf);
        assert_eq!(Msg::decode_body(&buf).unwrap(), msg);
        // Payload accounting matches the real frame size (12-byte
        // length+CRC header plus the body).
        assert_eq!(msg.wire_bytes(), FRAME_HEADER as u64 + buf.len() as u64);
    }

    #[test]
    fn every_message_roundtrips_with_exact_wire_size() {
        roundtrip(Msg::Hello { rank: 3 });
        roundtrip(Msg::Plan { waves: 17, table_hash: 0xdead_beef });
        roundtrip(Msg::WaveRequest { rank: 250 });
        roundtrip(Msg::WaveAssign { wave: u64::MAX });
        roundtrip(Msg::WaveResult {
            rank: 1,
            wave: 9,
            subgraphs: 64,
            nodes: 4096,
            bytes: vec![1, 2, 3, 4, 5],
        });
        roundtrip(Msg::Done);
        roundtrip(Msg::Abort { reason: "plan mismatch".into() });
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        assert!(Msg::decode_body(&[]).is_err());
        assert!(Msg::decode_body(&[99]).is_err());
        // Truncated WaveResult payload.
        let mut buf = Vec::new();
        Msg::WaveResult { rank: 0, wave: 0, subgraphs: 1, nodes: 1, bytes: vec![0; 16] }
            .encode_body(&mut buf);
        assert!(Msg::decode_body(&buf[..buf.len() - 1]).is_err());
        // Trailing garbage.
        buf.push(0);
        assert!(Msg::decode_body(&buf).is_err());
    }

    #[test]
    fn socket_send_recv_and_disconnect() {
        let path = sock_path("basic");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let op = Duration::from_secs(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                let got = fs.recv(Instant::now() + op).unwrap();
                assert_eq!(got, Msg::Hello { rank: 7 });
                fs.send(&Msg::Plan { waves: 4, table_hash: 11 }).unwrap();
                // Drop → client observes Disconnected, not a hang.
            });
            let mut fs = FramedStream::connect(&path, op, Instant::now() + op).unwrap();
            fs.send(&Msg::Hello { rank: 7 }).unwrap();
            let plan = Msg::Plan { waves: 4, table_hash: 11 };
            assert_eq!(fs.recv(Instant::now() + op).unwrap(), plan);
            let err = fs.recv(Instant::now() + Duration::from_secs(10)).unwrap_err();
            assert!(matches!(err, MailboxError::Disconnected(_)), "{err:?}");
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn idle_recv_times_out_softly_then_delivers() {
        let path = sock_path("idle");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let op = Duration::from_secs(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                std::thread::sleep(Duration::from_millis(150));
                fs.send(&Msg::Done).unwrap();
                // Hold the connection open until the client has read.
                let _ = fs.recv(Instant::now() + Duration::from_secs(5));
            });
            let mut fs = FramedStream::connect(&path, op, Instant::now() + op).unwrap();
            // First poll window expires before the peer sends: soft timeout.
            let err = fs.recv(Instant::now() + Duration::from_millis(20)).unwrap_err();
            assert!(err.is_timeout(), "{err:?}");
            // Next poll gets the message — the soft timeout lost nothing.
            assert_eq!(fs.recv(Instant::now() + Duration::from_secs(5)).unwrap(), Msg::Done);
            fs.send(&Msg::Done).unwrap();
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_frame_fails_crc_then_fresh_connection_recovers() {
        let path = sock_path("crc");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let op = Duration::from_secs(2);
        let before = crate::obs::metrics::counter("cluster.frames_corrupted").get();
        std::thread::scope(|s| {
            s.spawn(|| {
                // First connection: one poisoned frame, then a clean one.
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                fs.corrupt_next_frame();
                fs.send(&Msg::WaveAssign { wave: 3 }).unwrap();
                fs.send(&Msg::WaveAssign { wave: 3 }).unwrap();
                // Hold until the client has read both.
                let _ = fs.recv(Instant::now() + Duration::from_secs(5));
                // Second connection (the client's reconnect): all clean.
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                fs.send(&Msg::WaveAssign { wave: 3 }).unwrap();
                let _ = fs.recv(Instant::now() + Duration::from_secs(5));
            });
            let mut fs = FramedStream::connect(&path, op, Instant::now() + op).unwrap();
            let err = fs.recv(Instant::now() + Duration::from_secs(5)).unwrap_err();
            assert!(err.is_corrupt(), "{err:?}");
            // The stream itself still frames correctly after a corrupt
            // body (the header was intact), so the clean frame lands...
            let assign = Msg::WaveAssign { wave: 3 };
            assert_eq!(fs.recv(Instant::now() + Duration::from_secs(5)).unwrap(), assign);
            fs.send(&Msg::Done).unwrap();
            // ...but the recovery contract is reconnect: a fresh
            // connection delivers untainted frames.
            let mut fs2 = FramedStream::connect(&path, op, Instant::now() + op).unwrap();
            assert_eq!(fs2.recv(Instant::now() + Duration::from_secs(5)).unwrap(), assign);
            fs2.send(&Msg::Done).unwrap();
        });
        assert!(
            crate::obs::metrics::counter("cluster.frames_corrupted").get() > before,
            "corruption must be counted"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_frame_is_terminal_and_reconnect_recovers() {
        let path = sock_path("torn");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let op = Duration::from_millis(300);
        std::thread::scope(|s| {
            s.spawn(|| {
                // Partial write: full header promising 64 body bytes, then
                // only 10 of them, then hard close — a torn frame.
                let (mut conn, _) = listener.accept().unwrap();
                let mut raw = Vec::new();
                raw.extend_from_slice(&64u64.to_le_bytes());
                raw.extend_from_slice(&0u32.to_le_bytes());
                raw.extend_from_slice(&[7u8; 10]);
                conn.write_all(&raw).unwrap();
                drop(conn);
                // The peer reconnects; serve it a clean frame.
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                fs.send(&Msg::Plan { waves: 2, table_hash: 5 }).unwrap();
                let _ = fs.recv(Instant::now() + Duration::from_secs(5));
            });
            let mut fs =
                FramedStream::connect(&path, op, Instant::now() + Duration::from_secs(2)).unwrap();
            let err = fs.recv(Instant::now() + Duration::from_secs(5)).unwrap_err();
            assert!(matches!(err, MailboxError::Disconnected(_)), "{err:?}");
            let mut fs2 =
                FramedStream::connect(&path, op, Instant::now() + Duration::from_secs(2)).unwrap();
            assert_eq!(
                fs2.recv(Instant::now() + Duration::from_secs(5)).unwrap(),
                Msg::Plan { waves: 2, table_hash: 5 }
            );
            fs2.send(&Msg::Done).unwrap();
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn large_frame_survives_short_writes() {
        // A multi-megabyte WaveResult overflows the socket buffer, so the
        // sender's write loop takes the WouldBlock/short-write path many
        // times while the reader drains slowly; the position-tracked loop
        // must still deliver one exact, checksummed frame.
        let path = sock_path("short-write");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let op = Duration::from_secs(10);
        let payload: Vec<u8> =
            (0..4 * 1024 * 1024u32).map(|i| (i as u64 * 2654435761 >> 7) as u8).collect();
        let msg = Msg::WaveResult { rank: 2, wave: 5, subgraphs: 9, nodes: 33, bytes: payload };
        std::thread::scope(|s| {
            let msg2 = msg.clone();
            s.spawn(move || {
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, op).unwrap();
                std::thread::sleep(Duration::from_millis(100)); // let the writer hit a full buffer
                assert_eq!(fs.recv(Instant::now() + op).unwrap(), msg2);
            });
            let mut fs = FramedStream::connect(&path, op, Instant::now() + op).unwrap();
            fs.send(&msg).unwrap();
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn connect_retries_until_listener_appears() {
        let path = sock_path("retry");
        let _ = std::fs::remove_file(&path);
        let path2 = path.clone();
        std::thread::scope(|s| {
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(100));
                let listener = UnixListener::bind(&path2).unwrap();
                let (conn, _) = listener.accept().unwrap();
                let mut fs = FramedStream::from_stream(conn, Duration::from_secs(1)).unwrap();
                assert_eq!(
                    fs.recv(Instant::now() + Duration::from_secs(2)).unwrap(),
                    Msg::WaveRequest { rank: 0 }
                );
            });
            // Connect starts before the bind: backoff retries bridge it.
            let mut fs = FramedStream::connect(
                &path,
                Duration::from_secs(1),
                Instant::now() + Duration::from_secs(5),
            )
            .unwrap();
            fs.send(&Msg::WaveRequest { rank: 0 }).unwrap();
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn connect_deadline_expires_without_listener() {
        let path = sock_path("nobody");
        let _ = std::fs::remove_file(&path);
        let err = FramedStream::connect(
            &path,
            Duration::from_secs(1),
            Instant::now() + Duration::from_millis(50),
        )
        .unwrap_err();
        assert!(err.is_timeout(), "{err:?}");
        // Retries were counted on the shared cluster counter.
        assert!(crate::obs::metrics::counter("cluster.send_retries").get() > 0);
    }
}
