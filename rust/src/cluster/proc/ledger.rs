//! Durable wave-ownership ledger.
//!
//! Append-only text log in the run directory, one flushed line per
//! transition:
//!
//! ```text
//! C <wave> <rank>   # wave claimed by (assigned to) rank
//! D <wave> <rank>   # rank returned the wave's bytes
//! R <wave> <rank>   # rank was lost; its claim is void, wave re-queued
//! ```
//!
//! The coordinator is the only writer; the file exists so that *after a
//! crash* (or in a test) the exact recovery history is replayable: a
//! `C` without a matching `D` is an in-flight wave, and an in-flight
//! wave whose owner died is **stale** — [`WaveLedger::stale_for`] is what
//! the lease sweep feeds the reclaim queue with. Regeneration is
//! deterministic per (wave, seed-range), so a reclaimed wave's bytes are
//! identical no matter which survivor re-runs it.

use std::io::Write;
use std::path::Path;

use crate::util::fxhash::{FxHashMap, FxHashSet};

pub struct WaveLedger {
    file: std::fs::File,
    /// wave → current owner (claims voided by `R` are removed).
    claimed: FxHashMap<u64, u32>,
    done: FxHashSet<u64>,
}

impl WaveLedger {
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { file, claimed: Default::default(), done: Default::default() })
    }

    fn append(&mut self, tag: char, wave: u64, rank: u32) -> anyhow::Result<()> {
        // One line per transition, flushed: a SIGKILL between waves can
        // lose at most the transition being written, never reorder them.
        writeln!(self.file, "{tag} {wave} {rank}")?;
        self.file.flush()?;
        Ok(())
    }

    pub fn claim(&mut self, wave: u64, rank: u32) -> anyhow::Result<()> {
        self.claimed.insert(wave, rank);
        self.append('C', wave, rank)
    }

    pub fn done(&mut self, wave: u64, rank: u32) -> anyhow::Result<()> {
        self.claimed.remove(&wave);
        self.done.insert(wave);
        self.append('D', wave, rank)
    }

    /// Void a lost rank's claim on `wave` (recorded, then re-queued by
    /// the caller).
    pub fn reclaim(&mut self, wave: u64, lost_rank: u32) -> anyhow::Result<()> {
        self.claimed.remove(&wave);
        self.append('R', wave, lost_rank)
    }

    pub fn is_done(&self, wave: u64) -> bool {
        self.done.contains(&wave)
    }

    pub fn owner(&self, wave: u64) -> Option<u32> {
        self.claimed.get(&wave).copied()
    }

    /// Waves claimed by `rank` and never completed — stale the moment
    /// `rank` is declared lost, sorted so recovery regenerates in wave
    /// order.
    pub fn stale_for(&self, rank: u32) -> Vec<u64> {
        let mut waves: Vec<u64> =
            self.claimed.iter().filter(|&(_, &r)| r == rank).map(|(&w, _)| w).collect();
        waves.sort_unstable();
        waves
    }

    pub fn done_count(&self) -> u64 {
        self.done.len() as u64
    }
}

/// Replay a ledger file (crash forensics / tests): returns the in-flight
/// claims and the done set exactly as a restarted coordinator would see
/// them.
pub fn replay(path: &Path) -> anyhow::Result<(FxHashMap<u64, u32>, FxHashSet<u64>)> {
    let text = std::fs::read_to_string(path)?;
    let mut claimed: FxHashMap<u64, u32> = Default::default();
    let mut done: FxHashSet<u64> = Default::default();
    for (lineno, line) in text.lines().enumerate() {
        let mut parts = line.split_whitespace();
        let (tag, wave, rank) = (parts.next(), parts.next(), parts.next());
        let parse = || -> Option<(&str, u64, u32)> {
            Some((tag?, wave?.parse().ok()?, rank?.parse().ok()?))
        };
        // A torn final line (killed mid-write) is expected; anything
        // torn *before* the end means corruption.
        let Some((tag, wave, rank)) = parse() else {
            anyhow::ensure!(
                lineno + 1 == text.lines().count(),
                "corrupt ledger line {}: '{line}'",
                lineno + 1
            );
            continue;
        };
        match tag {
            "C" => {
                claimed.insert(wave, rank);
            }
            "D" => {
                claimed.remove(&wave);
                done.insert(wave);
            }
            "R" => {
                claimed.remove(&wave);
            }
            other => anyhow::bail!("corrupt ledger tag '{other}' at line {}", lineno + 1),
        }
    }
    Ok((claimed, done))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gg-ledger-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.ledger"))
    }

    #[test]
    fn claims_completions_and_stale_detection() {
        let p = path("stale");
        let _ = std::fs::remove_file(&p);
        let mut l = WaveLedger::create(&p).unwrap();
        l.claim(0, 0).unwrap();
        l.claim(1, 1).unwrap();
        l.claim(2, 1).unwrap();
        l.done(1, 1).unwrap();
        assert_eq!(l.owner(0), Some(0));
        assert!(l.is_done(1));
        // Rank 1 dies: wave 2 (claimed, not done) is stale; wave 1 is not.
        assert_eq!(l.stale_for(1), vec![2]);
        assert_eq!(l.stale_for(0), vec![0]);
        l.reclaim(2, 1).unwrap();
        assert_eq!(l.stale_for(1), Vec::<u64>::new());
        // Survivor takes it over and finishes.
        l.claim(2, 0).unwrap();
        l.done(2, 0).unwrap();
        assert_eq!(l.done_count(), 2);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ledger_is_durable_and_replayable() {
        let p = path("replay");
        let _ = std::fs::remove_file(&p);
        {
            let mut l = WaveLedger::create(&p).unwrap();
            l.claim(0, 0).unwrap();
            l.claim(1, 1).unwrap();
            l.done(0, 0).unwrap();
            l.claim(2, 0).unwrap();
            l.reclaim(1, 1).unwrap();
            l.claim(1, 0).unwrap();
        } // coordinator "dies" here
        let (claimed, done) = replay(&p).unwrap();
        assert!(done.contains(&0));
        assert_eq!(claimed.get(&1), Some(&0), "reclaimed wave re-owned by rank 0");
        assert_eq!(claimed.get(&2), Some(&0));
        assert_eq!(done.len(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_tolerated_corrupt_middle_rejected() {
        let p = path("torn");
        std::fs::write(&p, "C 0 0\nD 0 0\nC 1").unwrap(); // torn final line
        let (claimed, done) = replay(&p).unwrap();
        assert!(done.contains(&0));
        assert!(claimed.is_empty());
        std::fs::write(&p, "C 0 0\nX 1 1\nD 0 0\n").unwrap(); // bad tag mid-file
        assert!(replay(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
