//! Durable wave-ownership ledger.
//!
//! Append-only text log in the run directory, one flushed line per
//! transition:
//!
//! ```text
//! C <wave> <rank>      # wave claimed by (assigned to) rank
//! D <wave> <rank>      # rank returned the wave's bytes
//! R <wave> <rank>      # rank's claim is void, wave re-queued
//! S <rank> <attempt>   # marker: replacement worker spawned for rank
//! K <seq> <next_emit>  # marker: coordinator checkpoint written
//! A <seq> <next_emit>  # marker: coordinator resumed from checkpoint
//! ```
//!
//! The coordinator is the only writer; the file exists so that *after a
//! crash* (or in a test) the exact recovery history is replayable: a
//! `C` without a matching `D` is an in-flight wave, and an in-flight
//! wave whose owner died is **stale** — [`WaveLedger::stale_for`] is what
//! the lease sweep feeds the reclaim queue with. Regeneration is
//! deterministic per (wave, seed-range), so a reclaimed wave's bytes are
//! identical no matter which survivor re-runs it.
//!
//! `S`/`K`/`A` are **markers**: they carry no ownership state (replay
//! skips over them) but record the recovery history for forensics and
//! the CI smoke greps. Checkpoints [`WaveLedger::compact`] the file —
//! the claim/void churn of past recoveries collapses to the live state
//! plus the marker history, so the ledger stays bounded across any
//! number of restarts.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::fxhash::{FxHashMap, FxHashSet};

/// Typed replay failures. A torn *final* line (the coordinator was
/// killed mid-`write`) is expected and tolerated; a torn or unknown
/// *interior* line means the file was actually corrupted and recovery
/// must not silently guess.
#[derive(Debug, thiserror::Error)]
pub enum LedgerError {
    #[error("ledger io: {0}")]
    Io(#[from] std::io::Error),
    #[error("corrupt ledger line {line}: '{content}' (only a torn final line is tolerated)")]
    CorruptLine { line: usize, content: String },
    #[error("corrupt ledger tag '{tag}' at line {line}")]
    CorruptTag { tag: String, line: usize },
}

pub struct WaveLedger {
    file: std::fs::File,
    path: PathBuf,
    /// wave → current owner (claims voided by `R` are removed).
    claimed: FxHashMap<u64, u32>,
    /// wave → rank that completed it (retained for compaction).
    done: FxHashMap<u64, u32>,
    /// Marker lines (`S`/`K`/`A`) in append order, preserved verbatim
    /// across compactions: the recovery history of the run.
    markers: Vec<String>,
}

impl WaveLedger {
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self {
            file,
            path: path.to_path_buf(),
            claimed: Default::default(),
            done: Default::default(),
            markers: Default::default(),
        })
    }

    /// Reopen an existing ledger on coordinator resume: replays the file
    /// (typed errors — a corrupt interior line aborts the resume) into
    /// in-memory state, then appends.
    pub fn resume(path: &Path) -> Result<Self, LedgerError> {
        let (claimed, done, markers) = if path.exists() {
            replay_full(path)?
        } else {
            Default::default()
        };
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { file, path: path.to_path_buf(), claimed, done, markers })
    }

    fn append(&mut self, tag: char, a: u64, b: u64) -> anyhow::Result<()> {
        // One line per transition, flushed: a SIGKILL between waves can
        // lose at most the transition being written, never reorder them.
        writeln!(self.file, "{tag} {a} {b}")?;
        self.file.flush()?;
        Ok(())
    }

    pub fn claim(&mut self, wave: u64, rank: u32) -> anyhow::Result<()> {
        self.claimed.insert(wave, rank);
        self.append('C', wave, rank as u64)
    }

    pub fn done(&mut self, wave: u64, rank: u32) -> anyhow::Result<()> {
        self.claimed.remove(&wave);
        self.done.insert(wave, rank);
        self.append('D', wave, rank as u64)
    }

    /// Void a lost rank's claim on `wave` (recorded, then re-queued by
    /// the caller).
    pub fn reclaim(&mut self, wave: u64, lost_rank: u32) -> anyhow::Result<()> {
        self.claimed.remove(&wave);
        self.append('R', wave, lost_rank as u64)
    }

    /// Marker: a replacement worker process was spawned for `rank`.
    pub fn respawned(&mut self, rank: u32, attempt: u64) -> anyhow::Result<()> {
        self.markers.push(format!("S {rank} {attempt}"));
        self.append('S', rank as u64, attempt)
    }

    /// Marker: checkpoint `seq` persisted with emission frontier
    /// `next_emit` — and compact, so the ledger's size tracks the live
    /// in-flight set instead of the full recovery history.
    pub fn checkpointed(&mut self, seq: u64, next_emit: u64) -> anyhow::Result<()> {
        self.markers.push(format!("K {seq} {next_emit}"));
        self.append('K', seq, next_emit)?;
        self.compact()
    }

    /// Marker: the coordinator restarted from checkpoint `seq`.
    pub fn resumed(&mut self, seq: u64, next_emit: u64) -> anyhow::Result<()> {
        self.markers.push(format!("A {seq} {next_emit}"));
        self.append('A', seq, next_emit)
    }

    /// Rewrite the ledger as (markers, done set, live claims) via
    /// tmp-file + atomic rename: equivalent replay state, bounded size.
    pub fn compact(&mut self) -> anyhow::Result<()> {
        let tmp = self.path.with_extension("ledger.tmp");
        {
            let mut out = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            for m in &self.markers {
                writeln!(out, "{m}")?;
            }
            let mut done: Vec<(&u64, &u32)> = self.done.iter().collect();
            done.sort_unstable();
            for (w, r) in done {
                writeln!(out, "D {w} {r}")?;
            }
            let mut claims: Vec<(&u64, &u32)> = self.claimed.iter().collect();
            claims.sort_unstable();
            for (w, r) in claims {
                writeln!(out, "C {w} {r}")?;
            }
            out.flush()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        self.file = std::fs::OpenOptions::new().append(true).open(&self.path)?;
        Ok(())
    }

    pub fn is_done(&self, wave: u64) -> bool {
        self.done.contains_key(&wave)
    }

    /// Forget completion state for waves at or past `wave` (resume
    /// re-emits them; regeneration is deterministic, so replayed results
    /// must not be deduplicated away as "already done").
    pub fn reset_done_from(&mut self, wave: u64) {
        self.done.retain(|&w, _| w < wave);
        self.claimed.retain(|&w, _| w < wave);
    }

    pub fn owner(&self, wave: u64) -> Option<u32> {
        self.claimed.get(&wave).copied()
    }

    /// Waves claimed by `rank` and never completed — stale the moment
    /// `rank` is declared lost, sorted so recovery regenerates in wave
    /// order.
    pub fn stale_for(&self, rank: u32) -> Vec<u64> {
        let mut waves: Vec<u64> =
            self.claimed.iter().filter(|&(_, &r)| r == rank).map(|(&w, _)| w).collect();
        waves.sort_unstable();
        waves
    }

    pub fn done_count(&self) -> u64 {
        self.done.len() as u64
    }
}

/// Full replay: (in-flight claims, done map, marker lines).
fn replay_full(
    path: &Path,
) -> Result<(FxHashMap<u64, u32>, FxHashMap<u64, u32>, Vec<String>), LedgerError> {
    let text = std::fs::read_to_string(path)?;
    let mut claimed: FxHashMap<u64, u32> = Default::default();
    let mut done: FxHashMap<u64, u32> = Default::default();
    let mut markers: Vec<String> = Vec::new();
    let total = text.lines().count();
    for (lineno, line) in text.lines().enumerate() {
        let mut parts = line.split_whitespace();
        let (tag, a, b) = (parts.next(), parts.next(), parts.next());
        let parse = || -> Option<(&str, u64, u64)> {
            Some((tag?, a?.parse().ok()?, b?.parse().ok()?))
        };
        // A torn final line (killed mid-write) is expected; anything
        // torn *before* the end means corruption.
        let Some((tag, a, b)) = parse() else {
            if lineno + 1 == total {
                continue;
            }
            return Err(LedgerError::CorruptLine { line: lineno + 1, content: line.to_string() });
        };
        match tag {
            "C" => {
                claimed.insert(a, b as u32);
            }
            "D" => {
                claimed.remove(&a);
                done.insert(a, b as u32);
            }
            "R" => {
                claimed.remove(&a);
            }
            // Markers: no ownership state, preserved for history.
            "S" | "K" | "A" => markers.push(line.to_string()),
            other => {
                return Err(LedgerError::CorruptTag { tag: other.to_string(), line: lineno + 1 })
            }
        }
    }
    Ok((claimed, done, markers))
}

/// Replay a ledger file (crash forensics / tests): returns the in-flight
/// claims and the done set exactly as a restarted coordinator would see
/// them.
pub fn replay(path: &Path) -> Result<(FxHashMap<u64, u32>, FxHashSet<u64>), LedgerError> {
    let (claimed, done, _) = replay_full(path)?;
    Ok((claimed, done.into_keys().collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gg-ledger-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.ledger"))
    }

    #[test]
    fn claims_completions_and_stale_detection() {
        let p = path("stale");
        let _ = std::fs::remove_file(&p);
        let mut l = WaveLedger::create(&p).unwrap();
        l.claim(0, 0).unwrap();
        l.claim(1, 1).unwrap();
        l.claim(2, 1).unwrap();
        l.done(1, 1).unwrap();
        assert_eq!(l.owner(0), Some(0));
        assert!(l.is_done(1));
        // Rank 1 dies: wave 2 (claimed, not done) is stale; wave 1 is not.
        assert_eq!(l.stale_for(1), vec![2]);
        assert_eq!(l.stale_for(0), vec![0]);
        l.reclaim(2, 1).unwrap();
        assert_eq!(l.stale_for(1), Vec::<u64>::new());
        // Survivor takes it over and finishes.
        l.claim(2, 0).unwrap();
        l.done(2, 0).unwrap();
        assert_eq!(l.done_count(), 2);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ledger_is_durable_and_replayable() {
        let p = path("replay");
        let _ = std::fs::remove_file(&p);
        {
            let mut l = WaveLedger::create(&p).unwrap();
            l.claim(0, 0).unwrap();
            l.claim(1, 1).unwrap();
            l.done(0, 0).unwrap();
            l.claim(2, 0).unwrap();
            l.reclaim(1, 1).unwrap();
            l.claim(1, 0).unwrap();
        } // coordinator "dies" here
        let (claimed, done) = replay(&p).unwrap();
        assert!(done.contains(&0));
        assert_eq!(claimed.get(&1), Some(&0), "reclaimed wave re-owned by rank 0");
        assert_eq!(claimed.get(&2), Some(&0));
        assert_eq!(done.len(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_tolerated_corrupt_middle_rejected() {
        let p = path("torn");
        std::fs::write(&p, "C 0 0\nD 0 0\nC 1").unwrap(); // torn final line
        let (claimed, done) = replay(&p).unwrap();
        assert!(done.contains(&0));
        assert!(claimed.is_empty());
        // Torn line mid-file: typed interior-corruption error.
        std::fs::write(&p, "C 0 0\nC 1\nD 0 0\n").unwrap();
        match replay(&p) {
            Err(LedgerError::CorruptLine { line: 2, .. }) => {}
            other => panic!("expected CorruptLine at 2, got {other:?}"),
        }
        // Unknown tag mid-file: typed too.
        std::fs::write(&p, "C 0 0\nX 1 1\nD 0 0\n").unwrap();
        match replay(&p) {
            Err(LedgerError::CorruptTag { line: 2, ref tag }) if tag == "X" => {}
            other => panic!("expected CorruptTag at 2, got {other:?}"),
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn markers_survive_replay_and_compaction_bounds_the_file() {
        let p = path("compact");
        let _ = std::fs::remove_file(&p);
        let mut l = WaveLedger::create(&p).unwrap();
        // A churny history: claims, voids, respawns across many "recoveries".
        for round in 0..20u64 {
            for w in 0..8u64 {
                l.claim(w, (w % 3) as u32).unwrap();
            }
            for w in 0..8u64 {
                l.reclaim(w, (w % 3) as u32).unwrap();
            }
            l.respawned((round % 3) as u32, round).unwrap();
        }
        for w in 0..6u64 {
            l.claim(w, 0).unwrap();
            l.done(w, 0).unwrap();
        }
        l.claim(6, 1).unwrap();
        let grown = std::fs::metadata(&p).unwrap().len();
        // Checkpoint marker compacts in place.
        l.checkpointed(1, 6).unwrap();
        let compacted = std::fs::metadata(&p).unwrap().len();
        assert!(
            compacted * 4 < grown,
            "compaction must collapse history ({grown} -> {compacted} bytes)"
        );
        // Replay equivalence: same live claims + done set; markers kept.
        let (claimed, done, markers) = replay_full(&p).unwrap();
        assert_eq!(claimed.get(&6), Some(&1));
        assert_eq!(done.len(), 6);
        assert_eq!(markers.iter().filter(|m| m.starts_with("S ")).count(), 20);
        assert!(markers.iter().any(|m| m.starts_with("K 1 6")));
        // And the compacted file can itself be resumed + appended.
        drop(l);
        let mut l2 = WaveLedger::resume(&p).unwrap();
        assert!(l2.is_done(3));
        assert_eq!(l2.owner(6), Some(1));
        l2.done(6, 1).unwrap();
        l2.resumed(1, 6).unwrap();
        let (_, done2, markers2) = replay_full(&p).unwrap();
        assert_eq!(done2.len(), 7);
        assert!(markers2.iter().any(|m| m.starts_with("A 1 6")));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn reset_done_from_reopens_the_tail() {
        let p = path("reset");
        let _ = std::fs::remove_file(&p);
        let mut l = WaveLedger::create(&p).unwrap();
        for w in 0..5u64 {
            l.claim(w, 0).unwrap();
            l.done(w, 0).unwrap();
        }
        l.reset_done_from(3);
        assert!(l.is_done(2) && !l.is_done(3) && !l.is_done(4));
        assert_eq!(l.done_count(), 3);
        let _ = std::fs::remove_file(&p);
    }
}
