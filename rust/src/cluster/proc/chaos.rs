//! Deterministic chaos harness for the distributed layer.
//!
//! Fault injection driven entirely by a seed (`GG_CHAOS_SEED` /
//! `--chaos`): every decision is a pure hash of
//! `(seed, respawn generation, rank, wave)` — no wall clock, no RNG
//! state threaded through the run — so one seed names one exact fault
//! schedule, replayable across machines and across coordinator
//! restarts (the seed rides in the shared `config.json`).
//!
//! Injected faults, applied inside the worker process:
//! - **wave stall** — sleep before returning a wave (tests reorder
//!   windows, lease margins, parked requests);
//! - **worker kill** — `abort()` mid-wave, *before* sending the result
//!   (the hard case: the claim goes stale, the lease sweep must reclaim
//!   and respawn);
//! - **frame corruption** — one result frame is sent with a flipped
//!   body byte ([`super::wire::FramedStream::corrupt_next_frame`]); the
//!   coordinator's CRC rejects it, tears the connection, and the worker
//!   reconnects and resends;
//! - **heartbeat delay** — the heartbeat writer freezes past the lease
//!   once ([`super::heartbeat::HeartbeatWriter::start_with_pause`]),
//!   making a healthy worker look dead (false-positive recovery path).
//!
//! Coordinator kills are injected from *outside* (the CI soak SIGKILLs
//! the coordinator and relaunches `--resume`); a process cannot
//! meaningfully chaos-kill itself at interesting points.
//!
//! Each decision also folds in the worker's respawn generation
//! (`GG_CHAOS_GEN`, stamped by the coordinator on respawn): a
//! replacement worker re-assigned the wave that killed its predecessor
//! draws a fresh schedule, so a single seed cannot pin one wave into an
//! infinite kill loop. Byte-identity to the oracle is independent of
//! the schedule — chaos perturbs *timing and failures*, never payloads
//! that survive their CRC.

use crate::util::rng::mix3;

pub const CHAOS_SEED_ENV: &str = "GG_CHAOS_SEED";
pub const CHAOS_GEN_ENV: &str = "GG_CHAOS_GEN";

const SALT_STALL: u64 = 0x0005_7a11;
const SALT_KILL: u64 = 0x0000_dead;
const SALT_CORRUPT: u64 = 0x00c0_4475;
const SALT_HEARTBEAT: u64 = 0x0004_ea47;

#[derive(Debug, Clone, Copy)]
pub struct Chaos {
    seed: u64,
    generation: u64,
}

impl Chaos {
    pub fn new(seed: u64, generation: u64) -> Self {
        Self { seed, generation }
    }

    /// Worker-side constructor: explicit seed (from config.json) with
    /// `GG_CHAOS_SEED` as an override, `GG_CHAOS_GEN` stamped by the
    /// coordinator on respawn. Seed 0 disables chaos.
    pub fn from_env(config_seed: u64) -> Option<Self> {
        let seed = match std::env::var(CHAOS_SEED_ENV) {
            Ok(v) => v.parse().unwrap_or(config_seed),
            Err(_) => config_seed,
        };
        if seed == 0 {
            return None;
        }
        let generation = std::env::var(CHAOS_GEN_ENV)
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        Some(Self::new(seed, generation))
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn roll(&self, salt: u64, rank: u64, wave: u64) -> u64 {
        mix3(self.seed ^ salt, rank.wrapping_add(self.generation.wrapping_mul(0x9e37_79b9)), wave)
    }

    /// Sleep this long before returning `wave` (~1 in 4 waves, 5–40 ms).
    pub fn wave_stall_ms(&self, rank: u32, wave: u64) -> Option<u64> {
        let r = self.roll(SALT_STALL, rank as u64, wave);
        (r % 4 == 0).then(|| 5 + (r >> 8) % 36)
    }

    /// Abort before sending `wave`'s result (~1 in 10 waves).
    pub fn kill_before_result(&self, rank: u32, wave: u64) -> bool {
        self.roll(SALT_KILL, rank as u64, wave) % 10 == 0
    }

    /// Corrupt the result frame for `wave` (~1 in 6 waves). The worker
    /// applies this at most once per wave per process lifetime, so a
    /// reassignment of the same wave to the same rank still terminates.
    pub fn corrupt_result(&self, rank: u32, wave: u64) -> bool {
        self.roll(SALT_CORRUPT, rank as u64, wave) % 6 == 0
    }

    /// One-shot heartbeat freeze for this process (~1 in 3 ranks per
    /// generation): `(beat number to freeze before, freeze duration ms)`
    /// — the duration lands in `[1.2, 2.2) × lease`, guaranteeing the
    /// lease expires while the worker is in fact healthy.
    pub fn heartbeat_pause(&self, rank: u32, lease_ms: u64) -> Option<(u64, u64)> {
        let r = self.roll(SALT_HEARTBEAT, rank as u64, 0);
        (r % 3 == 0).then(|| {
            let beat = 2 + (r >> 8) % 6;
            let ms = lease_ms + lease_ms / 5 + (r >> 16) % lease_ms.max(1);
            (beat, ms)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_per_seed_and_generation() {
        let a = Chaos::new(7, 0);
        let b = Chaos::new(7, 0);
        let c = Chaos::new(8, 0);
        let g = Chaos::new(7, 1);
        let mut same = 0;
        let mut diff_seed = 0;
        let mut diff_gen = 0;
        for rank in 0..4u32 {
            for wave in 0..64u64 {
                let da = (
                    a.wave_stall_ms(rank, wave),
                    a.kill_before_result(rank, wave),
                    a.corrupt_result(rank, wave),
                );
                let db = (
                    b.wave_stall_ms(rank, wave),
                    b.kill_before_result(rank, wave),
                    b.corrupt_result(rank, wave),
                );
                assert_eq!(da, db, "same seed+gen must replay identically");
                same += 1;
                let dc = (
                    c.wave_stall_ms(rank, wave),
                    c.kill_before_result(rank, wave),
                    c.corrupt_result(rank, wave),
                );
                let dg = (
                    g.wave_stall_ms(rank, wave),
                    g.kill_before_result(rank, wave),
                    g.corrupt_result(rank, wave),
                );
                diff_seed += (da != dc) as u32;
                diff_gen += (da != dg) as u32;
            }
        }
        assert!(same > 0 && diff_seed > 0, "distinct seeds must diverge somewhere");
        assert!(diff_gen > 0, "a respawned generation must draw a fresh schedule");
    }

    #[test]
    fn fault_rates_are_in_sane_bands() {
        let c = Chaos::new(12345, 0);
        let (mut stalls, mut kills, mut corrupts) = (0u32, 0u32, 0u32);
        let n = 4 * 256;
        for rank in 0..4u32 {
            for wave in 0..256u64 {
                stalls += c.wave_stall_ms(rank, wave).is_some() as u32;
                kills += c.kill_before_result(rank, wave) as u32;
                corrupts += c.corrupt_result(rank, wave) as u32;
                if let Some(ms) = c.wave_stall_ms(rank, wave) {
                    assert!((5..41).contains(&ms));
                }
            }
        }
        // Loose 2x bands around the nominal 1/4, 1/10, 1/6 rates.
        assert!(stalls > n / 8 && stalls < n / 2, "{stalls}/{n}");
        assert!(kills > n / 20 && kills < n / 5, "{kills}/{n}");
        assert!(corrupts > n / 12 && corrupts < n / 3, "{corrupts}/{n}");
    }

    #[test]
    fn heartbeat_pause_expires_the_lease_when_drawn() {
        let mut drawn = 0;
        for seed in 1..40u64 {
            if let Some((beat, ms)) = Chaos::new(seed, 0).heartbeat_pause(1, 500) {
                assert!(beat >= 2);
                assert!(ms > 500, "pause {ms} must exceed the 500 ms lease");
                drawn += 1;
            }
        }
        assert!(drawn > 0, "some seed must draw a heartbeat pause");
    }

    #[test]
    fn env_override_and_disable() {
        // Seed 0 disables; config seed applies without env.
        assert!(Chaos::from_env(0).is_none() || std::env::var(CHAOS_SEED_ENV).is_ok());
        let c = Chaos::from_env(9);
        if std::env::var(CHAOS_SEED_ENV).is_err() {
            assert_eq!(c.unwrap().seed(), 9);
        }
    }
}
