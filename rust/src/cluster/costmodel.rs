//! Simulated-cluster cost model.
//!
//! This container exposes a **single CPU core** (`nproc` = 1), so real
//! wall-clock time cannot exhibit the parallel effects the paper's
//! evaluation is about (worker scaling, tree-reduction speedup, pipeline
//! overlap). Per the substitution methodology (DESIGN.md §2), the engines
//! therefore keep *work ledgers* — exact counters of scanned edge-entries,
//! merged reservoir entries, shuffled bytes, sorted rows and disk bytes,
//! attributed to simulated workers and reduction rounds — and this module
//! converts a ledger into **modeled cluster time**:
//!
//! ```text
//! phase time  = max over workers   (work_w · cost constants)     (parallel)
//!             | Σ over rounds max over groups (...)              (tree)
//! total time  = Σ phase times
//! ```
//!
//! Compute constants are *calibrated on this machine* (timed microloops,
//! see [`CostModel::calibrated`]); network and disk constants are the
//! documented assumptions of a commodity cluster (25 GbE, NVMe). Real
//! wall time is always reported alongside modeled time in the benches.

use std::collections::BTreeMap;

/// Work counters attributable to one worker (or one tree-merge group).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkUnits {
    /// Edge×interested-subgraph pairs scanned (map phase inner loop).
    pub scan_edge_entries: u64,
    /// Reservoir entries moved during merging (reduce phase).
    pub merge_entries: u64,
    /// Materialized + sorted join rows (SQL-like engine only).
    pub sort_rows: u64,
    /// Join-output rows materialized (allocated + written) before any
    /// sampling (SQL-like engine only).
    pub materialize_rows: u64,
    /// Bytes received over the network.
    pub net_bytes: u64,
    /// Network messages received.
    pub msgs: u64,
    /// Bytes written to + read from disk.
    pub disk_bytes: u64,
}

impl WorkUnits {
    pub fn add(&mut self, o: &WorkUnits) {
        self.scan_edge_entries += o.scan_edge_entries;
        self.merge_entries += o.merge_entries;
        self.sort_rows += o.sort_rows;
        self.materialize_rows += o.materialize_rows;
        self.net_bytes += o.net_bytes;
        self.msgs += o.msgs;
        self.disk_bytes += o.disk_bytes;
    }

    pub fn is_zero(&self) -> bool {
        *self == WorkUnits::default()
    }
}

/// One phase of a generation run.
#[derive(Debug, Clone, Default)]
pub struct PhaseWork {
    /// Work executed concurrently, one slot per simulated worker.
    pub per_worker: Vec<WorkUnits>,
    /// Tree-structured work: `rounds[r]` holds one entry per merge group;
    /// groups within a round run in parallel, rounds are sequential.
    pub rounds: Vec<Vec<WorkUnits>>,
}

impl PhaseWork {
    pub fn new(workers: usize) -> Self {
        Self { per_worker: vec![WorkUnits::default(); workers], rounds: Vec::new() }
    }
}

/// Per-phase work ledger for one generation run.
#[derive(Debug, Clone, Default)]
pub struct WorkLedger {
    pub workers: usize,
    pub phases: BTreeMap<String, PhaseWork>,
}

impl WorkLedger {
    pub fn new(workers: usize) -> Self {
        Self { workers, phases: BTreeMap::new() }
    }

    pub fn phase_mut(&mut self, name: &str) -> &mut PhaseWork {
        let w = self.workers;
        self.phases.entry(name.to_string()).or_insert_with(|| PhaseWork::new(w))
    }

    /// Attribute `units` to `worker` in `phase`.
    pub fn charge(&mut self, phase: &str, worker: usize, units: WorkUnits) {
        let w = worker % self.workers.max(1);
        self.phase_mut(phase).per_worker[w].add(&units);
    }

    /// Append a tree round (one `WorkUnits` per parallel group).
    pub fn charge_round(&mut self, phase: &str, groups: Vec<WorkUnits>) {
        self.phase_mut(phase).rounds.push(groups);
    }

    pub fn merge(&mut self, other: &WorkLedger) {
        for (name, pw) in &other.phases {
            let mine = self.phase_mut(name);
            for (a, b) in mine.per_worker.iter_mut().zip(&pw.per_worker) {
                a.add(b);
            }
            mine.rounds.extend(pw.rounds.iter().cloned());
        }
    }

    /// Total work across all workers and rounds (for sanity checks).
    pub fn total(&self) -> WorkUnits {
        let mut t = WorkUnits::default();
        for pw in self.phases.values() {
            for u in &pw.per_worker {
                t.add(u);
            }
            for r in &pw.rounds {
                for u in r {
                    t.add(u);
                }
            }
        }
        t
    }
}

/// Cost constants (nanoseconds per unit).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub scan_ns_per_edge_entry: f64,
    pub merge_ns_per_entry: f64,
    pub sort_ns_per_row: f64,
    /// Per join-output row materialized (allocation + 24-byte write +
    /// exchange-operator serialization — what a SQL engine pays before it
    /// can sort).
    pub materialize_ns_per_row: f64,
    /// Per received byte (NIC bandwidth). 25 GbE ≈ 0.32 ns/B.
    pub net_ns_per_byte: f64,
    /// Per message (switch + stack latency, pipelined ⇒ amortized).
    pub net_ns_per_msg: f64,
    /// Per disk byte, write+read averaged. NVMe ~2.5 GB/s ⇒ 0.4 ns/B.
    pub disk_ns_per_byte: f64,
}

impl CostModel {
    /// Documented cluster assumptions with *measured* compute constants
    /// for this container (see [`calibrated`](Self::calibrated)).
    pub fn calibrated() -> Self {
        let (scan, merge, sort, mat) = calibrate_compute();
        Self {
            scan_ns_per_edge_entry: scan,
            merge_ns_per_entry: merge,
            sort_ns_per_row: sort,
            materialize_ns_per_row: mat,
            net_ns_per_byte: 0.32,
            net_ns_per_msg: 2_000.0,
            disk_ns_per_byte: 0.4,
        }
    }

    /// Fixed constants (unit tests / reproducible examples).
    pub fn fixed() -> Self {
        Self {
            scan_ns_per_edge_entry: 6.0,
            merge_ns_per_entry: 40.0,
            sort_ns_per_row: 110.0,
            materialize_ns_per_row: 60.0,
            net_ns_per_byte: 0.32,
            net_ns_per_msg: 2_000.0,
            disk_ns_per_byte: 0.4,
        }
    }

    fn units_ns(&self, u: &WorkUnits) -> f64 {
        u.scan_edge_entries as f64 * self.scan_ns_per_edge_entry
            + u.merge_entries as f64 * self.merge_ns_per_entry
            + u.sort_rows as f64 * self.sort_ns_per_row
            + u.materialize_rows as f64 * self.materialize_ns_per_row
            + u.net_bytes as f64 * self.net_ns_per_byte
            + u.msgs as f64 * self.net_ns_per_msg
            + u.disk_bytes as f64 * self.disk_ns_per_byte
    }

    /// Modeled seconds for one phase: parallel part (max over workers)
    /// plus sequential tree rounds (max over groups each).
    pub fn phase_secs(&self, p: &PhaseWork) -> f64 {
        let parallel: f64 = p
            .per_worker
            .iter()
            .map(|u| self.units_ns(u))
            .fold(0.0, f64::max);
        let rounds: f64 = p
            .rounds
            .iter()
            .map(|groups| groups.iter().map(|u| self.units_ns(u)).fold(0.0, f64::max))
            .sum();
        (parallel + rounds) * 1e-9
    }

    /// Modeled total + per-phase breakdown.
    pub fn breakdown(&self, ledger: &WorkLedger) -> SimBreakdown {
        let per_phase: Vec<(String, f64)> = ledger
            .phases
            .iter()
            .map(|(name, p)| (name.clone(), self.phase_secs(p)))
            .collect();
        SimBreakdown { total_secs: per_phase.iter().map(|(_, s)| s).sum(), per_phase }
    }
}

/// Modeled time report.
#[derive(Debug, Clone)]
pub struct SimBreakdown {
    pub total_secs: f64,
    pub per_phase: Vec<(String, f64)>,
}

impl SimBreakdown {
    pub fn render(&self) -> String {
        let phases: Vec<String> = self
            .per_phase
            .iter()
            .filter(|(_, s)| *s > 0.0)
            .map(|(n, s)| format!("{n}={}", crate::util::bytes::fmt_secs(*s)))
            .collect();
        format!(
            "modeled cluster time {} [{}]",
            crate::util::bytes::fmt_secs(self.total_secs),
            phases.join(" ")
        )
    }
}

/// Measure per-unit compute costs with timed microloops (~10 ms total).
/// Returns (scan, merge, sort, materialize) ns/unit.
fn calibrate_compute() -> (f64, f64, f64, f64) {
    use crate::sampler::reservoir::TopK;
    use crate::util::rng::Xoshiro256;
    use std::time::Instant;

    // Scan: priority hash + reservoir threshold check per edge entry.
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut res = TopK::new(40);
    let n = 400_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        let p = crate::sampler::priority(7, 1, 3, 5, (i % 65536) as u32);
        res.insert(p, (i % 65536) as u32);
    }
    std::hint::black_box(&res);
    let scan = t0.elapsed().as_nanos() as f64 / n as f64;

    // Merge: moving reservoir entries between maps.
    let mut maps: Vec<crate::util::fxhash::FxHashMap<u64, TopK>> = (0..8)
        .map(|s| {
            let mut m = crate::util::fxhash::FxHashMap::default();
            for k in 0..2_000u64 {
                let mut t = TopK::new(20);
                for _ in 0..20 {
                    t.insert(rng.next_u64(), rng.next_u32());
                }
                m.insert(k.wrapping_mul(s + 1), t);
            }
            m
        })
        .collect();
    let entries: u64 = maps.iter().map(|m| m.values().map(|t| t.len() as u64).sum::<u64>()).sum();
    let t0 = Instant::now();
    let mut acc = maps.swap_remove(0);
    for m in maps {
        for (k, v) in m {
            match acc.entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().merge(&v),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
    }
    std::hint::black_box(&acc);
    let merge = t0.elapsed().as_nanos() as f64 / entries as f64;

    // Sort: 24-byte rows by (key, order).
    let mut rows: Vec<(u64, u64, u64)> =
        (0..300_000u64).map(|_| (rng.next_u64() % 512, rng.next_u64(), rng.next_u64())).collect();
    let t0 = Instant::now();
    rows.sort_unstable();
    std::hint::black_box(&rows);
    let sort = t0.elapsed().as_nanos() as f64 / rows.len() as f64;

    // Materialize: per-row allocation+write+concat of 24-byte rows, the
    // way the SQL engine's join output is produced.
    let n_rows = 200_000usize;
    let t0 = Instant::now();
    let mut chunks: Vec<Vec<(u64, u64, u64)>> = Vec::new();
    let mut cur = Vec::new();
    for i in 0..n_rows {
        cur.push((rng.next_u64(), rng.next_u64(), i as u64));
        if cur.len() == 4096 {
            chunks.push(std::mem::take(&mut cur));
        }
    }
    chunks.push(cur);
    let mut all: Vec<(u64, u64, u64)> = Vec::with_capacity(n_rows);
    for mut c in chunks {
        all.append(&mut c);
    }
    std::hint::black_box(&all);
    let mat = t0.elapsed().as_nanos() as f64 / n_rows as f64;

    (scan, merge, sort, mat)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn units(scan: u64, merge: u64) -> WorkUnits {
        WorkUnits { scan_edge_entries: scan, merge_entries: merge, ..Default::default() }
    }

    #[test]
    fn phase_time_is_makespan_not_sum() {
        let model = CostModel::fixed();
        let mut p = PhaseWork::new(4);
        p.per_worker[0] = units(1000, 0);
        p.per_worker[1] = units(1000, 0);
        let balanced = model.phase_secs(&p);
        let mut q = PhaseWork::new(4);
        q.per_worker[0] = units(2000, 0);
        let skewed = model.phase_secs(&q);
        assert!(skewed > balanced * 1.9, "{skewed} vs {balanced}");
    }

    #[test]
    fn tree_rounds_are_sequential_groups_parallel() {
        let model = CostModel::fixed();
        let mut p = PhaseWork::new(4);
        p.rounds.push(vec![units(0, 100), units(0, 100)]); // parallel → 100
        p.rounds.push(vec![units(0, 50)]); // → 50
        let secs = model.phase_secs(&p);
        let want = (150.0 * model.merge_ns_per_entry) * 1e-9;
        assert!((secs - want).abs() < 1e-12, "{secs} vs {want}");
    }

    #[test]
    fn ledger_charges_and_merges() {
        let mut a = WorkLedger::new(2);
        a.charge("scan", 0, units(10, 0));
        a.charge("scan", 3, units(5, 0)); // wraps to worker 1
        let mut b = WorkLedger::new(2);
        b.charge("scan", 1, units(7, 0));
        b.charge_round("merge", vec![units(0, 3)]);
        a.merge(&b);
        assert_eq!(a.phases["scan"].per_worker[0].scan_edge_entries, 10);
        assert_eq!(a.phases["scan"].per_worker[1].scan_edge_entries, 12);
        assert_eq!(a.phases["merge"].rounds.len(), 1);
        assert_eq!(a.total().scan_edge_entries, 22);
        assert_eq!(a.total().merge_entries, 3);
    }

    #[test]
    fn flat_vs_tree_model_ordering() {
        // 32 partials × 1000 entries: flat = serial 32k entries on one
        // worker; tree arity 4 = 3 rounds of parallel groups.
        let model = CostModel::fixed();
        let mut flat = PhaseWork::new(8);
        flat.per_worker[0] = units(0, 32_000);
        // tree: round 1: 8 groups × 4 partials (3 merged each → 3000)
        let mut tree = PhaseWork::new(8);
        tree.rounds.push(vec![units(0, 3_000); 8]);
        tree.rounds.push(vec![units(0, 12_000); 2]); // 2 groups of 4 level-2 maps
        tree.rounds.push(vec![units(0, 8_000)]); // final merge of 2
        assert!(
            model.phase_secs(&tree) < model.phase_secs(&flat) / 1.3,
            "tree {} flat {}",
            model.phase_secs(&tree),
            model.phase_secs(&flat)
        );
    }

    #[test]
    fn calibration_returns_sane_constants() {
        let m = CostModel::calibrated();
        assert!(m.scan_ns_per_edge_entry > 0.1 && m.scan_ns_per_edge_entry < 1_000.0);
        assert!(m.merge_ns_per_entry > 1.0 && m.merge_ns_per_entry < 10_000.0);
        assert!(m.sort_ns_per_row > 1.0 && m.sort_ns_per_row < 10_000.0);
        assert!(m.materialize_ns_per_row > 0.5 && m.materialize_ns_per_row < 10_000.0);
    }

    #[test]
    fn breakdown_sums_phases() {
        let model = CostModel::fixed();
        let mut l = WorkLedger::new(2);
        l.charge("a", 0, units(1000, 0));
        l.charge("b", 1, units(0, 1000));
        let b = model.breakdown(&l);
        assert_eq!(b.per_phase.len(), 2);
        let sum: f64 = b.per_phase.iter().map(|(_, s)| s).sum();
        assert!((b.total_secs - sum).abs() < 1e-15);
        assert!(b.render().contains("modeled cluster time"));
    }
}
