//! Network fabric accounting: every inter-worker transfer is charged here.
//!
//! Real wall-clock performance on this testbed comes from actual thread
//! parallelism; the fabric's job is *observability* (how many bytes would
//! cross the network, the tree-reduction fan-in, replication overhead) and
//! an optional analytic cost model that converts the traffic into
//! estimated cluster time for the EXPERIMENTS.md projections.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared, thread-safe traffic accounting for one simulated cluster.
#[derive(Debug, Clone)]
pub struct Fabric {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    workers: usize,
    /// Bytes sent by each worker.
    sent_bytes: Vec<AtomicU64>,
    /// Bytes received by each worker.
    recv_bytes: Vec<AtomicU64>,
    messages: AtomicU64,
}

/// Snapshot of fabric counters.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricStats {
    pub workers: usize,
    pub total_bytes: u64,
    pub total_messages: u64,
    pub per_worker_sent: Vec<u64>,
    pub per_worker_recv: Vec<u64>,
}

impl FabricStats {
    /// Counter-wise difference vs an earlier snapshot of the same fabric
    /// (per-run reporting off a long-lived fabric).
    pub fn delta(&self, earlier: &FabricStats) -> FabricStats {
        let sub = |a: &[u64], b: &[u64]| -> Vec<u64> {
            a.iter()
                .zip(b.iter().chain(std::iter::repeat(&0)))
                .map(|(x, y)| x.saturating_sub(*y))
                .collect()
        };
        FabricStats {
            workers: self.workers,
            total_bytes: self.total_bytes.saturating_sub(earlier.total_bytes),
            total_messages: self.total_messages.saturating_sub(earlier.total_messages),
            per_worker_sent: sub(&self.per_worker_sent, &earlier.per_worker_sent),
            per_worker_recv: sub(&self.per_worker_recv, &earlier.per_worker_recv),
        }
    }

    /// Max-over-mean of per-worker received bytes — the fan-in hot spot
    /// metric that the tree reduction is designed to flatten (E4).
    pub fn recv_imbalance(&self) -> f64 {
        crate::util::stats::Samples::from_iter(self.per_worker_recv.iter().map(|&b| b as f64))
            .imbalance()
    }

    /// Analytic transfer-time estimate (seconds) under an α-β cost model:
    /// `messages * latency + bottleneck_bytes / bandwidth`, where the
    /// bottleneck is the busiest receiver (links are full-duplex,
    /// per-worker NICs).
    pub fn estimate_time(&self, latency_s: f64, bandwidth_bps: f64) -> f64 {
        let bottleneck = self
            .per_worker_recv
            .iter()
            .chain(self.per_worker_sent.iter())
            .copied()
            .max()
            .unwrap_or(0) as f64;
        self.total_messages as f64 * latency_s + bottleneck * 8.0 / bandwidth_bps
    }
}

impl Fabric {
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1);
        Self {
            inner: Arc::new(Inner {
                workers,
                sent_bytes: (0..workers).map(|_| AtomicU64::new(0)).collect(),
                recv_bytes: (0..workers).map(|_| AtomicU64::new(0)).collect(),
                messages: AtomicU64::new(0),
            }),
        }
    }

    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Charge a transfer of `bytes` from `src` to `dst`.
    #[inline]
    pub fn charge(&self, src: usize, dst: usize, bytes: u64) {
        self.inner.sent_bytes[src].fetch_add(bytes, Ordering::Relaxed);
        self.inner.recv_bytes[dst].fetch_add(bytes, Ordering::Relaxed);
        self.inner.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> FabricStats {
        let per_worker_sent: Vec<u64> =
            self.inner.sent_bytes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let per_worker_recv: Vec<u64> =
            self.inner.recv_bytes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        FabricStats {
            workers: self.inner.workers,
            total_bytes: per_worker_sent.iter().sum(),
            total_messages: self.inner.messages.load(Ordering::Relaxed),
            per_worker_sent,
            per_worker_recv,
        }
    }

    pub fn reset(&self) {
        for a in &self.inner.sent_bytes {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.inner.recv_bytes {
            a.store(0, Ordering::Relaxed);
        }
        self.inner.messages.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let f = Fabric::new(3);
        f.charge(0, 1, 100);
        f.charge(0, 2, 50);
        f.charge(2, 1, 25);
        let s = f.stats();
        assert_eq!(s.total_bytes, 175);
        assert_eq!(s.total_messages, 3);
        assert_eq!(s.per_worker_sent, vec![150, 0, 25]);
        assert_eq!(s.per_worker_recv, vec![0, 125, 50]);
    }

    #[test]
    fn imbalance_detects_fan_in() {
        let f = Fabric::new(4);
        // Everyone sends to worker 0 — the flat-aggregation hot spot.
        for w in 1..4 {
            f.charge(w, 0, 1000);
        }
        assert!(f.stats().recv_imbalance() > 3.9);
    }

    #[test]
    fn cost_model_monotone_in_traffic() {
        let f = Fabric::new(2);
        f.charge(0, 1, 1_000_000);
        let t1 = f.stats().estimate_time(1e-5, 10e9);
        f.charge(0, 1, 9_000_000);
        let t2 = f.stats().estimate_time(1e-5, 10e9);
        assert!(t2 > t1);
    }

    #[test]
    fn delta_subtracts_counterwise() {
        let f = Fabric::new(2);
        f.charge(0, 1, 100);
        let before = f.stats();
        f.charge(0, 1, 50);
        f.charge(1, 0, 10);
        let d = f.stats().delta(&before);
        assert_eq!(d.total_bytes, 60);
        assert_eq!(d.total_messages, 2);
        assert_eq!(d.per_worker_sent, vec![50, 10]);
        assert_eq!(d.per_worker_recv, vec![10, 50]);
    }

    #[test]
    fn reset_zeroes() {
        let f = Fabric::new(2);
        f.charge(0, 1, 10);
        f.reset();
        assert_eq!(f.stats().total_bytes, 0);
        assert_eq!(f.stats().total_messages, 0);
    }

    #[test]
    fn concurrent_charges_are_consistent() {
        let f = Fabric::new(8);
        std::thread::scope(|s| {
            for w in 0..8 {
                let f = f.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        f.charge(w, (w + 1) % 8, 3);
                    }
                });
            }
        });
        let st = f.stats();
        assert_eq!(st.total_bytes, 8 * 1000 * 3);
        assert_eq!(st.total_messages, 8000);
    }
}
