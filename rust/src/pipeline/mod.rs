//! The in-memory generation→training pipeline — the paper's headline
//! integration: "as new subgraphs are generated, they are directly loaded
//! into memory and used for training" (§2 step 4).
//!
//! * [`queue`] — bounded MPMC queue with blocking push/pop, close
//!   semantics and backpressure counters. This queue *is* the "in-memory
//!   graph learning" handoff: it replaces GraphGen's disk round trip.
//!   [`QueueSink`] doubles as the look-ahead ring's admission gate: above
//!   the high-water mark it parks speculative generation until trainer
//!   dequeues return credits — granted **per wave sequence** and
//!   bucketed by the adaptive controller's effective depth
//!   ([`QueueSink::admits_by_depth`]) — and clamps wave-ahead cache
//!   warming to the same window.
//! * [`driver`] — runs generation and training concurrently (GraphGen+)
//!   or sequentially (ablation), producing the E6 comparison; also owns
//!   the generation/gather pool split ([`split_pool_budget`]).

pub mod driver;
pub mod queue;

pub use driver::{
    run_pipeline, run_pipeline_distributed, split_memory_budget, split_pool_budget,
    split_pool_budget_seeded, DistPipelineReport, PipelineMode, PipelineReport,
};
pub use queue::{BoundedQueue, QueueSink, QueueStats};
