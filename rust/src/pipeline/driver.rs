//! Pipeline driver: composes a generation engine with the trainer, either
//! **concurrently** (GraphGen+: subgraphs stream straight into training)
//! or **sequentially** (generate-everything-then-train, what any offline
//! or storage-backed flow does). The E6 experiment is exactly this
//! comparison.

use std::time::Duration;

use anyhow::Result;

use crate::cluster::proc::{
    run_coordinator_with, ConsumerCut, DistOptions, DistPlan, DistReport, WaveBytes,
};
use crate::cluster::FabricStats;
use crate::engines::{EngineConfig, GenReport, SubgraphEngine};
use crate::featurestore::FeatureService;
use crate::graph::csr::Csr;
use crate::graph::NodeId;
use crate::sampler::Subgraph;
use crate::train::trainer::{train, TrainConfig, TrainReport, TrainState};
use crate::train::ModelRuntime;
use crate::util::timer::Stopwatch;

use super::queue::{BoundedQueue, QueueSink, QueueStats};

/// How generation and training are composed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Generation streams into training through the bounded queue
    /// (the paper's design: "subgraph generation and training are
    /// executed concurrently").
    Concurrent,
    /// Generation fully completes before training starts (ablation; also
    /// the inherent behaviour of the offline engine).
    Sequential,
}

impl std::str::FromStr for PipelineMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "concurrent" => Ok(Self::Concurrent),
            "sequential" => Ok(Self::Sequential),
            other => Err(format!("unknown pipeline mode '{other}'")),
        }
    }
}

/// Combined outcome of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub mode: PipelineMode,
    pub gen: GenReport,
    pub train: TrainReport,
    pub queue: QueueStats,
    /// Feature-store traffic charged during this run (delta of the
    /// service's fabric over the run, so re-using one service across
    /// runs does not double-count).
    pub feature_fabric: FabricStats,
    /// End-to-end wall time (≤ gen.wall + train.wall when concurrent).
    pub wall: Duration,
    /// Generation-side pipeline bubble: wall time the wave loop stalled
    /// lane-starved, waiting for a prefetched wave that was not ready
    /// (the overlap gap; 0 when wave pipelining is off or fully hidden).
    /// The full stall taxonomy — lane-starved vs queue-full vs
    /// gather-wait — plus the adaptive depth controller's decision trace
    /// and the effective-depth occupancy histogram live in
    /// `gen.wave_pipeline`.
    pub bubble: Duration,
    /// Waves whose unique nodes were warmed into the feature cache ahead
    /// of training (0 without a cache).
    pub warmed_waves: u64,
    /// Waves whose warming was clamped because they completed above the
    /// queue's backpressure high-water mark (speculative run-ahead).
    pub warm_skipped_waves: u64,
}

impl PipelineReport {
    /// Overlap efficiency: how much wall time the concurrency saved
    /// relative to running the two phases back-to-back.
    pub fn overlap_ratio(&self) -> f64 {
        let serial = self.gen.wall.as_secs_f64() + self.train.wall.as_secs_f64();
        1.0 - self.wall.as_secs_f64() / serial
    }

    /// JSON view for the unified report writer ([`crate::obs::report`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("mode", format!("{:?}", self.mode))
            .set("wall_s", self.wall.as_secs_f64())
            .set("overlap_ratio", self.overlap_ratio())
            .set("bubble_s", self.bubble.as_secs_f64())
            .set("warmed_waves", self.warmed_waves)
            .set("warm_skipped_waves", self.warm_skipped_waves)
            .set("gen", self.gen.to_json())
            .set("train", self.train.to_json());
        let mut q = Json::obj();
        q.set("pushes", self.queue.pushes)
            .set("pops", self.queue.pops)
            .set("max_depth", self.queue.max_depth)
            .set("push_blocks", self.queue.push_blocks)
            .set("pop_blocks", self.queue.pop_blocks);
        o.set("queue", q);
        let mut ff = Json::obj();
        ff.set("total_bytes", self.feature_fabric.total_bytes)
            .set("total_messages", self.feature_fabric.total_messages);
        o.set("feature_fabric", ff);
        o
    }

    pub fn render(&self) -> String {
        use crate::util::bytes::{fmt_bytes, fmt_secs};
        let wp = &self.gen.wave_pipeline;
        format!(
            "mode={:?} wall={} gen={} train={} iters={} loss={:.4} acc={:.3} overlap={:.0}% bubble={} stalls[lane={} queue={} gather={}] depth_ctl[eff={} +{}/-{} decisions={}] workers_ctl[eff={} +{}/-{}] warmed_waves={} warm_skipped={} queue_max={} feat_remote={} feat_cache={:.0}%",
            self.mode,
            fmt_secs(self.wall.as_secs_f64()),
            fmt_secs(self.gen.wall.as_secs_f64()),
            fmt_secs(self.train.wall.as_secs_f64()),
            self.train.iterations,
            self.train.final_loss,
            self.train.accuracy,
            self.overlap_ratio() * 100.0,
            fmt_secs(self.bubble.as_secs_f64()),
            wp.lane_starved_stalls,
            wp.queue_full_stalls,
            fmt_secs(wp.gather_wait.as_secs_f64()),
            wp.effective_depth_last,
            wp.deepen_steps,
            wp.shallow_steps,
            wp.depth_trace.len(),
            wp.effective_workers_last,
            wp.worker_scale_ups,
            wp.worker_scale_downs,
            self.warmed_waves,
            self.warm_skipped_waves,
            self.queue.max_depth,
            fmt_bytes(self.train.feature_fetch.remote_bytes),
            self.train.feature_fetch.cache_hit_rate() * 100.0,
        )
    }
}

/// Queue capacity: enough for a few iteration groups of backlog — small
/// enough that generation feels backpressure instead of ballooning memory
/// (that bounded footprint is the "in-memory, no external storage" claim).
pub fn default_queue_cap(tcfg: &TrainConfig, batch: usize) -> usize {
    (tcfg.replicas * batch * 4).max(64)
}

/// Split the machine's worker threads between generation hop scans and
/// feature gathers for the concurrent pipeline. Gathers run on their own
/// pool ([`WorkPool::gather_global`](crate::util::workpool::WorkPool)),
/// so [`ShardedStore::gather_into`](crate::featurestore::ShardedStore)
/// bulk copies and hop scans genuinely run concurrently; this split
/// apportions the cores between the two sides.
/// `gather_threads == 0` picks the default split (a quarter of the
/// budget, at least one); an explicit request is clamped so generation
/// always keeps at least one thread and the shares sum to `total`. Both
/// shares are ≥ 1; on a single-thread budget the shares overlap — there
/// is nothing to partition.
pub fn split_pool_budget(total: usize, gather_threads: usize) -> (usize, usize) {
    let total = total.max(1);
    let cap = (total - 1).max(1);
    let gather = if gather_threads > 0 { gather_threads.min(cap) } else { (total / 4).max(1) };
    let gen = (total - gather.min(total - 1)).max(1);
    (gen, gather)
}

/// Parse the measured gather-pool knee (`knee_gather_threads`) out of an
/// E7 bench trajectory (`BENCH_e7.json`). `None` when the file is
/// missing, malformed, or records a degenerate knee.
pub fn knee_gather_threads_from(path: &std::path::Path) -> Option<usize> {
    let text = std::fs::read_to_string(path).ok()?;
    let doc = crate::util::json::Json::parse(&text).ok()?;
    doc.get("knee_gather_threads")?.as_usize().filter(|&k| k > 0)
}

/// Resolve the gather share fed to [`split_pool_budget`]: an explicit
/// `--gather-threads` request wins; otherwise the measured knee from the
/// E7 bench seeds the split (path `BENCH_e7.json`, overridable via
/// `GG_BENCH_E7_JSON`); with neither, 0 falls through to the quarter-split
/// default.
pub fn seeded_gather_threads(gather_threads: usize) -> usize {
    if gather_threads > 0 {
        return gather_threads;
    }
    let path = std::env::var("GG_BENCH_E7_JSON").unwrap_or_else(|_| "BENCH_e7.json".into());
    knee_gather_threads_from(std::path::Path::new(&path)).unwrap_or(0)
}

/// [`split_pool_budget`] with the E7 knee seeding applied, publishing the
/// chosen shares as obs gauges (`pool.gen_threads` / `pool.gather_threads`)
/// so snapshots record what the split actually was.
pub fn split_pool_budget_seeded(total: usize, gather_threads: usize) -> (usize, usize) {
    let (gen, gather) = split_pool_budget(total, seeded_gather_threads(gather_threads));
    crate::obs::metrics::gauge("pool.gen_threads").set(gen as f64);
    crate::obs::metrics::gauge("pool.gather_threads").set(gather as f64);
    (gen, gather)
}

/// Split the tiered-memory budget (`--memory-budget-mb`, already
/// env-resolved via [`crate::storage::tier::memory_budget_mb`]) between
/// the feature hot tier and the graph page cache, in bytes: half/half
/// when both sides are tiered, everything to the one side otherwise.
/// Returns `(feature_bytes, graph_bytes)`; a 0 budget (unlimited) stays
/// 0 on both sides. The chosen split is published as the
/// `tier.budget_feature_bytes` / `tier.budget_graph_bytes` gauges.
pub fn split_memory_budget(
    total_mb: usize,
    features_tiered: bool,
    graph_tiered: bool,
) -> (u64, u64) {
    let total = total_mb as u64 * 1024 * 1024;
    let (feat, graph) = match (total, features_tiered, graph_tiered) {
        (0, _, _) => (0, 0),
        (t, true, true) => (t / 2, t - t / 2),
        (t, true, false) => (t, 0),
        (t, false, true) => (0, t),
        (_, false, false) => (0, 0),
    };
    crate::obs::metrics::gauge("tier.budget_feature_bytes").set(feat as f64);
    crate::obs::metrics::gauge("tier.budget_graph_bytes").set(graph as f64);
    (feat, graph)
}

/// Run `engine` over `seeds` and train on the produced subgraphs.
#[allow(clippy::too_many_arguments)]
pub fn run_pipeline(
    graph: &Csr,
    seeds: &[NodeId],
    engine: &dyn SubgraphEngine,
    ecfg: &EngineConfig,
    features: &FeatureService,
    runtime: &ModelRuntime,
    tcfg: &TrainConfig,
    mode: PipelineMode,
) -> Result<PipelineReport> {
    let wall = Stopwatch::new();
    let feature_fabric_before = features.fabric_stats();
    let cap = default_queue_cap(tcfg, runtime.meta().spec.batch);
    let queue = BoundedQueue::<Subgraph>::new(cap);
    // Wave-ahead cache warming: only meaningful when the service has a
    // hot-node cache AND generation overlaps training — in sequential
    // mode all waves finish before training starts, so per-wave warming
    // would only churn the cache's preloaded hot set for nothing.
    let warmer = if features.has_cache() && mode == PipelineMode::Concurrent {
        Some(crate::featurestore::WaveWarmer::new(features))
    } else {
        None
    };
    let (gen_report, train_report) = match mode {
        PipelineMode::Concurrent => std::thread::scope(|scope| -> Result<_> {
            let gen_handle = scope.spawn(|| {
                crate::obs::trace::set_track(crate::obs::trace::Track::Generator);
                let _span = crate::obs::trace::span("generate");
                let sink = QueueSink::new(&queue, warmer.as_ref());
                let r = engine.generate(graph, seeds, ecfg, &sink);
                queue.close(); // close even on error so the trainer exits
                r
            });
            let train_report = train(runtime, features, &queue, tcfg);
            // If training died, the generator may be parked in push or in
            // the look-ahead backpressure wait with nobody left to drain —
            // close the queue so it fails fast and the scope can join it.
            if train_report.is_err() {
                queue.close();
            }
            let train_report = train_report?;
            let gen_report = gen_handle
                .join()
                .map_err(|_| anyhow::anyhow!("generator panicked"))??;
            Ok((gen_report, train_report))
        })?,
        PipelineMode::Sequential => {
            // Unbounded staging (the memory cost sequential pays).
            let staging = BoundedQueue::<Subgraph>::new(usize::MAX >> 1);
            let gen_report = {
                let _span = crate::obs::trace::span("generate");
                engine.generate(graph, seeds, ecfg, &QueueSink::new(&staging, warmer.as_ref()))?
            };
            staging.close();
            // Only after generation fully completed: forward into the
            // training queue while the trainer consumes.
            std::thread::scope(|scope| -> Result<_> {
                let fwd = scope.spawn(|| {
                    while let Some(sg) = staging.pop() {
                        if queue.push(sg).is_err() {
                            break;
                        }
                    }
                    queue.close();
                });
                let train_report = train(runtime, features, &queue, tcfg);
                // Same fail-fast as the concurrent arm: a dead trainer
                // must not leave the forwarder parked in push forever.
                if train_report.is_err() {
                    queue.close();
                }
                let train_report = train_report?;
                fwd.join().map_err(|_| anyhow::anyhow!("forwarder panicked"))?;
                Ok(train_report)
            })
            .map(|t| (gen_report, t))?
        }
    };
    Ok(PipelineReport {
        mode,
        queue: queue.stats(),
        bubble: gen_report.wave_pipeline.bubble,
        warmed_waves: warmer.as_ref().map_or(0, |w| w.stats().0),
        warm_skipped_waves: warmer.as_ref().map_or(0, |w| w.skipped()),
        gen: gen_report,
        train: train_report,
        feature_fabric: features.fabric_stats().delta(&feature_fabric_before),
        wall: wall.elapsed(),
    })
}

/// Outcome of one distributed pipeline run: multi-process generation
/// (coordinator + `gg-worker` processes) streaming into in-process
/// training through the same bounded queue.
#[derive(Debug, Clone)]
pub struct DistPipelineReport {
    pub dist: DistReport,
    pub train: TrainReport,
    pub queue: QueueStats,
    pub wall: Duration,
}

impl DistPipelineReport {
    pub fn render(&self) -> String {
        use crate::util::bytes::fmt_secs;
        format!(
            "dist-pipeline wall={} iters={} loss={:.4} acc={:.3} queue_max={}\n{}",
            fmt_secs(self.wall.as_secs_f64()),
            self.train.iterations,
            self.train.final_loss,
            self.train.accuracy,
            self.queue.max_depth,
            self.dist.render(),
        )
    }
}

/// Distributed counterpart of [`run_pipeline`]'s concurrent mode: the
/// coordinator assigns waves to worker *processes* and emits their
/// decoded subgraphs — FIFO by wave, slot order within a wave, exactly
/// the in-process emission order — into the training queue. Because the
/// stream is byte-identical to the single-process oracle, the loss curve
/// is too.
///
/// Checkpoint/restart: when `opts.checkpoint_waves` is set, the
/// coordinator's snapshot hook cuts at the trainer's last *completed*
/// iteration — the published [`TrainState`] rides in the checkpoint
/// payload, and the cut wave + skip count locate the exact subgraph the
/// resumed trainer needs next. A run resumed from `opts.resume_from`
/// drops the already-trained prefix of the first re-emitted wave and
/// finishes with the loss curve byte-identical to an uninterrupted run.
pub fn run_pipeline_distributed(
    plan: &DistPlan,
    opts: &DistOptions,
    features: &FeatureService,
    runtime: &ModelRuntime,
    tcfg: &TrainConfig,
) -> Result<DistPipelineReport> {
    let wall = Stopwatch::new();
    let cap = default_queue_cap(tcfg, runtime.meta().spec.batch);
    let queue = BoundedQueue::<Subgraph>::new(cap);
    let group = (tcfg.replicas.max(1) * runtime.meta().spec.batch) as u64;

    let mut tcfg = tcfg.clone();
    let mut skip = 0u64;
    let resume_state = match &opts.resume_from {
        Some(ck) => {
            skip = ck.skip_subgraphs;
            let st = if ck.payload.is_empty() {
                TrainState::default()
            } else {
                TrainState::decode(&ck.payload)?
            };
            tcfg.resume = Some(st.clone());
            st
        }
        None => TrainState::default(),
    };
    // Seeded with the resumed state so a checkpoint taken before the
    // trainer completes any new iteration still cuts at the old spot.
    let publish = std::sync::Arc::new(std::sync::Mutex::new(resume_state.clone()));
    tcfg.publish = Some(publish.clone());
    let tcfg = &tcfg;
    // Absolute index of the first subgraph the coordinator will
    // re-emit: everything the resumed trainer already consumed, minus
    // the tail of the cut wave it had not finished.
    let abs_base = (resume_state.iteration * group).saturating_sub(skip);

    let (dist, train_report) = std::thread::scope(|scope| -> Result<_> {
        let coord = scope.spawn(|| {
            crate::obs::trace::set_track(crate::obs::trace::Track::Generator);
            let _span = crate::obs::trace::span("generate_distributed");
            // (next absolute index, subgraphs left to skip, per-wave
            // (wave, abs start, count)) — shared between the emit path
            // and the snapshot hook, which both run on this thread.
            let index = std::cell::RefCell::new((abs_base, skip, Vec::<(u64, u64, u64)>::new()));
            let mut emit = |wb: WaveBytes| -> Result<()> {
                let sgs = wb.decode()?;
                let (abs_next, to_skip, waves) = &mut *index.borrow_mut();
                waves.push((wb.wave, *abs_next, sgs.len() as u64));
                *abs_next += sgs.len() as u64;
                let dropped = (*to_skip).min(sgs.len() as u64);
                *to_skip -= dropped;
                for sg in sgs.into_iter().skip(dropped as usize) {
                    anyhow::ensure!(queue.push(sg).is_ok(), "training queue closed early");
                }
                Ok(())
            };
            let mut snapshot = |frontier: u64| -> Result<ConsumerCut> {
                let st = publish.lock().unwrap().clone();
                let (_, _, waves) = &*index.borrow();
                // The trainer consumed `iteration × group` subgraphs;
                // find the emitted wave containing that boundary. All
                // consumed → cut at the emit frontier.
                let consumed = st.iteration * group;
                let mut cut = (frontier, 0u64);
                for &(w, start, count) in waves.iter() {
                    if consumed < start + count {
                        cut = (w, consumed.saturating_sub(start));
                        break;
                    }
                }
                Ok(ConsumerCut {
                    resume_wave: cut.0,
                    skip_subgraphs: cut.1,
                    emitted_bytes: 0,
                    payload: st.encode(),
                })
            };
            let r = run_coordinator_with(plan, opts, &mut emit, Some(&mut snapshot));
            queue.close(); // close even on error so the trainer exits
            r
        });
        let train_report = train(runtime, features, &queue, tcfg);
        // A dead trainer must not leave the coordinator parked in push:
        // closing the queue fails its emit, which tears the run down
        // (workers killed, children reaped) inside `run_coordinator`.
        if train_report.is_err() {
            queue.close();
        }
        let train_report = train_report?;
        let dist =
            coord.join().map_err(|_| anyhow::anyhow!("coordinator panicked"))??;
        Ok((dist, train_report))
    })?;
    Ok(DistPipelineReport {
        dist,
        train: train_report,
        queue: queue.stats(),
        wall: wall.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn pool_budget_partitions_without_oversubscribing() {
        // Auto split: a quarter to gather, remainder to generation.
        assert_eq!(split_pool_budget(8, 0), (6, 2));
        assert_eq!(split_pool_budget(16, 0), (12, 4));
        // Explicit requests clamp so the shares sum to the budget and
        // generation keeps at least one thread.
        assert_eq!(split_pool_budget(8, 3), (5, 3));
        assert_eq!(split_pool_budget(8, 8), (1, 7));
        assert_eq!(split_pool_budget(8, 100), (1, 7));
        // Degenerate single-thread budget: both shares overlap on it.
        assert_eq!(split_pool_budget(1, 0), (1, 1));
        assert_eq!(split_pool_budget(1, 5), (1, 1));
        assert_eq!(split_pool_budget(0, 0), (1, 1));
    }

    #[test]
    fn memory_budget_splits_by_tiered_sides() {
        const MB: u64 = 1024 * 1024;
        // Unlimited budget stays unlimited on both sides.
        assert_eq!(split_memory_budget(0, true, true), (0, 0));
        // Both tiered: half each (odd totals round the graph side up).
        assert_eq!(split_memory_budget(64, true, true), (32 * MB, 32 * MB));
        assert_eq!(split_memory_budget(1, true, true), (MB / 2, MB - MB / 2));
        // One side tiered: it gets the whole budget.
        assert_eq!(split_memory_budget(64, true, false), (64 * MB, 0));
        assert_eq!(split_memory_budget(64, false, true), (0, 64 * MB));
        assert_eq!(split_memory_budget(64, false, false), (0, 0));
        // The chosen split lands on the gauges for snapshots.
        let (f, g) = split_memory_budget(10, true, true);
        assert_eq!(crate::obs::metrics::gauge("tier.budget_feature_bytes").get(), f as f64);
        assert_eq!(crate::obs::metrics::gauge("tier.budget_graph_bytes").get(), g as f64);
    }

    #[test]
    fn knee_seeding_reads_bench_trajectory() {
        let dir = std::env::temp_dir().join(format!("gg_knee_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_e7.json");

        // Missing file → no knee.
        assert_eq!(knee_gather_threads_from(&path), None);

        // Well-formed trajectory → the recorded knee.
        std::fs::write(&path, r#"{"bench":"e7_featurestore","knee_gather_threads":4}"#).unwrap();
        assert_eq!(knee_gather_threads_from(&path), Some(4));
        // The seeded split hands the knee to the gather pool.
        assert_eq!(split_pool_budget(16, 4), (12, 4));

        // Malformed / degenerate values → no knee, not a panic.
        std::fs::write(&path, "{not json").unwrap();
        assert_eq!(knee_gather_threads_from(&path), None);
        std::fs::write(&path, r#"{"knee_gather_threads":0}"#).unwrap();
        assert_eq!(knee_gather_threads_from(&path), None);
        std::fs::write(&path, r#"{"knee_gather_threads":"four"}"#).unwrap();
        assert_eq!(knee_gather_threads_from(&path), None);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_and_sequential_agree_on_results() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let gen = generator::from_spec("planted:n=1024,e=8192,c=8", 7).unwrap();
        let g = gen.csr();
        let features = FeatureService::procedural(
            crate::graph::features::FeatureStore::with_labels(
                spec.dim,
                spec.classes as u32,
                gen.labels.clone().unwrap(),
                2,
            ),
        );
        let seeds: Vec<NodeId> = (0..(spec.batch as u32 * 2 * 4)).collect();
        let ecfg = EngineConfig {
            workers: 4,
            wave_size: 128,
            fanout: FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]),
            ..Default::default()
        };
        let tcfg = TrainConfig { replicas: 2, curve_every: 1, ..Default::default() };
        let conc = run_pipeline(
            &g, &seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
            PipelineMode::Concurrent,
        )
        .unwrap();
        let seq = run_pipeline(
            &g, &seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
            PipelineMode::Sequential,
        )
        .unwrap();
        // Same subgraphs, same order, same replicas → same losses.
        assert_eq!(conc.train.iterations, seq.train.iterations);
        assert_eq!(conc.train.iterations, 4);
        assert!((conc.train.final_loss - seq.train.final_loss).abs() < 1e-5);
        // Concurrent must overlap: wall < gen.wall + train.wall.
        assert!(conc.wall <= conc.gen.wall + conc.train.wall + Duration::from_millis(50));
        assert!(conc.queue.max_depth <= default_queue_cap(&tcfg, spec.batch));
        runtime.shutdown();
    }
}
