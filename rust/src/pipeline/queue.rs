//! Bounded MPMC queue with close semantics and backpressure accounting.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::engines::common::MAX_TRACKED_DEPTH;
use crate::engines::SubgraphSink;
use crate::sampler::Subgraph;

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    // stats
    pushes: u64,
    pops: u64,
    max_depth: usize,
    push_blocks: u64,
    pop_blocks: u64,
}

/// Backpressure counters snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueStats {
    pub pushes: u64,
    pub pops: u64,
    pub max_depth: usize,
    /// Producer had to wait (queue full) this many times.
    pub push_blocks: u64,
    /// Consumer had to wait (queue empty) this many times.
    pub pop_blocks: u64,
}

/// Blocking bounded queue. `push` blocks at capacity (backpressure on the
/// generator), `pop` blocks when empty and returns `None` once the queue
/// is closed and drained.
pub struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Self {
            cap,
            state: Mutex::new(State {
                items: VecDeque::new(),
                closed: false,
                pushes: 0,
                pops: 0,
                max_depth: 0,
                push_blocks: 0,
                pop_blocks: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Blocking push. Returns `Err` if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        while st.items.len() >= self.cap && !st.closed {
            st.push_blocks += 1;
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        st.pushes += 1;
        st.max_depth = st.max_depth.max(st.items.len());
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                st.pops += 1;
                drop(st);
                // `not_full` has two kinds of waiters — capacity-blocked
                // producers and look-ahead backpressure waits
                // (`wait_depth_at_most`) — so a single token could land
                // on the wrong one and strand the other.
                self.not_full.notify_all();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st.pop_blocks += 1;
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Block until the queue depth is at or below `mark` (credits return
    /// as the consumer dequeues) or the queue is closed — the look-ahead
    /// ring's backpressure wait. Returns immediately when already below.
    pub fn wait_depth_at_most(&self, mark: usize) {
        let mut st = self.state.lock().unwrap();
        while st.items.len() > mark && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
    }

    pub fn stats(&self) -> QueueStats {
        let st = self.state.lock().unwrap();
        QueueStats {
            pushes: st.pushes,
            pops: st.pops,
            max_depth: st.max_depth,
            push_blocks: st.push_blocks,
            pop_blocks: st.pop_blocks,
        }
    }
}

/// Adapter: lets a generation engine stream into a queue. With a
/// [`WaveWarmer`](crate::featurestore::WaveWarmer) attached, each
/// completed wave's unique nodes are pushed into the feature cache from
/// the generator thread — a whole wave ahead of the batches that need
/// them (see [`crate::featurestore::prefetch`]).
///
/// The sink is also the look-ahead ring's backpressure authority: while
/// the queue sits above `high_water`, [`SubgraphSink::lookahead_admit`]
/// refuses new speculative waves and [`SubgraphSink::lookahead_wait`]
/// parks the ring until the trainer's dequeues return credits — so
/// generation memory (queue + in-flight lanes) stays bounded even at
/// deep look-ahead. Credits are granted **per wave sequence**: each
/// admission is reported through
/// [`SubgraphSink::lookahead_admitted`] with the adaptive controller's
/// effective depth, and [`QueueSink::admits_by_depth`] buckets them on
/// that axis so the sink's view matches the ring's occupancy histogram
/// and decision trace. Warming is clamped to the same window: a wave that
/// completes while the queue is above the mark is far ahead of
/// consumption, and inserting its rows would evict the hot set batches
/// pending *now* still need.
pub struct QueueSink<'a> {
    pub queue: &'a BoundedQueue<Subgraph>,
    /// Optional wave-ahead feature warmer.
    pub warm: Option<&'a crate::featurestore::WaveWarmer<'a>>,
    /// Look-ahead admission high-water mark (queue depth).
    pub high_water: usize,
    /// Per-sequence admission credits, bucketed by the adaptive
    /// controller's effective depth at grant time — the same axis the
    /// ring's occupancy histogram and decision trace use, so the three
    /// views stay consistent (credits used to be observable only as an
    /// aggregate, which drifted from the histogram whenever the
    /// controller moved mid-run).
    admits_by_depth: [AtomicU64; MAX_TRACKED_DEPTH],
}

impl<'a> QueueSink<'a> {
    /// Default backpressure window: 3/4 of the queue capacity. Unbounded
    /// staging queues get an effectively infinite mark — never gated.
    pub fn default_high_water(cap: usize) -> usize {
        (cap - cap / 4).max(1)
    }

    pub fn new(
        queue: &'a BoundedQueue<Subgraph>,
        warm: Option<&'a crate::featurestore::WaveWarmer<'a>>,
    ) -> Self {
        let high_water = Self::default_high_water(queue.capacity());
        Self {
            queue,
            warm,
            high_water,
            admits_by_depth: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Override the backpressure mark (tests, tuning).
    pub fn with_high_water(mut self, mark: usize) -> Self {
        self.high_water = mark.max(1);
        self
    }

    /// Snapshot of the per-sequence admission credits: `[d]` counts waves
    /// admitted while the ring's effective look-ahead depth was `d`
    /// (clamped to `MAX_TRACKED_DEPTH - 1`). Totals match the ring's
    /// occupancy histogram wave for wave; a single wave can sit one
    /// bucket apart from its occupancy entry when the controller moved
    /// between its admission and its retirement.
    pub fn admits_by_depth(&self) -> [u64; MAX_TRACKED_DEPTH] {
        std::array::from_fn(|d| self.admits_by_depth[d].load(Ordering::Relaxed))
    }
}

impl SubgraphSink for QueueSink<'_> {
    fn accept(&self, _worker: usize, sg: Subgraph) -> anyhow::Result<()> {
        self.queue
            .push(sg)
            .map_err(|_| anyhow::anyhow!("pipeline queue closed while generating"))
    }

    fn wants_waves(&self) -> bool {
        self.warm.is_some()
    }

    fn wave_complete(&self, nodes: &[crate::graph::NodeId]) {
        if let Some(w) = self.warm {
            if self.queue.len() > self.high_water {
                w.note_skipped();
            } else {
                w.warm(nodes);
            }
        }
    }

    fn lookahead_admit(&self) -> bool {
        self.queue.len() <= self.high_water
    }

    fn lookahead_wait(&self) {
        let _span = crate::obs::trace::span("queue.wait");
        self.queue.wait_depth_at_most(self.high_water);
    }

    fn lookahead_admitted(&self, seq: u64, depth: usize) {
        self.admits_by_depth[depth.min(MAX_TRACKED_DEPTH - 1)].fetch_add(1, Ordering::Relaxed);
        crate::obs::trace::instant_on(
            crate::obs::trace::Track::Queue,
            "queue.admit",
            &[("seq", seq as f64), ("depth", depth as f64)],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        q.close();
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_producer() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                q2.push(i).unwrap();
            }
            q2.close();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        // Producer can be at most cap ahead.
        assert!(q.len() <= 2);
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
        let st = q.stats();
        assert!(st.push_blocks > 0, "producer should have hit backpressure");
        assert_eq!(st.pushes, 100);
        assert_eq!(st.pops, 100);
        assert!(st.max_depth <= 2);
    }

    #[test]
    fn close_unblocks_everyone() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
        assert!(q.push(1).is_err());
    }

    #[test]
    fn wait_depth_at_most_returns_on_drain_and_close() {
        let q = Arc::new(BoundedQueue::new(8));
        for i in 0..6 {
            q.push(i).unwrap();
        }
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.wait_depth_at_most(2));
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!waiter.is_finished(), "must block while above the mark");
        for _ in 0..4 {
            q.pop();
        }
        waiter.join().unwrap();
        // Closing releases a fresh waiter even above the mark.
        let q3 = Arc::new(BoundedQueue::new(8));
        for i in 0..6 {
            q3.push(i).unwrap();
        }
        let q4 = q3.clone();
        let waiter = std::thread::spawn(move || q4.wait_depth_at_most(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        q3.close();
        waiter.join().unwrap();
    }

    #[test]
    fn sink_gates_lookahead_on_high_water() {
        let q = BoundedQueue::<Subgraph>::new(16);
        let sink = QueueSink::new(&q, None).with_high_water(2);
        assert!(sink.lookahead_admit());
        for s in 0..3u32 {
            q.push(Subgraph::new(s)).unwrap();
        }
        assert!(!sink.lookahead_admit(), "above the mark must refuse admission");
        q.pop();
        assert!(sink.lookahead_admit(), "dequeue returns credits");
    }

    #[test]
    fn admission_credits_bucket_by_effective_depth() {
        let q = BoundedQueue::<Subgraph>::new(16);
        let sink = QueueSink::new(&q, None);
        sink.lookahead_admitted(0, 2);
        sink.lookahead_admitted(1, 2);
        sink.lookahead_admitted(2, 1);
        // Depths beyond the tracked range fold into the last bucket.
        sink.lookahead_admitted(3, MAX_TRACKED_DEPTH + 5);
        let by_depth = sink.admits_by_depth();
        assert_eq!(by_depth[2], 2);
        assert_eq!(by_depth[1], 1);
        assert_eq!(by_depth[MAX_TRACKED_DEPTH - 1], 1);
        assert_eq!(by_depth.iter().sum::<u64>(), 4);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 4 * 500;
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500 {
                    q.push(p * 500 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                while let Some(v) = q.pop() {
                    local.push(v);
                }
                local
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..total).collect::<Vec<_>>());
    }
}
