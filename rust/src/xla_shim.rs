//! API-compatible stand-in for the `xla` crate's PJRT surface.
//!
//! The build environment for this tree does not ship libxla (the `xla`
//! crate needs the XLA extension shared library at build time), so
//! [`crate::train::runtime`] compiles against this shim instead:
//! construction of executables fails with an actionable error, every
//! artifact-gated test skips cleanly, and the rest of the L3 system —
//! engines, feature store, pipeline — builds and tests unchanged. To run
//! real training, swap `use crate::xla_shim as xla;` in
//! `train/runtime.rs` back to the real crate; the type and method
//! signatures below mirror exactly the subset the runtime uses.

/// Error type mirroring the crate's (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: built against the xla shim (libxla not present in this \
         environment; see DESIGN.md §runtime)"
    ))
}

/// Element types the shim's [`Literal`] carries.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// Host-side tensor stand-in. Carries no data — it only needs to
/// typecheck the argument-marshalling code paths.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module. The shim cannot parse HLO text, so loading any
/// artifact fails here — before a client or executable is ever built.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compilation"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_loading_fails_actionably() {
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(format!("{err}").contains("xla shim"));
        // Literal marshalling typechecks and round-trips shape calls.
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
        assert!(PjRtClient::cpu().is_ok());
        assert!(PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&HloModuleProto)).is_err());
    }
}
