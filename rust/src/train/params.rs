//! Parameter store: deterministic initialization (all replicas start
//! identical without any broadcast) and flatten/unflatten for AllReduce.

use crate::util::rng::{mix2, Xoshiro256};

use super::meta::ModelMeta;

/// One model replica's parameters, in `meta.param_names` order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamStore {
    pub params: Vec<Vec<f32>>,
    shapes: Vec<Vec<usize>>,
}

impl ParamStore {
    /// Glorot-uniform weights / zero biases, matching python
    /// `model.init_params` in spirit (exact values differ; determinism and
    /// scale are what matter — every worker calls this with the same seed
    /// and gets bit-identical replicas).
    pub fn init(meta: &ModelMeta, seed: u64) -> Self {
        let mut params = Vec::with_capacity(meta.param_shapes.len());
        for (i, shape) in meta.param_shapes.iter().enumerate() {
            let n: usize = shape.iter().product();
            if shape.len() == 1 {
                params.push(vec![0.0; n]); // biases
            } else {
                let mut rng = Xoshiro256::seed_from_u64(mix2(seed, i as u64));
                let limit = (6.0 / (shape[0] + shape[1]) as f32).sqrt();
                params.push((0..n).map(|_| (rng.gen_f32() * 2.0 - 1.0) * limit).collect());
            }
        }
        Self { params, shapes: meta.param_shapes.clone() }
    }

    /// Concatenate all gradients/params into one AllReduce buffer.
    pub fn flatten(tensors: &[Vec<f32>]) -> Vec<f32> {
        let total: usize = tensors.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for t in tensors {
            out.extend_from_slice(t);
        }
        out
    }

    /// Split a flat buffer back into this store's tensor shapes.
    pub fn unflatten(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.shapes.len());
        let mut off = 0usize;
        for shape in &self.shapes {
            let n: usize = shape.iter().product();
            out.push(flat[off..off + n].to_vec());
            off += n;
        }
        assert_eq!(off, flat.len(), "flat buffer size mismatch");
        out
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::meta::{ModelMeta, ModelSpec};

    fn meta() -> ModelMeta {
        ModelMeta {
            dir: std::path::PathBuf::new(),
            spec: ModelSpec { batch: 2, f1: 2, f2: 2, dim: 4, hidden: 6, classes: 3 },
            param_names: ["ws1", "wn1", "b1", "ws2", "wn2", "b2"].map(String::from).to_vec(),
            param_shapes: vec![
                vec![4, 6],
                vec![4, 6],
                vec![6],
                vec![6, 3],
                vec![6, 3],
                vec![3],
            ],
            grad_file: "g".into(),
            apply_file: "a".into(),
            forward_file: "f".into(),
        }
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let m = meta();
        let a = ParamStore::init(&m, 7);
        let b = ParamStore::init(&m, 7);
        assert_eq!(a, b);
        let c = ParamStore::init(&m, 8);
        assert_ne!(a, c);
        // biases zero, weights within glorot bound
        assert!(a.params[2].iter().all(|&v| v == 0.0));
        let limit = (6.0f32 / 10.0).sqrt();
        assert!(a.params[0].iter().all(|&v| v.abs() <= limit));
        assert!(a.params[0].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let m = meta();
        let store = ParamStore::init(&m, 3);
        let flat = ParamStore::flatten(&store.params);
        assert_eq!(flat.len(), store.num_params());
        let back = store.unflatten(&flat);
        assert_eq!(back, store.params);
    }

    #[test]
    #[should_panic]
    fn unflatten_rejects_wrong_size() {
        let m = meta();
        let store = ParamStore::init(&m, 3);
        store.unflatten(&[0.0; 3]);
    }
}
