//! Synchronous data-parallel training loop (Alg. 1 step 4 / lines 23-30):
//! each worker fetches the next available subgraphs from the in-memory
//! queue, runs a mini-batch gradient step, and synchronizes gradients
//! across all workers with AllReduce.
//!
//! Replica mechanics: every worker initializes identical parameters
//! (deterministic seed), computes local grads via the compiled artifact,
//! mean-AllReduces `[grads… , loss, correct]` over the simulated fabric,
//! and applies the same averaged update — replicas stay bit-identical
//! (asserted in tests) without any parameter broadcast.
//!
//! Feature rows come from a [`FeatureService`] (procedural or sharded —
//! byte-identical either way, so the trajectory is backend-independent).
//! With [`TrainConfig::prefetch`] the gather for iteration t+1 overlaps
//! training on iteration t ([`crate::featurestore::prefetch`]).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::collective::{group, AllReduceAlgo};
use crate::cluster::{Fabric, FabricStats};
use crate::featurestore::{spawn_prefetcher, BatchFeed, FeatureService, FetchStats};
use crate::pipeline::BoundedQueue;
use crate::sampler::Subgraph;
use crate::train::params::ParamStore;
use crate::train::runtime::ModelRuntime;
use crate::util::timer::Stopwatch;

/// Training-loop settings.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Data-parallel workers (model replicas).
    pub replicas: usize,
    pub lr: f32,
    pub allreduce: AllReduceAlgo,
    /// Parameter init seed (same on every replica).
    pub init_seed: u64,
    /// Record the loss every N iterations into the curve.
    pub curve_every: u64,
    /// Materialize batch t+1's features while batch t trains.
    pub prefetch: bool,
    /// Resume from a mid-run snapshot: parameters, loss history and
    /// counters carry over so the finished run is byte-identical to an
    /// uninterrupted one (coordinator checkpoint/restart).
    pub resume: Option<TrainState>,
    /// After every applied iteration, worker 0 publishes the full
    /// [`TrainState`] here; the coordinator's checkpoint hook snapshots
    /// it to cut the resume point at a consumed-iteration boundary.
    pub publish: Option<Arc<Mutex<TrainState>>>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            replicas: 2,
            lr: 0.05,
            allreduce: AllReduceAlgo::Ring,
            init_seed: 0x11,
            curve_every: 10,
            prefetch: false,
            resume: None,
            publish: None,
        }
    }
}

/// A bit-exact mid-run snapshot of the training loop, taken at a
/// synchronous iteration boundary (where all replicas hold identical
/// parameters by construction).
///
/// The distributed pipeline serializes this into the coordinator
/// checkpoint payload ([`crate::cluster::proc::ConsumerCut`]); on
/// `--resume` the trainer restarts from it and the finished run's loss
/// curve, counters and parameters are byte-identical to an
/// uninterrupted run — f32s round-trip through raw little-endian bits,
/// so no precision is lost in the encode/decode cycle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrainState {
    /// Completed synchronous iterations.
    pub iteration: u64,
    /// Cumulative subgraphs consumed by those iterations.
    pub subgraphs_trained: u64,
    /// Cumulative sampled node slots consumed by those iterations.
    pub nodes_trained: u64,
    /// Per-iteration global mean loss, from iteration 1.
    pub losses: Vec<f32>,
    /// Per-iteration mean training accuracy.
    pub accs: Vec<f32>,
    /// Model parameters after `iteration` applied updates.
    pub params: Vec<Vec<f32>>,
}

impl TrainState {
    /// Serialize as little-endian binary (checkpoint payload).
    pub fn encode(&self) -> Vec<u8> {
        fn w64(out: &mut Vec<u8>, v: u64) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        fn wf32s(out: &mut Vec<u8>, v: &[f32]) {
            w64(out, v.len() as u64);
            for &f in v {
                out.extend_from_slice(&f.to_le_bytes());
            }
        }
        let mut out = Vec::new();
        w64(&mut out, self.iteration);
        w64(&mut out, self.subgraphs_trained);
        w64(&mut out, self.nodes_trained);
        wf32s(&mut out, &self.losses);
        wf32s(&mut out, &self.accs);
        w64(&mut out, self.params.len() as u64);
        for layer in &self.params {
            wf32s(&mut out, layer);
        }
        out
    }

    /// Inverse of [`TrainState::encode`]; bit-exact for every f32.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        fn r64(buf: &[u8], pos: &mut usize) -> Result<u64> {
            let s = buf.get(*pos..*pos + 8).context("train state truncated")?;
            *pos += 8;
            Ok(u64::from_le_bytes(s.try_into().unwrap()))
        }
        fn rf32s(buf: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
            let n = r64(buf, pos)? as usize;
            anyhow::ensure!(
                n <= buf.len().saturating_sub(*pos) / 4,
                "train state length field corrupt"
            );
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let s = buf.get(*pos..*pos + 4).context("train state truncated")?;
                *pos += 4;
                v.push(f32::from_le_bytes(s.try_into().unwrap()));
            }
            Ok(v)
        }
        let mut pos = 0usize;
        let iteration = r64(buf, &mut pos)?;
        let subgraphs_trained = r64(buf, &mut pos)?;
        let nodes_trained = r64(buf, &mut pos)?;
        let losses = rf32s(buf, &mut pos)?;
        let accs = rf32s(buf, &mut pos)?;
        let layers = r64(buf, &mut pos)? as usize;
        anyhow::ensure!(layers <= 1 << 20, "train state layer count corrupt");
        let mut params = Vec::with_capacity(layers);
        for _ in 0..layers {
            params.push(rf32s(buf, &mut pos)?);
        }
        anyhow::ensure!(pos == buf.len(), "trailing bytes in train state");
        Ok(Self { iteration, subgraphs_trained, nodes_trained, losses, accs, params })
    }
}

/// Outcome of one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Synchronous iterations (each = `replicas` batches + 1 AllReduce).
    pub iterations: u64,
    pub subgraphs_trained: u64,
    /// Sampled node slots consumed — the "nodes per iteration" unit.
    pub nodes_trained: u64,
    /// Subgraphs dropped because they couldn't fill a full iteration
    /// group (uniform-work semantics, like the balance table's discard).
    pub subgraphs_dropped: u64,
    pub final_loss: f32,
    /// Mean training accuracy over the final 25% of iterations.
    pub accuracy: f32,
    /// (iteration, global mean loss) samples.
    pub loss_curve: Vec<(u64, f32)>,
    pub wall: Duration,
    /// AllReduce traffic.
    pub fabric: FabricStats,
    /// Feature-store fetch counters for this run (dedup, cache hits,
    /// remote rows/bytes — see the E7 benchmark).
    pub feature_fetch: FetchStats,
    /// Batch-buffer arena counters for this run: after warm-up (the first
    /// two iterations), batch assembly must allocate nothing
    /// (`steady_allocs == 0`).
    pub batch_reuse: crate::train::batch::BatchReuse,
    /// The trained parameters (replica 0 — all replicas are identical).
    pub params: Vec<Vec<f32>>,
}

impl TrainReport {
    /// JSON view for the unified report writer ([`crate::obs::report`]).
    /// Trained parameters are omitted (bulky, reproducible from the seed).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("iterations", self.iterations)
            .set("subgraphs_trained", self.subgraphs_trained)
            .set("nodes_trained", self.nodes_trained)
            .set("subgraphs_dropped", self.subgraphs_dropped)
            .set("final_loss", self.final_loss as f64)
            .set("accuracy", self.accuracy as f64)
            .set("wall_s", self.wall.as_secs_f64());
        let curve: Vec<Json> = self
            .loss_curve
            .iter()
            .map(|&(i, l)| Json::Arr(vec![Json::from(i), Json::from(l as f64)]))
            .collect();
        o.set("loss_curve", Json::Arr(curve));
        let mut fabric = Json::obj();
        fabric
            .set("workers", self.fabric.workers)
            .set("total_bytes", self.fabric.total_bytes)
            .set("total_messages", self.fabric.total_messages);
        o.set("fabric", fabric);
        let mut fetch = Json::obj();
        fetch
            .set("requested", self.feature_fetch.requested)
            .set("unique", self.feature_fetch.unique)
            .set("cache_hits", self.feature_fetch.cache_hits)
            .set("local_rows", self.feature_fetch.local_rows)
            .set("remote_rows", self.feature_fetch.remote_rows)
            .set("remote_bytes", self.feature_fetch.remote_bytes)
            .set("remote_msgs", self.feature_fetch.remote_msgs)
            .set("gathers", self.feature_fetch.gathers);
        o.set("feature_fetch", fetch);
        let mut reuse = Json::obj();
        reuse
            .set("allocated", self.batch_reuse.allocated)
            .set("reused", self.batch_reuse.reused)
            .set("steady_allocs", self.batch_reuse.steady_allocs);
        o.set("batch_reuse", reuse);
        o
    }
}

/// Train from an in-memory subgraph queue until it closes.
///
/// The dispatcher groups `replicas × batch` subgraphs per iteration and
/// feeds one batch to every worker, so collectives always have full
/// participation.
pub fn train(
    runtime: &ModelRuntime,
    features: &FeatureService,
    queue: &BoundedQueue<Subgraph>,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let wall = Stopwatch::new();
    let spec = runtime.meta().spec;
    let r = cfg.replicas.max(1);
    let fabric = Fabric::new(r);
    let collectives = group(r, &fabric);
    let fetch_before = features.stats();
    let batch_before = features.batch_reuse();

    let base = cfg.resume.clone().unwrap_or_default();
    // Cumulative (subgraphs, nodes) totals at each iteration boundary,
    // recorded by the dispatcher *before* batches are handed out so
    // worker 0 can publish exact consumption alongside its snapshot.
    // Entry k = totals after iteration `base.iteration + k + 1`.
    let node_cap = (1 + spec.f1 + spec.f1 * spec.f2) as u64;
    let dispatched: Arc<Mutex<Vec<(u64, u64)>>> = Arc::new(Mutex::new(Vec::new()));

    // Per-worker batch channels (bounded by rendezvous: dispatcher sends
    // one batch per worker per iteration).
    let mut batch_txs: Vec<Sender<Vec<Subgraph>>> = Vec::with_capacity(r);
    let mut batch_rxs: Vec<Receiver<Vec<Subgraph>>> = Vec::with_capacity(r);
    for _ in 0..r {
        let (tx, rx) = channel();
        batch_txs.push(tx);
        batch_rxs.push(rx);
    }

    let mut report = TrainReport {
        iterations: 0,
        subgraphs_trained: 0,
        nodes_trained: 0,
        subgraphs_dropped: 0,
        final_loss: f32::NAN,
        accuracy: 0.0,
        loss_curve: Vec::new(),
        wall: Duration::ZERO,
        fabric: fabric.stats(),
        feature_fetch: FetchStats::default(),
        batch_reuse: crate::train::batch::BatchReuse::default(),
        params: Vec::new(),
    };

    std::thread::scope(|scope| -> Result<()> {
        // --- workers -----------------------------------------------------
        let mut joins = Vec::new();
        for (worker, (coll, rx)) in collectives.into_iter().zip(batch_rxs).enumerate() {
            let runtime = runtime.clone();
            let cfg = cfg.clone();
            let base = base.clone();
            let dispatched = dispatched.clone();
            // Batch materialization: overlapped on a prefetch thread, or
            // inline on the worker thread.
            let feed = if cfg.prefetch {
                BatchFeed::Prefetched(spawn_prefetcher(
                    scope,
                    features,
                    spec,
                    worker as u32,
                    rx,
                    1,
                ))
            } else {
                BatchFeed::Inline { rx, spec, worker: worker as u32 }
            };
            joins.push(scope.spawn(move || -> Result<WorkerOut> {
                crate::obs::trace::set_track(crate::obs::trace::Track::Trainer(worker as u16));
                let store = ParamStore::init(runtime.meta(), cfg.init_seed);
                let mut params = if base.params.is_empty() {
                    store.params.clone()
                } else {
                    base.params.clone()
                };
                let mut out = WorkerOut::default();
                let mut iter = base.iteration;
                if worker == 0 {
                    // Pre-load the resumed history so the loss curve and
                    // accuracy tail come out identical to an
                    // uninterrupted run.
                    out.losses = base.losses.clone();
                    out.accs = base.accs.clone();
                }
                while let Some(next) = feed.next(features) {
                    let _step_span =
                        crate::obs::trace::span("train.step").arg("iter", iter as f64);
                    let batch = next?;
                    out.nodes += batch.nodes;
                    out.subgraphs += spec.batch as u64;
                    let g = runtime.grad(&params, &batch)?;
                    // The gradient is computed; hand the batch's tensor
                    // buffers back for reuse by later materializations.
                    features.release_batch(batch);
                    // AllReduce [grads…, loss, correct] in one buffer.
                    let mut buf = ParamStore::flatten(&g.grads);
                    buf.push(g.loss);
                    buf.push(g.correct);
                    coll.allreduce_mean(&mut buf, cfg.allreduce)
                        .context("gradient allreduce")?;
                    let mean_correct = buf.pop().unwrap();
                    let mean_loss = buf.pop().unwrap();
                    let grads = store.unflatten(&buf);
                    params = runtime.apply(&params, &grads, cfg.lr)?;
                    iter += 1;
                    out.losses.push(mean_loss);
                    out.accs.push(mean_correct / spec.batch as f32);
                    if worker == 0 {
                        log::debug!(target: "train", "iter {iter}: loss {mean_loss:.4}");
                        if let Some(publish) = &cfg.publish {
                            let ix = (iter - base.iteration - 1) as usize;
                            let (subs, nodes) = dispatched
                                .lock()
                                .unwrap()
                                .get(ix)
                                .copied()
                                .unwrap_or((0, 0));
                            let mut st = publish.lock().unwrap();
                            st.iteration = iter;
                            st.subgraphs_trained = subs;
                            st.nodes_trained = nodes;
                            st.losses.clone_from(&out.losses);
                            st.accs.clone_from(&out.accs);
                            st.params.clone_from(&params);
                        }
                    }
                }
                out.params = params;
                Ok(out)
            }));
        }

        // --- dispatcher (this thread) -------------------------------------
        let batch_size = spec.batch;
        let group_size = batch_size * r;
        let mut pending: Vec<Subgraph> = Vec::with_capacity(group_size);
        loop {
            match queue.pop() {
                Some(sg) => {
                    pending.push(sg);
                    if pending.len() == group_size {
                        {
                            let mut d = dispatched.lock().unwrap();
                            let (mut subs, mut nodes) = d
                                .last()
                                .copied()
                                .unwrap_or((base.subgraphs_trained, base.nodes_trained));
                            subs += group_size as u64;
                            nodes += pending
                                .iter()
                                .map(|sg| sg.num_nodes().min(node_cap))
                                .sum::<u64>();
                            d.push((subs, nodes));
                        }
                        for tx in &batch_txs {
                            let batch: Vec<Subgraph> = pending.drain(..batch_size).collect();
                            tx.send(batch).map_err(|_| anyhow::anyhow!("worker died"))?;
                        }
                        report.iterations += 1;
                        if report.iterations == 2 {
                            // Batch-buffer warm-up is over: with prefetch,
                            // each worker keeps ≤ 3 batches in flight
                            // (training / handed over / materializing), so
                            // 3r+2 pooled spares guarantee steady-state
                            // assembly never allocates.
                            features.mark_batches_warm(spec, r * 3 + 2);
                        }
                    }
                }
                None => break,
            }
        }
        report.subgraphs_dropped = pending.len() as u64;
        drop(batch_txs); // close worker channels → workers finish
        for (w, j) in joins.into_iter().enumerate() {
            let out = j
                .join()
                .map_err(|_| anyhow::anyhow!("worker {w} panicked"))??;
            report.subgraphs_trained += out.subgraphs;
            report.nodes_trained += out.nodes;
            if w == 0 {
                report.final_loss = out.losses.last().copied().unwrap_or(f32::NAN);
                let tail = (out.accs.len() * 3 / 4).min(out.accs.len().saturating_sub(1));
                let tail_accs = &out.accs[tail..];
                report.accuracy = if tail_accs.is_empty() {
                    0.0
                } else {
                    tail_accs.iter().sum::<f32>() / tail_accs.len() as f32
                };
                report.loss_curve = out
                    .losses
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| (*i as u64) % cfg.curve_every.max(1) == 0)
                    .map(|(i, &l)| (i as u64, l))
                    .collect();
                report.params = out.params;
            }
        }
        // Fold in the resumed prefix once (not per worker) so counters
        // match an uninterrupted run exactly.
        report.iterations += base.iteration;
        report.subgraphs_trained += base.subgraphs_trained;
        report.nodes_trained += base.nodes_trained;
        Ok(())
    })?;

    report.wall = wall.elapsed();
    report.fabric = fabric.stats();
    report.feature_fetch = features.stats().delta(&fetch_before);
    report.batch_reuse = features.batch_reuse().delta(&batch_before);
    Ok(report)
}

#[derive(Default)]
struct WorkerOut {
    subgraphs: u64,
    nodes: u64,
    losses: Vec<f32>,
    accs: Vec<f32>,
    params: Vec<Vec<f32>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::FeatureStore;
    use crate::graph::generator;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }

    /// Full loop: generate on a planted graph, train, loss must drop.
    #[test]
    fn end_to_end_loss_decreases() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let gen = generator::from_spec("planted:n=2048,e=32768,c=8", 3).unwrap();
        let g = gen.csr();
        let features = FeatureService::procedural(FeatureStore::with_labels(
            spec.dim,
            spec.classes as u32,
            gen.labels.clone().unwrap(),
            5,
        ));
        // Generate enough subgraphs for ~12 iterations × 2 replicas.
        let seeds: Vec<u32> = (0..(spec.batch as u32 * 2 * 12)).collect();
        let queue = BoundedQueue::new(1 << 14);
        let ecfg = crate::engines::EngineConfig {
            workers: 4,
            fanout: crate::sampler::FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]),
            ..Default::default()
        };
        use crate::engines::SubgraphEngine;
        crate::engines::graphgen_plus::GraphGenPlus
            .generate(&g, &seeds, &ecfg, &crate::pipeline::QueueSink::new(&queue, None))
            .unwrap();
        queue.close();
        let report = train(
            &runtime,
            &features,
            &queue,
            &TrainConfig { replicas: 2, lr: 0.1, curve_every: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.iterations, 12);
        assert_eq!(report.subgraphs_trained, (spec.batch * 2 * 12) as u64);
        let first = report.loss_curve.first().unwrap().1;
        assert!(
            report.final_loss < first * 0.8,
            "loss {first} → {} should decrease",
            report.final_loss
        );
        assert!(report.fabric.total_bytes > 0, "allreduce traffic expected");
        // Procedural backend: features were fetched but never remote.
        assert!(report.feature_fetch.requested > 0);
        assert_eq!(report.feature_fetch.remote_bytes, 0);
        runtime.shutdown();
    }

    /// Replica count must not change the learning trajectory (synchronous
    /// data parallelism = bigger effective batch, but with identical
    /// total subgraphs per iteration the averaged grads are identical).
    #[test]
    fn leftover_subgraphs_are_dropped_not_hung() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let features =
            FeatureService::procedural(FeatureStore::hashed(spec.dim, spec.classes as u32, 1));
        let queue = BoundedQueue::new(1024);
        // 1.5 iteration-groups worth of subgraphs → 1 iteration + drops.
        let group = spec.batch * 2;
        for i in 0..(group + group / 2) as u32 {
            queue
                .push(Subgraph { seed: i % 97, hop1: vec![], hop2: vec![] })
                .unwrap();
        }
        queue.close();
        let report = train(
            &runtime,
            &features,
            &queue,
            &TrainConfig { replicas: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.iterations, 1);
        assert_eq!(report.subgraphs_dropped as usize, group / 2);
        runtime.shutdown();
    }

    /// Snapshot serialization must round-trip every f32 bit-exactly and
    /// reject truncated or over-long buffers with typed errors.
    #[test]
    fn train_state_roundtrip_is_bit_exact() {
        let st = TrainState {
            iteration: 7,
            subgraphs_trained: 224,
            nodes_trained: 9000,
            losses: vec![1.5, f32::MIN_POSITIVE, -0.0, 3.25e-7],
            accs: vec![0.5, 0.75],
            params: vec![vec![1.0, -2.5], vec![], vec![0.1]],
        };
        let rt = TrainState::decode(&st.encode()).unwrap();
        assert_eq!(rt, st);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&rt.losses), bits(&st.losses));
        assert_eq!(rt.losses[2].to_bits(), (-0.0f32).to_bits());
        let mut bytes = st.encode();
        assert!(TrainState::decode(&bytes[..bytes.len() - 1]).is_err());
        bytes.push(0);
        assert!(TrainState::decode(&bytes).is_err(), "trailing bytes must be rejected");
        let default = TrainState::default();
        assert_eq!(TrainState::decode(&default.encode()).unwrap(), default);
    }

    /// Killing a run at an iteration boundary and resuming from the
    /// published snapshot must reproduce the uninterrupted run exactly:
    /// same loss curve, counters, and parameter bits.
    #[test]
    fn resume_mid_run_is_bit_identical() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let sg = |i: u32| Subgraph { seed: i % 53, hop1: vec![i % 11], hop2: vec![vec![]] };
        let group = spec.batch * 2;
        let total = (group * 6) as u32;
        let run = |lo: u32, hi: u32, cfg: TrainConfig| {
            let features =
                FeatureService::procedural(FeatureStore::hashed(spec.dim, spec.classes as u32, 7));
            let queue = BoundedQueue::new(1024);
            for i in lo..hi {
                queue.push(sg(i)).unwrap();
            }
            queue.close();
            train(&runtime, &features, &queue, &cfg).unwrap()
        };
        let base_cfg = TrainConfig { replicas: 2, curve_every: 1, ..Default::default() };
        let full = run(0, total, base_cfg.clone());

        // First half, publishing the snapshot each iteration…
        let publish = Arc::new(Mutex::new(TrainState::default()));
        run(
            0,
            (group * 3) as u32,
            TrainConfig { publish: Some(publish.clone()), ..base_cfg.clone() },
        );
        let snap = publish.lock().unwrap().clone();
        assert_eq!(snap.iteration, 3);
        assert_eq!(snap.subgraphs_trained, (group * 3) as u64);
        assert!(snap.nodes_trained > 0);

        // …then resume through the serialized form over the second half.
        let snap = TrainState::decode(&snap.encode()).unwrap();
        let resumed =
            run((group * 3) as u32, total, TrainConfig { resume: Some(snap), ..base_cfg });
        assert_eq!(resumed.iterations, full.iterations);
        assert_eq!(resumed.subgraphs_trained, full.subgraphs_trained);
        assert_eq!(resumed.nodes_trained, full.nodes_trained);
        assert_eq!(resumed.loss_curve, full.loss_curve);
        assert_eq!(resumed.params, full.params);
        assert_eq!(resumed.final_loss.to_bits(), full.final_loss.to_bits());
        assert_eq!(resumed.accuracy.to_bits(), full.accuracy.to_bits());
        runtime.shutdown();
    }

    /// Prefetching only moves gather latency off the critical path — the
    /// training trajectory must be bit-identical.
    #[test]
    fn prefetch_does_not_change_trajectory() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let run = |prefetch: bool| {
            let features =
                FeatureService::procedural(FeatureStore::hashed(spec.dim, spec.classes as u32, 7));
            let queue = BoundedQueue::new(1024);
            for i in 0..(spec.batch * 2 * 4) as u32 {
                queue
                    .push(Subgraph { seed: i % 53, hop1: vec![i % 11], hop2: vec![vec![]] })
                    .unwrap();
            }
            queue.close();
            train(
                &runtime,
                &features,
                &queue,
                &TrainConfig { replicas: 2, curve_every: 1, prefetch, ..Default::default() },
            )
            .unwrap()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.loss_curve, b.loss_curve);
        assert_eq!(a.params, b.params);
        runtime.shutdown();
    }
}
