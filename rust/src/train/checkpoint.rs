//! Parameter checkpointing: save/restore trained GCN parameters with
//! shape validation against the artifact metadata. Binary format:
//! magic, tensor count, then per tensor (rank, dims…, f32 data), all
//! little-endian, with a trailing xor checksum of the byte stream.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::meta::ModelMeta;

const MAGIC: &[u8; 8] = b"GGCKPT01";

fn xor_checksum(data: &[u8]) -> u64 {
    let mut acc = 0xDEAD_BEEF_u64;
    for chunk in data.chunks(8) {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        acc = crate::util::rng::mix64(acc ^ u64::from_le_bytes(b));
    }
    acc
}

/// Save parameters (in `meta.param_shapes` order).
pub fn save(path: &Path, meta: &ModelMeta, params: &[Vec<f32>]) -> Result<()> {
    anyhow::ensure!(
        params.len() == meta.param_shapes.len(),
        "expected {} tensors, got {}",
        meta.param_shapes.len(),
        params.len()
    );
    let mut body: Vec<u8> = Vec::new();
    body.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (tensor, shape) in params.iter().zip(&meta.param_shapes) {
        let n: usize = shape.iter().product();
        anyhow::ensure!(tensor.len() == n, "tensor/shape mismatch: {} vs {:?}", tensor.len(), shape);
        body.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            body.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in tensor {
            body.extend_from_slice(&v.to_le_bytes());
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&xor_checksum(&body).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Load a checkpoint and validate shapes against `meta`.
pub fn load(path: &Path, meta: &ModelMeta) -> Result<Vec<Vec<f32>>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a GraphGen+ checkpoint", path.display());
    }
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum)?;
    let mut body = Vec::new();
    r.read_to_end(&mut body)?;
    if u64::from_le_bytes(sum) != xor_checksum(&body) {
        bail!("checkpoint {} is corrupt (checksum mismatch)", path.display());
    }
    let mut pos = 0usize;
    let take = |body: &[u8], pos: &mut usize, n: usize| -> Result<Vec<u8>> {
        let b = body
            .get(*pos..*pos + n)
            .ok_or_else(|| anyhow::anyhow!("truncated checkpoint"))?;
        *pos += n;
        Ok(b.to_vec())
    };
    let count = u32::from_le_bytes(take(&body, &mut pos, 4)?.try_into().unwrap()) as usize;
    anyhow::ensure!(
        count == meta.param_shapes.len(),
        "checkpoint has {count} tensors, model needs {}",
        meta.param_shapes.len()
    );
    let mut out = Vec::with_capacity(count);
    for shape in &meta.param_shapes {
        let rank = u32::from_le_bytes(take(&body, &mut pos, 4)?.try_into().unwrap()) as usize;
        anyhow::ensure!(rank == shape.len(), "rank mismatch");
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(u64::from_le_bytes(take(&body, &mut pos, 8)?.try_into().unwrap()) as usize);
        }
        anyhow::ensure!(&dims == shape, "shape mismatch: {dims:?} vs {shape:?}");
        let n: usize = dims.iter().product();
        let bytes = take(&body, &mut pos, n * 4)?;
        out.push(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        );
    }
    anyhow::ensure!(pos == body.len(), "trailing bytes in checkpoint");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::meta::{ModelMeta, ModelSpec};
    use crate::train::params::ParamStore;

    fn meta() -> ModelMeta {
        ModelMeta {
            dir: std::path::PathBuf::new(),
            spec: ModelSpec { batch: 2, f1: 2, f2: 2, dim: 4, hidden: 6, classes: 3 },
            param_names: ["ws1", "wn1", "b1", "ws2", "wn2", "b2"].map(String::from).to_vec(),
            param_shapes: vec![vec![4, 6], vec![4, 6], vec![6], vec![6, 3], vec![6, 3], vec![3]],
            grad_file: "g".into(),
            apply_file: "a".into(),
            forward_file: "f".into(),
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ggckpt-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn roundtrip() {
        let m = meta();
        let params = ParamStore::init(&m, 9).params;
        let p = tmp("ok.ckpt");
        save(&p, &m, &params).unwrap();
        let loaded = load(&p, &m).unwrap();
        assert_eq!(loaded, params);
    }

    #[test]
    fn detects_corruption() {
        let m = meta();
        let params = ParamStore::init(&m, 9).params;
        let p = tmp("corrupt.ckpt");
        save(&p, &m, &params).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, bytes).unwrap();
        let err = load(&p, &m).unwrap_err();
        assert!(format!("{err}").contains("corrupt"), "{err}");
    }

    #[test]
    fn rejects_wrong_magic_and_shape() {
        let m = meta();
        let p = tmp("magic.ckpt");
        std::fs::write(&p, b"NOTACKPT00000000").unwrap();
        assert!(load(&p, &m).is_err());

        // Save with modified shape → load with original meta must fail.
        let mut m2 = meta();
        m2.param_shapes[0] = vec![2, 12];
        let mut params = ParamStore::init(&m, 9).params;
        params[0] = vec![0.0; 24];
        let p2 = tmp("shape.ckpt");
        save(&p2, &m2, &params).unwrap();
        assert!(load(&p2, &m).is_err());
    }

    #[test]
    fn save_rejects_mismatched_tensors() {
        let m = meta();
        let mut params = ParamStore::init(&m, 9).params;
        params[0].pop();
        assert!(save(&tmp("bad.ckpt"), &m, &params).is_err());
    }
}
