//! Held-out evaluation: run the compiled forward artifact over subgraphs
//! of unseen seed nodes and score label accuracy. This is what a
//! production deployment of the paper's system does after each epoch.

use anyhow::Result;

use crate::engines::{CollectSink, EngineConfig, SubgraphEngine};
use crate::featurestore::FeatureService;
use crate::graph::csr::Csr;
use crate::graph::NodeId;

use super::runtime::ModelRuntime;

/// Evaluation outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalReport {
    pub examples: u64,
    pub correct: u64,
    /// Mean negative log-likelihood is not produced by the forward
    /// artifact (logits only); accuracy is the headline metric.
    pub accuracy: f64,
}

impl EvalReport {
    /// JSON view for the unified report writer ([`crate::obs::report`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("examples", self.examples)
            .set("correct", self.correct)
            .set("accuracy", self.accuracy);
        o
    }
}

/// Generate subgraphs for `seeds` with `engine`, run the forward pass and
/// score `argmax(logits) == label`. Seeds that don't fill a whole batch
/// are dropped (fixed-shape artifact), mirroring training semantics.
pub fn evaluate(
    runtime: &ModelRuntime,
    engine: &dyn SubgraphEngine,
    graph: &Csr,
    features: &FeatureService,
    seeds: &[NodeId],
    ecfg: &EngineConfig,
    params: &[Vec<f32>],
) -> Result<EvalReport> {
    let spec = runtime.meta().spec;
    let sink = CollectSink::default();
    engine.generate(graph, seeds, ecfg, &sink)?;
    let mut subgraphs = sink.take_sorted();
    // Deterministic batch packing by seed order.
    subgraphs.sort_by_key(|s| s.seed);
    let mut examples = 0u64;
    let mut correct = 0u64;
    for chunk in subgraphs.chunks(spec.batch) {
        if chunk.len() < spec.batch {
            break; // fixed-shape artifact: drop the remainder
        }
        let batch = features.materialize(spec, chunk, 0)?;
        let logits = runtime.forward(params, &batch)?;
        for (b, sg) in chunk.iter().enumerate() {
            let row = &logits[b * spec.classes..(b + 1) * spec.classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i as u32)
                .unwrap_or(0);
            examples += 1;
            if pred == features.label(sg.seed) {
                correct += 1;
            }
        }
    }
    Ok(EvalReport {
        examples,
        correct,
        accuracy: if examples == 0 { 0.0 } else { correct as f64 / examples as f64 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::graph::generator;
    use crate::pipeline::{run_pipeline, PipelineMode};
    use crate::sampler::FanoutSpec;
    use crate::train::trainer::TrainConfig;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }

    /// Train on one set of seeds, evaluate on *held-out* seeds: accuracy
    /// must transfer (planted labels are learnable from structure+feats).
    #[test]
    fn heldout_accuracy_after_training() {
        let Some(dir) = artifacts_dir() else { return };
        let runtime = ModelRuntime::load(&dir, 1).unwrap();
        let spec = runtime.meta().spec;
        let gen = generator::from_spec("planted:n=4096,e=32768,c=8", 21).unwrap();
        let g = gen.csr();
        let features = FeatureService::procedural(crate::graph::features::FeatureStore::with_labels(
            spec.dim,
            spec.classes as u32,
            gen.labels.clone().unwrap(),
            6,
        ));
        let ecfg = EngineConfig {
            workers: 4,
            fanout: FanoutSpec::new(vec![spec.f1 as u32, spec.f2 as u32]),
            ..Default::default()
        };
        // Train on the first half of the node ids.
        let train_seeds: Vec<NodeId> =
            (0..(spec.batch * 2 * 10) as u32).map(|i| i % 2048).collect();
        let tcfg = TrainConfig { replicas: 2, lr: 0.1, ..Default::default() };
        let r = run_pipeline(
            &g, &train_seeds, &GraphGenPlus, &ecfg, &features, &runtime, &tcfg,
            PipelineMode::Concurrent,
        )
        .unwrap();
        // Evaluate on unseen seeds from the second half.
        let eval_seeds: Vec<NodeId> = (2048..2048 + 4 * spec.batch as u32).collect();
        let report = evaluate(
            &runtime, &GraphGenPlus, &g, &features, &eval_seeds, &ecfg, &r.train.params,
        )
        .unwrap();
        assert_eq!(report.examples, 4 * spec.batch as u64);
        assert!(
            report.accuracy > 0.7,
            "held-out accuracy {} too low (train acc {})",
            report.accuracy,
            r.train.accuracy
        );
        // Untrained params should be near chance — sanity that eval isn't
        // trivially returning high numbers.
        let fresh = crate::train::params::ParamStore::init(runtime.meta(), 123).params;
        let chance = evaluate(
            &runtime, &GraphGenPlus, &g, &features, &eval_seeds, &ecfg, &fresh,
        )
        .unwrap();
        assert!(
            chance.accuracy < report.accuracy - 0.2,
            "untrained {} vs trained {}",
            chance.accuracy,
            report.accuracy
        );
        runtime.shutdown();
    }
}
