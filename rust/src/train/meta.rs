//! Artifact metadata: the shape and argument-order contract emitted by
//! `python/compile/aot.py` into `artifacts/meta.json`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Static model dimensions (mirror of python `model.Spec`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelSpec {
    pub batch: usize,
    pub f1: usize,
    pub f2: usize,
    pub dim: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl ModelSpec {
    /// Total floats in one batch's feature tensors (excl. labels).
    pub fn feature_floats(&self) -> usize {
        let (b, f1, f2, d) = (self.batch, self.f1, self.f2, self.dim);
        b * d + b * f1 * d + b * f1 * f2 * d + b * f1 + b * f1 * f2
    }

    /// Sampled node slots per batch (the nodes/iteration unit).
    pub fn nodes_per_batch(&self) -> u64 {
        (self.batch * (1 + self.f1 + self.f1 * self.f2)) as u64
    }
}

/// Parsed `meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub spec: ModelSpec,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub grad_file: PathBuf,
    pub apply_file: PathBuf,
    pub forward_file: PathBuf,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {} (run `make artifacts` first)", meta_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", meta_path.display()))?;
        let spec_j = j.get("spec").context("meta.json: missing spec")?;
        let dim = |k: &str| -> Result<usize> {
            spec_j
                .get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json: spec.{k}"))
        };
        let spec = ModelSpec {
            batch: dim("batch")?,
            f1: dim("f1")?,
            f2: dim("f2")?,
            dim: dim("dim")?,
            hidden: dim("hidden")?,
            classes: dim("classes")?,
        };
        let param_names: Vec<String> = j
            .get("param_names")
            .and_then(Json::as_arr)
            .context("meta.json: param_names")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let param_shapes: Vec<Vec<usize>> = j
            .get("param_shapes")
            .and_then(Json::as_arr)
            .context("meta.json: param_shapes")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default()
            })
            .collect();
        anyhow::ensure!(
            param_names.len() == param_shapes.len() && param_names.len() == 6,
            "meta.json: expected 6 params, got {}",
            param_names.len()
        );
        let file = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(
                j.get_path(&format!("artifacts.{key}.file"))
                    .and_then(Json::as_str)
                    .with_context(|| format!("meta.json: artifacts.{key}.file"))?,
            ))
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            spec,
            param_names,
            param_shapes,
            grad_file: file("grad")?,
            apply_file: file("apply")?,
            forward_file: file("forward")?,
        })
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let meta = r#"{
          "spec": {"batch": 4, "f1": 3, "f2": 2, "dim": 6, "hidden": 8, "classes": 3},
          "param_names": ["ws1", "wn1", "b1", "ws2", "wn2", "b2"],
          "param_shapes": [[6,8],[6,8],[8],[8,3],[8,3],[3]],
          "batch_names": ["x_seed","x_h1","x_h2","m_h1","m_h2","y"],
          "batch_shapes": [[4,6],[4,3,6],[4,3,2,6],[4,3],[4,3,2],[4]],
          "artifacts": {
            "grad": {"file": "gcn_grad.hlo.txt", "inputs": [], "outputs": []},
            "apply": {"file": "gcn_apply.hlo.txt", "inputs": [], "outputs": []},
            "forward": {"file": "gcn_forward.hlo.txt", "inputs": [], "outputs": []}
          }
        }"#;
        std::fs::write(dir.join("meta.json"), meta).unwrap();
    }

    #[test]
    fn loads_and_computes_sizes() {
        let dir = std::env::temp_dir().join(format!("ggmeta-{}", std::process::id()));
        write_meta(&dir);
        let m = ModelMeta::load(&dir).unwrap();
        assert_eq!(m.spec.batch, 4);
        assert_eq!(m.num_params(), 48 + 48 + 8 + 24 + 24 + 3);
        assert_eq!(m.spec.nodes_per_batch(), 4 * (1 + 3 + 6));
        assert_eq!(
            m.spec.feature_floats(),
            4 * 6 + 4 * 3 * 6 + 4 * 3 * 2 * 6 + 4 * 3 + 4 * 3 * 2
        );
        assert!(m.grad_file.ends_with("gcn_grad.hlo.txt"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = ModelMeta::load(Path::new("/nonexistent-gg")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
