//! L3 training runtime: loads the AOT-compiled GCN artifacts and drives
//! data-parallel in-memory training (Alg. 1 step 4).
//!
//! Python never runs here — the HLO text artifacts produced by
//! `python/compile/aot.py` are loaded through the PJRT C API (`xla`
//! crate) at startup and executed from the request path.
//!
//! * [`meta`] — artifact metadata (shape/argument-order contract).
//! * [`runtime`] — PJRT executor threads (`PjRtClient` is `Rc`-based and
//!   not `Send`, so each executor owns its client on a dedicated thread).
//! * [`params`] — deterministic parameter store + flatten/unflatten for
//!   AllReduce.
//! * [`batch`] — pads sampled subgraphs into the fixed tensor layout.
//! * [`trainer`] — multi-replica synchronous training loop with ring
//!   AllReduce gradient sync.

pub mod batch;
pub mod checkpoint;
pub mod eval;
pub mod meta;
pub mod params;
pub mod runtime;
pub mod trainer;

pub use meta::ModelMeta;
pub use runtime::ModelRuntime;
pub use trainer::{TrainConfig, TrainReport, TrainState};
