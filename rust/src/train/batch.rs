//! Batch assembly: pad sampled subgraphs into the fixed tensor layout the
//! compiled artifacts expect (in-memory, straight from the generation
//! pipeline — never from disk).
//!
//! Rows come from any [`FeatureBackend`]; contiguous tensor runs (the
//! seed column, each hop-1 slice, each hop-2 group) are filled with one
//! bulk [`FeatureBackend::gather_into`] call instead of per-node fetches,
//! and the per-subgraph fill fans out over the persistent
//! [`WorkPool`](crate::util::workpool::WorkPool) (each subgraph writes
//! disjoint tensor slices). [`crate::featurestore::FeatureService::materialize`]
//! layers batch-wide dedup, caching and remote-traffic accounting on top
//! by gathering a frame first and pointing this builder at it.
//!
//! [`BatchArena`] applies the generation side's reset-don't-free pattern
//! to batch buffers: a consumed [`HostBatch`]'s tensors return to a pool
//! and are re-zeroed in place on the next acquire, so steady-state batch
//! assembly performs **zero heap allocations** (counted in
//! [`TrainReport`](crate::train::trainer::TrainReport)).

use anyhow::Result;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::featurestore::FeatureBackend;
use crate::graph::NodeId;
use crate::sampler::Subgraph;

use super::meta::ModelSpec;
use super::runtime::HostBatch;

/// Stateless batch builder bound to a spec + feature backend.
pub struct BatchBuilder<'a> {
    pub spec: ModelSpec,
    pub features: &'a dyn FeatureBackend,
    /// Worker-thread cap for the per-subgraph fill fan-out (the
    /// feature-path budget; see
    /// [`FeatureService::with_threads`](crate::featurestore::FeatureService::with_threads)).
    threads: usize,
}

impl<'a> BatchBuilder<'a> {
    pub fn new(spec: ModelSpec, features: &'a dyn FeatureBackend) -> Self {
        assert_eq!(features.dim(), spec.dim, "feature dim must match artifact spec");
        Self { spec, features, threads: crate::util::workpool::default_threads() }
    }

    /// Cap the fill fan-out at `threads` pool workers (builder style).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Assemble exactly `spec.batch` subgraphs into a fresh batch.
    ///
    /// Hops longer than the spec's fanout are truncated (priority order —
    /// the kept prefix is the top-priority sample); shorter hops are
    /// zero-padded with mask 0. An invalid hop-1 slot forces its whole
    /// hop-2 group invalid.
    pub fn build(&self, subgraphs: &[Subgraph]) -> Result<HostBatch> {
        let mut out = shaped_batch(self.spec);
        self.build_into(subgraphs, &mut out)?;
        Ok(out)
    }

    /// [`build`](Self::build) into a caller-provided batch whose buffers
    /// are already shaped for the spec and zeroed (what
    /// [`BatchArena::acquire`] hands out) — the zero-allocation path. The
    /// per-subgraph fill runs on the work pool: subgraph `bi` writes only
    /// its own `bi`-indexed tensor slices, so the fan-out is
    /// write-disjoint and the bytes match the serial order exactly.
    pub fn build_into(&self, subgraphs: &[Subgraph], out: &mut HostBatch) -> Result<()> {
        let s = self.spec;
        anyhow::ensure!(
            subgraphs.len() == s.batch,
            "batch needs exactly {} subgraphs, got {}",
            s.batch,
            subgraphs.len()
        );
        let (b, f1, f2, d) = (s.batch, s.f1, s.f2, s.dim);
        // The per-subgraph fill below writes through raw pointers sized by
        // the spec, so every buffer's shape is load-bearing for safety —
        // reject wrong-shaped batches outright, release builds included.
        anyhow::ensure!(
            out.x_seed.len() == b * d
                && out.x_h1.len() == b * f1 * d
                && out.x_h2.len() == b * f1 * f2 * d
                && out.m_h1.len() == b * f1
                && out.m_h2.len() == b * f1 * f2
                && out.y.len() == b,
            "batch buffers not shaped for spec {s:?}"
        );
        // Seed rows are one contiguous run across the whole batch.
        let seeds: Vec<NodeId> = subgraphs.iter().map(|sg| sg.seed).collect();
        self.features.gather_into(&seeds, &mut out.x_seed);
        let features = self.features;
        use crate::util::workpool::RawParts;
        struct Tensors {
            x_h1: RawParts<f32>,
            x_h2: RawParts<f32>,
            m_h1: RawParts<f32>,
            m_h2: RawParts<f32>,
            y: RawParts<i32>,
        }
        let t = Tensors {
            x_h1: RawParts(out.x_h1.as_mut_ptr()),
            x_h2: RawParts(out.x_h2.as_mut_ptr()),
            m_h1: RawParts(out.m_h1.as_mut_ptr()),
            m_h2: RawParts(out.m_h2.as_mut_ptr()),
            y: RawParts(out.y.as_mut_ptr()),
        };
        let t = &t;
        // Trainer-side work runs on the gather pool under the feature
        // budget, so batch assembly never occupies the generation pool's
        // job slot (see `WorkPool::gather_global`).
        let threads = self.threads.min(b);
        let per_sg: Vec<u64> =
            crate::util::workpool::WorkPool::gather_global().map_collect_labeled(
                b,
                threads,
                1,
                "batch.assemble",
                |bi| {
                    let sg = &subgraphs[bi];
                    // SAFETY: every slice is the exclusive `bi`-indexed
                    // range of its tensor, and `out` outlives this
                    // blocking call.
                    let x_h1 = unsafe {
                        std::slice::from_raw_parts_mut(t.x_h1.0.add(bi * f1 * d), f1 * d)
                    };
                    let x_h2 = unsafe {
                        std::slice::from_raw_parts_mut(t.x_h2.0.add(bi * f1 * f2 * d), f1 * f2 * d)
                    };
                    let m_h1 =
                        unsafe { std::slice::from_raw_parts_mut(t.m_h1.0.add(bi * f1), f1) };
                    let m_h2 = unsafe {
                        std::slice::from_raw_parts_mut(t.m_h2.0.add(bi * f1 * f2), f1 * f2)
                    };
                    unsafe { *t.y.0.add(bi) = features.label(sg.seed) as i32 };
                    let t1 = sg.hop1.len().min(f1);
                    features.gather_into(&sg.hop1[..t1], &mut x_h1[..t1 * d]);
                    for i in 0..t1 {
                        m_h1[i] = 1.0;
                        if let Some(group) = sg.hop2.get(i) {
                            let t2 = group.len().min(f2);
                            let base = i * f2;
                            features
                                .gather_into(&group[..t2], &mut x_h2[base * d..(base + t2) * d]);
                            for j in 0..t2 {
                                m_h2[base + j] = 1.0;
                            }
                        }
                    }
                    sg.num_nodes().min((1 + f1 + f1 * f2) as u64)
                },
            );
        out.nodes = per_sg.iter().sum();
        Ok(())
    }
}

/// A fresh zeroed batch with `spec`'s tensor shapes.
fn shaped_batch(spec: ModelSpec) -> HostBatch {
    let (b, f1, f2, d) = (spec.batch, spec.f1, spec.f2, spec.dim);
    HostBatch {
        x_seed: vec![0.0; b * d],
        x_h1: vec![0.0; b * f1 * d],
        x_h2: vec![0.0; b * f1 * f2 * d],
        m_h1: vec![0.0; b * f1],
        m_h2: vec![0.0; b * f1 * f2],
        y: vec![0; b],
        nodes: 0,
    }
}

/// Batch-buffer reuse counters (snapshot; deltas via [`BatchReuse::delta`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchReuse {
    /// Batches allocated fresh (warm-up plus warm slack).
    pub allocated: u64,
    /// Acquisitions served from the pool.
    pub reused: u64,
    /// Fresh allocations after warm-up — 0 in steady state.
    pub steady_allocs: u64,
}

impl BatchReuse {
    /// Counter-wise difference vs an earlier snapshot.
    pub fn delta(&self, earlier: &BatchReuse) -> BatchReuse {
        BatchReuse {
            allocated: self.allocated.saturating_sub(earlier.allocated),
            reused: self.reused.saturating_sub(earlier.reused),
            steady_allocs: self.steady_allocs.saturating_sub(earlier.steady_allocs),
        }
    }
}

/// Reset-don't-free pool of [`HostBatch`] buffers plus id-scratch vecs —
/// the training-side sibling of the generation engines' `FrameArena`.
/// Released batches keep their tensor capacity; `acquire` re-zeros them in
/// place (a memset, not an allocation), so once warm, batch assembly
/// allocates nothing per iteration.
#[derive(Debug, Default)]
pub struct BatchArena {
    batches: Mutex<Vec<HostBatch>>,
    ids: Mutex<Vec<Vec<NodeId>>>,
    allocated: AtomicU64,
    reused: AtomicU64,
    steady_allocs: AtomicU64,
    warm: AtomicBool,
}

impl BatchArena {
    /// Take a zeroed batch shaped for `spec` (pooled buffers when
    /// available — re-zeroing stays within their capacity).
    pub fn acquire(&self, spec: ModelSpec) -> HostBatch {
        let pooled = self.batches.lock().unwrap().pop();
        match pooled {
            Some(mut b) => {
                self.reused.fetch_add(1, Ordering::Relaxed);
                reset_buf(&mut b.x_seed, spec.batch * spec.dim);
                reset_buf(&mut b.x_h1, spec.batch * spec.f1 * spec.dim);
                reset_buf(&mut b.x_h2, spec.batch * spec.f1 * spec.f2 * spec.dim);
                reset_buf(&mut b.m_h1, spec.batch * spec.f1);
                reset_buf(&mut b.m_h2, spec.batch * spec.f1 * spec.f2);
                reset_buf(&mut b.y, spec.batch);
                b.nodes = 0;
                b
            }
            None => {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                if self.warm.load(Ordering::Relaxed) {
                    self.steady_allocs.fetch_add(1, Ordering::Relaxed);
                }
                shaped_batch(spec)
            }
        }
    }

    /// Return a consumed batch's buffers to the pool.
    pub fn release(&self, b: HostBatch) {
        self.batches.lock().unwrap().push(b);
    }

    /// Pooled id-collection scratch (comes back cleared).
    pub fn acquire_ids(&self) -> Vec<NodeId> {
        match self.ids.lock().unwrap().pop() {
            Some(mut v) => {
                v.clear();
                v
            }
            None => Vec::new(),
        }
    }

    /// Return an id-scratch vec (its capacity is what's being pooled).
    pub fn release_ids(&self, v: Vec<NodeId>) {
        self.ids.lock().unwrap().push(v);
    }

    /// Declare warm-up over, stocking `slack` spare shaped batches first
    /// (so a racing `acquire` can never observe warm-but-unstocked) —
    /// later misses count as steady-state allocations.
    pub fn mark_warm(&self, spec: ModelSpec, slack: usize) {
        if self.warm.load(Ordering::Relaxed) {
            return;
        }
        {
            let mut pool = self.batches.lock().unwrap();
            for _ in 0..slack {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                pool.push(shaped_batch(spec));
            }
        }
        self.warm.store(true, Ordering::Relaxed);
    }

    pub fn stats(&self) -> BatchReuse {
        BatchReuse {
            allocated: self.allocated.load(Ordering::Relaxed),
            reused: self.reused.load(Ordering::Relaxed),
            steady_allocs: self.steady_allocs.load(Ordering::Relaxed),
        }
    }
}

/// Clear + re-zero a reusable buffer: a memset while `len` stays within
/// the buffer's high-water capacity (the steady-state case).
fn reset_buf<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    v.clear();
    v.resize(len, T::default());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::FeatureStore;
    use crate::graph::NodeId;
    use crate::train::meta::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec { batch: 2, f1: 3, f2: 2, dim: 4, hidden: 8, classes: 3 }
    }

    fn store() -> FeatureStore {
        FeatureStore::with_labels(4, 3, vec![0, 1, 2, 0, 1, 2, 0, 1], 9)
    }

    fn sg(seed: NodeId, h1: Vec<NodeId>, h2: Vec<Vec<NodeId>>) -> Subgraph {
        Subgraph { seed, hop1: h1, hop2: h2 }
    }

    #[test]
    fn shapes_masks_and_labels() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let batch = b
            .build(&[
                sg(0, vec![1, 2], vec![vec![3], vec![4, 5]]),
                sg(7, vec![], vec![]),
            ])
            .unwrap();
        assert_eq!(batch.x_seed.len(), 2 * 4);
        assert_eq!(batch.m_h1, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        // subgraph 0: h2 groups [3] (1 valid of 2) and [4,5] (2 valid)
        assert_eq!(
            batch.m_h2,
            vec![1.0, 0.0, 1.0, 1.0, 0.0, 0.0, /* bi=1 */ 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
        assert_eq!(batch.y, vec![0, 1]);
        assert_eq!(batch.nodes, (1 + 2 + 3) + 1);
        // padded features are exactly zero
        let last_h1 = &batch.x_h1[(1 * 3 + 0) * 4..];
        assert!(last_h1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn truncates_oversized_hops_in_priority_order() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let batch = b
            .build(&[
                sg(
                    0,
                    vec![1, 2, 3, 4, 5], // 5 > f1=3
                    vec![vec![6, 7, 1], vec![2], vec![3], vec![4], vec![5]],
                ),
                sg(1, vec![], vec![]),
            ])
            .unwrap();
        // Only the first 3 hop-1 slots are valid; h2 groups follow hop1.
        assert_eq!(&batch.m_h1[..3], &[1.0, 1.0, 1.0]);
        // group 0 truncated to f2=2
        assert_eq!(&batch.m_h2[..2], &[1.0, 1.0]);
    }

    #[test]
    fn wrong_count_is_error() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        assert!(b.build(&[sg(0, vec![], vec![])]).is_err());
    }

    #[test]
    fn features_are_deterministic_per_node() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let subs = [sg(3, vec![1], vec![vec![2]]), sg(4, vec![], vec![])];
        assert_eq!(b.build(&subs).unwrap(), b.build(&subs).unwrap());
    }

    #[test]
    fn build_into_reused_buffers_matches_fresh_build() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let arena = BatchArena::default();
        let subs_a = [sg(0, vec![1, 2], vec![vec![3], vec![4, 5]]), sg(7, vec![6], vec![vec![0]])];
        let subs_b = [sg(3, vec![1], vec![vec![2]]), sg(4, vec![], vec![])];
        let mut batch = arena.acquire(spec());
        b.build_into(&subs_a, &mut batch).unwrap();
        assert_eq!(batch, b.build(&subs_a).unwrap());
        // Recycle: stale tensor content must be fully overwritten/zeroed.
        arena.release(batch);
        let mut batch = arena.acquire(spec());
        b.build_into(&subs_b, &mut batch).unwrap();
        assert_eq!(batch, b.build(&subs_b).unwrap());
        let s = arena.stats();
        assert_eq!(s.allocated, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.steady_allocs, 0);
    }

    #[test]
    fn arena_counts_steady_allocs_after_warm() {
        let arena = BatchArena::default();
        arena.mark_warm(spec(), 1);
        let b1 = arena.acquire(spec()); // served by the warm slack
        let _b2 = arena.acquire(spec()); // pool empty → steady alloc
        assert_eq!(arena.stats().steady_allocs, 1);
        arena.release(b1);
        let _b3 = arena.acquire(spec());
        assert_eq!(arena.stats().steady_allocs, 1, "reuse must not count");
        // Id scratch pooling keeps capacity and comes back cleared.
        let mut ids = arena.acquire_ids();
        ids.extend_from_slice(&[1, 2, 3]);
        let cap = ids.capacity();
        arena.release_ids(ids);
        let again = arena.acquire_ids();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap);
    }

    #[test]
    fn bulk_gather_fills_exact_per_node_rows() {
        // Every valid slot must hold exactly the node's procedural row —
        // the bulk-gather layout math and the per-node path must agree.
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let subs = [sg(0, vec![1, 2], vec![vec![3], vec![4, 5]]), sg(7, vec![6], vec![vec![0]])];
        let batch = b.build(&subs).unwrap();
        let d = 4;
        assert_eq!(&batch.x_seed[0..d], &fs.feature(0)[..]);
        assert_eq!(&batch.x_seed[d..2 * d], &fs.feature(7)[..]);
        // bi=0: hop1 slots 0,1 = nodes 1,2
        assert_eq!(&batch.x_h1[0..d], &fs.feature(1)[..]);
        assert_eq!(&batch.x_h1[d..2 * d], &fs.feature(2)[..]);
        // bi=1: hop1 slot 0 = node 6 at offset (1*3+0)*d
        let off = (1 * 3 + 0) * d;
        assert_eq!(&batch.x_h1[off..off + d], &fs.feature(6)[..]);
        // bi=0, i=1, j=1 → node 5 at ((0*3+1)*2+1)*d
        let off2 = ((0 * 3 + 1) * 2 + 1) * d;
        assert_eq!(&batch.x_h2[off2..off2 + d], &fs.feature(5)[..]);
    }
}
