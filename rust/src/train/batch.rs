//! Batch assembly: pad sampled subgraphs into the fixed tensor layout the
//! compiled artifacts expect (in-memory, straight from the generation
//! pipeline — never from disk).
//!
//! Rows come from any [`FeatureBackend`]; contiguous tensor runs (the
//! seed column, each hop-1 slice, each hop-2 group) are filled with one
//! bulk [`FeatureBackend::gather_into`] call instead of per-node fetches.
//! [`crate::featurestore::FeatureService::materialize`] layers batch-wide
//! dedup, caching and remote-traffic accounting on top by gathering a
//! frame first and pointing this builder at it.

use anyhow::Result;

use crate::featurestore::FeatureBackend;
use crate::graph::NodeId;
use crate::sampler::Subgraph;

use super::meta::ModelSpec;
use super::runtime::HostBatch;

/// Stateless batch builder bound to a spec + feature backend.
pub struct BatchBuilder<'a> {
    pub spec: ModelSpec,
    pub features: &'a dyn FeatureBackend,
}

impl<'a> BatchBuilder<'a> {
    pub fn new(spec: ModelSpec, features: &'a dyn FeatureBackend) -> Self {
        assert_eq!(features.dim(), spec.dim, "feature dim must match artifact spec");
        Self { spec, features }
    }

    /// Assemble exactly `spec.batch` subgraphs into a batch.
    ///
    /// Hops longer than the spec's fanout are truncated (priority order —
    /// the kept prefix is the top-priority sample); shorter hops are
    /// zero-padded with mask 0. An invalid hop-1 slot forces its whole
    /// hop-2 group invalid.
    pub fn build(&self, subgraphs: &[Subgraph]) -> Result<HostBatch> {
        let s = self.spec;
        anyhow::ensure!(
            subgraphs.len() == s.batch,
            "batch needs exactly {} subgraphs, got {}",
            s.batch,
            subgraphs.len()
        );
        let (b, f1, f2, d) = (s.batch, s.f1, s.f2, s.dim);
        let mut out = HostBatch {
            x_seed: vec![0.0; b * d],
            x_h1: vec![0.0; b * f1 * d],
            x_h2: vec![0.0; b * f1 * f2 * d],
            m_h1: vec![0.0; b * f1],
            m_h2: vec![0.0; b * f1 * f2],
            y: vec![0; b],
            nodes: 0,
        };
        // Seed rows are one contiguous run across the whole batch.
        let seeds: Vec<NodeId> = subgraphs.iter().map(|sg| sg.seed).collect();
        self.features.gather_into(&seeds, &mut out.x_seed);
        for (bi, sg) in subgraphs.iter().enumerate() {
            out.nodes += sg.num_nodes().min((1 + f1 + f1 * f2) as u64);
            out.y[bi] = self.features.label(sg.seed) as i32;
            let t1 = sg.hop1.len().min(f1);
            let h1_off = bi * f1 * d;
            self.features
                .gather_into(&sg.hop1[..t1], &mut out.x_h1[h1_off..h1_off + t1 * d]);
            for i in 0..t1 {
                out.m_h1[bi * f1 + i] = 1.0;
                if let Some(group) = sg.hop2.get(i) {
                    let t2 = group.len().min(f2);
                    let base = (bi * f1 + i) * f2;
                    self.features
                        .gather_into(&group[..t2], &mut out.x_h2[base * d..(base + t2) * d]);
                    for j in 0..t2 {
                        out.m_h2[base + j] = 1.0;
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::FeatureStore;
    use crate::graph::NodeId;
    use crate::train::meta::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec { batch: 2, f1: 3, f2: 2, dim: 4, hidden: 8, classes: 3 }
    }

    fn store() -> FeatureStore {
        FeatureStore::with_labels(4, 3, vec![0, 1, 2, 0, 1, 2, 0, 1], 9)
    }

    fn sg(seed: NodeId, h1: Vec<NodeId>, h2: Vec<Vec<NodeId>>) -> Subgraph {
        Subgraph { seed, hop1: h1, hop2: h2 }
    }

    #[test]
    fn shapes_masks_and_labels() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let batch = b
            .build(&[
                sg(0, vec![1, 2], vec![vec![3], vec![4, 5]]),
                sg(7, vec![], vec![]),
            ])
            .unwrap();
        assert_eq!(batch.x_seed.len(), 2 * 4);
        assert_eq!(batch.m_h1, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        // subgraph 0: h2 groups [3] (1 valid of 2) and [4,5] (2 valid)
        assert_eq!(
            batch.m_h2,
            vec![1.0, 0.0, 1.0, 1.0, 0.0, 0.0, /* bi=1 */ 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
        assert_eq!(batch.y, vec![0, 1]);
        assert_eq!(batch.nodes, (1 + 2 + 3) + 1);
        // padded features are exactly zero
        let last_h1 = &batch.x_h1[(1 * 3 + 0) * 4..];
        assert!(last_h1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn truncates_oversized_hops_in_priority_order() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let batch = b
            .build(&[
                sg(
                    0,
                    vec![1, 2, 3, 4, 5], // 5 > f1=3
                    vec![vec![6, 7, 1], vec![2], vec![3], vec![4], vec![5]],
                ),
                sg(1, vec![], vec![]),
            ])
            .unwrap();
        // Only the first 3 hop-1 slots are valid; h2 groups follow hop1.
        assert_eq!(&batch.m_h1[..3], &[1.0, 1.0, 1.0]);
        // group 0 truncated to f2=2
        assert_eq!(&batch.m_h2[..2], &[1.0, 1.0]);
    }

    #[test]
    fn wrong_count_is_error() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        assert!(b.build(&[sg(0, vec![], vec![])]).is_err());
    }

    #[test]
    fn features_are_deterministic_per_node() {
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let subs = [sg(3, vec![1], vec![vec![2]]), sg(4, vec![], vec![])];
        assert_eq!(b.build(&subs).unwrap(), b.build(&subs).unwrap());
    }

    #[test]
    fn bulk_gather_fills_exact_per_node_rows() {
        // Every valid slot must hold exactly the node's procedural row —
        // the bulk-gather layout math and the per-node path must agree.
        let fs = store();
        let b = BatchBuilder::new(spec(), &fs);
        let subs = [sg(0, vec![1, 2], vec![vec![3], vec![4, 5]]), sg(7, vec![6], vec![vec![0]])];
        let batch = b.build(&subs).unwrap();
        let d = 4;
        assert_eq!(&batch.x_seed[0..d], &fs.feature(0)[..]);
        assert_eq!(&batch.x_seed[d..2 * d], &fs.feature(7)[..]);
        // bi=0: hop1 slots 0,1 = nodes 1,2
        assert_eq!(&batch.x_h1[0..d], &fs.feature(1)[..]);
        assert_eq!(&batch.x_h1[d..2 * d], &fs.feature(2)[..]);
        // bi=1: hop1 slot 0 = node 6 at offset (1*3+0)*d
        let off = (1 * 3 + 0) * d;
        assert_eq!(&batch.x_h1[off..off + d], &fs.feature(6)[..]);
        // bi=0, i=1, j=1 → node 5 at ((0*3+1)*2+1)*d
        let off2 = ((0 * 3 + 1) * 2 + 1) * d;
        assert_eq!(&batch.x_h2[off2..off2 + d], &fs.feature(5)[..]);
    }
}
