//! PJRT executor: loads the HLO-text artifacts and serves grad / apply /
//! forward executions from dedicated threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! executor thread owns its own client + compiled executables and serves
//! requests over a channel; requests and responses carry plain `Vec<f32>`
//! host buffers. [`ModelRuntime`] is cheaply cloneable and shared by all
//! logical training workers; `pool_size` > 1 spreads executions over
//! several PJRT clients for parallelism (see EXPERIMENTS.md §Perf).

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

// This tree builds against the PJRT shim (libxla is absent in the
// offline environment); swap back to the real `xla` crate to execute —
// the shim mirrors the exact API subset used below.
use crate::xla_shim as xla;

use super::meta::ModelMeta;

/// A batch in host memory, laid out per the meta.json contract.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HostBatch {
    pub x_seed: Vec<f32>,
    pub x_h1: Vec<f32>,
    pub x_h2: Vec<f32>,
    pub m_h1: Vec<f32>,
    pub m_h2: Vec<f32>,
    pub y: Vec<i32>,
    /// Sampled node slots this batch represents (for throughput metrics).
    pub nodes: u64,
}

/// Gradient-step output.
#[derive(Debug, Clone)]
pub struct GradOut {
    pub loss: f32,
    pub correct: f32,
    pub grads: Vec<Vec<f32>>,
}

enum Request {
    Grad { params: Vec<Vec<f32>>, batch: HostBatch, reply: Sender<Result<GradOut>> },
    Apply { params: Vec<Vec<f32>>, grads: Vec<Vec<f32>>, lr: f32, reply: Sender<Result<Vec<Vec<f32>>>> },
    Forward { params: Vec<Vec<f32>>, batch: HostBatch, reply: Sender<Result<Vec<f32>>> },
    Shutdown,
}

/// Shared handle to the executor pool.
#[derive(Clone)]
pub struct ModelRuntime {
    meta: Arc<ModelMeta>,
    txs: Arc<Vec<Sender<Request>>>,
    next: Arc<AtomicUsize>,
    executions: Arc<AtomicU64>,
}

impl ModelRuntime {
    /// Load artifacts from `dir` and start `pool_size` executor threads.
    pub fn load(dir: &Path, pool_size: usize) -> Result<Self> {
        let meta = Arc::new(ModelMeta::load(dir)?);
        let pool_size = pool_size.max(1);
        let mut txs = Vec::with_capacity(pool_size);
        for i in 0..pool_size {
            let (tx, rx) = channel::<Request>();
            let m = meta.clone();
            // Propagate executor startup errors through a handshake.
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            std::thread::Builder::new()
                .name(format!("pjrt-exec-{i}"))
                .spawn(move || executor_thread(m, rx, ready_tx))
                .context("spawn executor")?;
            ready_rx
                .recv()
                .context("executor thread died during startup")??;
            txs.push(tx);
        }
        Ok(Self {
            meta,
            txs: Arc::new(txs),
            next: Arc::new(AtomicUsize::new(0)),
            executions: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Total executions served (all kinds).
    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    fn tx(&self) -> &Sender<Request> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        &self.txs[i % self.txs.len()]
    }

    /// Compute loss/accuracy/gradients for one batch.
    pub fn grad(&self, params: &[Vec<f32>], batch: &HostBatch) -> Result<GradOut> {
        self.executions.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        self.tx()
            .send(Request::Grad { params: params.to_vec(), batch: batch.clone(), reply })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().context("executor dropped grad reply")?
    }

    /// SGD update via the compiled apply artifact.
    pub fn apply(&self, params: &[Vec<f32>], grads: &[Vec<f32>], lr: f32) -> Result<Vec<Vec<f32>>> {
        self.executions.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        self.tx()
            .send(Request::Apply { params: params.to_vec(), grads: grads.to_vec(), lr, reply })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().context("executor dropped apply reply")?
    }

    /// Inference logits `[batch * classes]`.
    pub fn forward(&self, params: &[Vec<f32>], batch: &HostBatch) -> Result<Vec<f32>> {
        self.executions.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        self.tx()
            .send(Request::Forward { params: params.to_vec(), batch: batch.clone(), reply })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().context("executor dropped forward reply")?
    }

    /// Stop all executor threads (drops are also fine; this is explicit).
    pub fn shutdown(&self) {
        for tx in self.txs.iter() {
            let _ = tx.send(Request::Shutdown);
        }
    }
}

struct Executables {
    grad: xla::PjRtLoadedExecutable,
    apply: xla::PjRtLoadedExecutable,
    forward: xla::PjRtLoadedExecutable,
}

fn compile_all(meta: &ModelMeta) -> Result<Executables> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
    let load = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))
    };
    Ok(Executables {
        grad: load(&meta.grad_file)?,
        apply: load(&meta.apply_file)?,
        forward: load(&meta.forward_file)?,
    })
}

fn executor_thread(
    meta: Arc<ModelMeta>,
    rx: std::sync::mpsc::Receiver<Request>,
    ready: Sender<Result<()>>,
) {
    let exes = match compile_all(&meta) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Grad { params, batch, reply } => {
                let _ = reply.send(run_grad(&exes, &meta, &params, &batch));
            }
            Request::Apply { params, grads, lr, reply } => {
                let _ = reply.send(run_apply(&exes, &meta, &params, &grads, lr));
            }
            Request::Forward { params, batch, reply } => {
                let _ = reply.send(run_forward(&exes, &meta, &params, &batch));
            }
        }
    }
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    anyhow::ensure!(
        expected as usize == data.len(),
        "tensor size mismatch: {} vs {:?}",
        data.len(),
        dims
    );
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e}"))
}

fn param_literals(meta: &ModelMeta, params: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
    anyhow::ensure!(params.len() == 6, "expected 6 params, got {}", params.len());
    params
        .iter()
        .zip(&meta.param_shapes)
        .map(|(p, s)| lit_f32(p, &s.iter().map(|&d| d as i64).collect::<Vec<_>>()))
        .collect()
}

fn feature_literals(meta: &ModelMeta, b: &HostBatch) -> Result<Vec<xla::Literal>> {
    let s = &meta.spec;
    let (bb, f1, f2, d) = (s.batch as i64, s.f1 as i64, s.f2 as i64, s.dim as i64);
    Ok(vec![
        lit_f32(&b.x_seed, &[bb, d])?,
        lit_f32(&b.x_h1, &[bb, f1, d])?,
        lit_f32(&b.x_h2, &[bb, f1, f2, d])?,
        lit_f32(&b.m_h1, &[bb, f1])?,
        lit_f32(&b.m_h2, &[bb, f1, f2])?,
    ])
}

fn execute_tuple(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
    result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))
}

fn run_grad(exes: &Executables, meta: &ModelMeta, params: &[Vec<f32>], batch: &HostBatch) -> Result<GradOut> {
    let mut args = param_literals(meta, params)?;
    args.extend(feature_literals(meta, batch)?);
    anyhow::ensure!(batch.y.len() == meta.spec.batch, "label count mismatch");
    args.push(xla::Literal::vec1(&batch.y));
    let out = execute_tuple(&exes.grad, &args)?;
    anyhow::ensure!(out.len() == 8, "grad artifact returned {} outputs", out.len());
    let mut it = out.into_iter();
    let loss = it.next().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?[0];
    let correct = it.next().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?[0];
    let grads: Result<Vec<Vec<f32>>> = it
        .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("grad out: {e}")))
        .collect();
    Ok(GradOut { loss, correct, grads: grads? })
}

fn run_apply(
    exes: &Executables,
    meta: &ModelMeta,
    params: &[Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
) -> Result<Vec<Vec<f32>>> {
    let mut args = param_literals(meta, params)?;
    args.extend(param_literals(meta, grads)?);
    args.push(xla::Literal::scalar(lr));
    let out = execute_tuple(&exes.apply, &args)?;
    anyhow::ensure!(out.len() == 6, "apply artifact returned {} outputs", out.len());
    out.into_iter()
        .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("apply out: {e}")))
        .collect()
}

fn run_forward(exes: &Executables, meta: &ModelMeta, params: &[Vec<f32>], batch: &HostBatch) -> Result<Vec<f32>> {
    let mut args = param_literals(meta, params)?;
    args.extend(feature_literals(meta, batch)?);
    let out = execute_tuple(&exes.forward, &args)?;
    anyhow::ensure!(out.len() == 1, "forward artifact returned {} outputs", out.len());
    out.into_iter()
        .next()
        .unwrap()
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("logits: {e}"))
}

#[cfg(test)]
mod tests {
    //! These tests need `artifacts/` (run `make artifacts`); they skip
    //! gracefully when absent so `cargo test` works standalone.
    use super::*;
    use crate::train::params::ParamStore;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }

    fn dummy_batch(meta: &ModelMeta, seed: u64) -> HostBatch {
        let s = &meta.spec;
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.gen_f32() - 0.5).collect() };
        HostBatch {
            x_seed: randv(s.batch * s.dim),
            x_h1: randv(s.batch * s.f1 * s.dim),
            x_h2: randv(s.batch * s.f1 * s.f2 * s.dim),
            m_h1: vec![1.0; s.batch * s.f1],
            m_h2: vec![1.0; s.batch * s.f1 * s.f2],
            y: (0..s.batch).map(|i| (i % s.classes) as i32).collect(),
            nodes: s.nodes_per_batch(),
        }
    }

    #[test]
    fn grad_apply_forward_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = ModelRuntime::load(&dir, 1).unwrap();
        let store = ParamStore::init(rt.meta(), 42);
        let batch = dummy_batch(rt.meta(), 7);
        let out = rt.grad(&store.params, &batch).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        assert!(out.correct >= 0.0 && out.correct <= rt.meta().spec.batch as f32);
        assert_eq!(out.grads.len(), 6);
        for (g, s) in out.grads.iter().zip(&rt.meta().param_shapes) {
            assert_eq!(g.len(), s.iter().product::<usize>());
        }
        // apply: params - lr*grads, verify one coordinate by hand.
        let new = rt.apply(&store.params, &out.grads, 0.1).unwrap();
        let want = store.params[0][0] - 0.1 * out.grads[0][0];
        assert!((new[0][0] - want).abs() < 1e-6);
        // forward: logits shape.
        let logits = rt.forward(&store.params, &batch).unwrap();
        assert_eq!(logits.len(), rt.meta().spec.batch * rt.meta().spec.classes);
        rt.shutdown();
    }

    #[test]
    fn training_reduces_loss_on_separable_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = ModelRuntime::load(&dir, 1).unwrap();
        let meta = rt.meta().clone();
        let s = meta.spec;
        // Class-dependent features: y decides the sign of every feature.
        let mut batch = dummy_batch(&meta, 3);
        for b in 0..s.batch {
            let sign = if batch.y[b] % 2 == 0 { 1.0 } else { -1.0 };
            for v in &mut batch.x_seed[b * s.dim..(b + 1) * s.dim] {
                *v = sign * (0.5 + v.abs());
            }
            let h1 = s.f1 * s.dim;
            for v in &mut batch.x_h1[b * h1..(b + 1) * h1] {
                *v = sign * (0.5 + v.abs());
            }
            let h2 = s.f1 * s.f2 * s.dim;
            for v in &mut batch.x_h2[b * h2..(b + 1) * h2] {
                *v = sign * (0.5 + v.abs());
            }
            batch.y[b] %= 2;
        }
        let mut params = ParamStore::init(&meta, 1).params;
        let first = rt.grad(&params, &batch).unwrap().loss;
        let mut last = first;
        for _ in 0..30 {
            let out = rt.grad(&params, &batch).unwrap();
            last = out.loss;
            params = rt.apply(&params, &out.grads, 0.1).unwrap();
        }
        assert!(
            last < 0.5 * first,
            "loss should drop on separable data: {first} → {last}"
        );
        rt.shutdown();
    }

    #[test]
    fn pool_round_robins() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = ModelRuntime::load(&dir, 2).unwrap();
        let store = ParamStore::init(rt.meta(), 5);
        let batch = dummy_batch(rt.meta(), 11);
        let a = rt.grad(&store.params, &batch).unwrap();
        let b = rt.grad(&store.params, &batch).unwrap();
        assert_eq!(a.loss, b.loss, "both executors must be deterministic");
        assert_eq!(rt.executions(), 2);
        rt.shutdown();
    }
}
