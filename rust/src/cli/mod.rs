//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with generated `--help` text. Declarative enough for
//! the launcher in `main.rs` while staying dependency-free.

use std::collections::BTreeMap;

/// Declared option for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` if the option takes a value; `false` for boolean flags.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A declared subcommand.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Parsed arguments for a (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    UnknownOption(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("unknown command '{0}'")]
    UnknownCommand(String),
    #[error("missing required option --{0}")]
    MissingRequired(String),
    #[error("invalid value for --{0}: '{1}' ({2})")]
    InvalidValue(String, String, String),
    #[error("help requested")]
    HelpRequested,
}

impl Parsed {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| CliError::InvalidValue(key.into(), v.into(), e.to_string())),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    pub fn require(&self, key: &str) -> Result<&str, CliError> {
        self.get(key).ok_or_else(|| CliError::MissingRequired(key.to_string()))
    }

    /// All `--key value` pairs, for config overrides.
    pub fn values(&self) -> &BTreeMap<String, String> {
        &self.values
    }
}

/// Top-level application CLI: name, about, subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    /// Parse argv (excluding `argv[0]`). On `--help`/`-h`/`help`, prints help
    /// and returns `CliError::HelpRequested` (the caller exits 0).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        if args.is_empty()
            || args[0] == "--help"
            || args[0] == "-h"
            || (args[0] == "help" && args.len() == 1)
        {
            println!("{}", self.help());
            return Err(CliError::HelpRequested);
        }
        let cmd_name = &args[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError::UnknownCommand(cmd_name.clone()))?;
        let mut parsed = Parsed { command: cmd.name.to_string(), ..Default::default() };
        // Seed defaults.
        for opt in &cmd.opts {
            if let Some(d) = opt.default {
                parsed.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.command_help(cmd));
                return Err(CliError::HelpRequested);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = cmd.opts.iter().find(|o| o.name == key);
                match spec {
                    None => return Err(CliError::UnknownOption(key)),
                    Some(spec) if spec.takes_value => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                args.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError::MissingValue(key.clone()))?
                            }
                        };
                        parsed.values.insert(key, val);
                    }
                    Some(_) => {
                        if let Some(v) = inline_val {
                            // allow --flag=true/false
                            if v == "true" {
                                parsed.flags.push(key);
                            }
                        } else {
                            parsed.flags.push(key);
                        }
                    }
                }
            } else {
                parsed.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        let w = self.commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
        for c in &self.commands {
            s.push_str(&format!("  {:w$}  {}\n", c.name, c.about, w = w));
        }
        s.push_str("\nRun '<command> --help' for command options.");
        s
    }

    pub fn command_help(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        let w = cmd.opts.iter().map(|o| o.name.len()).max().unwrap_or(0);
        for o in &cmd.opts {
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let v = if o.takes_value { " <v>" } else { "    " };
            s.push_str(&format!("  --{:w$}{v}  {}{d}\n", o.name, o.help, w = w));
        }
        s
    }
}

/// Convenience constructor for an option that takes a value.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec { name, help, takes_value: true, default }
}

/// Convenience constructor for a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, takes_value: false, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "gg",
            about: "test app",
            commands: vec![
                CommandSpec {
                    name: "run",
                    about: "run things",
                    opts: vec![
                        opt("workers", "worker count", Some("4")),
                        opt("graph", "graph file", None),
                        flag("verbose", "more output"),
                    ],
                },
                CommandSpec { name: "ls", about: "list", opts: vec![] },
            ],
        }
    }

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let p = app()
            .parse(&args(&["run", "--workers", "8", "--verbose", "--graph=g.bin", "extra"]))
            .unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.get("workers"), Some("8"));
        assert_eq!(p.get("graph"), Some("g.bin"));
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let p = app().parse(&args(&["run"])).unwrap();
        assert_eq!(p.get_or::<usize>("workers", 0).unwrap(), 4);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn typed_parsing_errors() {
        let p = app().parse(&args(&["run", "--workers", "abc"])).unwrap();
        assert!(matches!(
            p.get_parse::<usize>("workers"),
            Err(CliError::InvalidValue(..))
        ));
    }

    #[test]
    fn rejects_unknown() {
        assert!(matches!(
            app().parse(&args(&["nope"])),
            Err(CliError::UnknownCommand(_))
        ));
        assert!(matches!(
            app().parse(&args(&["run", "--bogus", "1"])),
            Err(CliError::UnknownOption(_))
        ));
        assert!(matches!(
            app().parse(&args(&["run", "--graph"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn require_reports_missing() {
        let p = app().parse(&args(&["run"])).unwrap();
        assert!(matches!(p.require("graph"), Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn help_text_lists_commands() {
        let h = app().help();
        assert!(h.contains("run"));
        assert!(h.contains("ls"));
    }
}
