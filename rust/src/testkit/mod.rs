//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Provides seeded-case generation with failure reporting and a greedy
//! input-shrinking pass for integer-vector inputs. Usage pattern:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libxla rpath in this image)
//! use graphgen_plus::testkit::Cases;
//!
//! Cases::new("sum is commutative", 100).run(|rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic RNG derived from (`GG_TESTKIT_SEED`, case
//! index), so failures print a reproducible `case` number that can be
//! re-run in isolation with [`Cases::run_case`].

use crate::util::rng::{mix2, Xoshiro256};

/// Deterministic per-wave delay injection for the look-ahead ring tests
/// (hooked in via `EngineConfig::wave_delay`): a speculator claiming wave
/// `w` with `w % every == offset` sleeps `delay_ms` before starting
/// hop 1, so wave `w+1` reliably finishes first and the reorder buffer's
/// out-of-order path is exercised regardless of machine speed. Pure
/// scheduling jitter — output bytes are unaffected, which is exactly
/// what the reorder tests assert.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaveDelay {
    /// Period of the delayed-wave pattern (0 disables).
    pub every: usize,
    /// Which residue of the period is delayed.
    pub offset: usize,
    /// Sleep applied to matching waves, milliseconds.
    pub delay_ms: u64,
}

impl WaveDelay {
    /// Apply the configured delay if wave `wave` matches the pattern.
    pub fn apply(&self, wave: usize) {
        if self.every > 0 && wave % self.every == self.offset % self.every {
            std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
        }
    }
}

/// Base seed for all property tests; override with `GG_TESTKIT_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("GG_TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00)
}

/// A batch of seeded property-test cases.
pub struct Cases {
    name: &'static str,
    count: u64,
    seed: u64,
}

impl Cases {
    pub fn new(name: &'static str, count: u64) -> Self {
        Self { name, count, seed: base_seed() }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run all cases; panics (with the case index) on the first failure.
    pub fn run(&self, f: impl Fn(&mut Xoshiro256) + std::panic::RefUnwindSafe) {
        for case in 0..self.count {
            let result = std::panic::catch_unwind(|| {
                let mut rng = Xoshiro256::seed_from_u64(mix2(self.seed, case));
                f(&mut rng);
            });
            if let Err(payload) = result {
                let msg = panic_message(&payload);
                panic!(
                    "property '{}' failed at case {case} (seed {}): {msg}\n\
                     reproduce with Cases::new(..).with_seed({}).run_case({case}, ..)",
                    self.name, self.seed, self.seed
                );
            }
        }
    }

    /// Re-run a single case (for failure reproduction while debugging).
    pub fn run_case(&self, case: u64, f: impl FnOnce(&mut Xoshiro256)) {
        let mut rng = Xoshiro256::seed_from_u64(mix2(self.seed, case));
        f(&mut rng);
    }
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Greedily shrink a failing `Vec<u64>` input: tries removing spans and
/// halving values while `fails` keeps returning true. Returns the smallest
/// failing input found.
pub fn shrink_vec(input: Vec<u64>, fails: impl Fn(&[u64]) -> bool) -> Vec<u64> {
    assert!(fails(&input), "shrink_vec requires a failing input");
    let mut cur = input;
    let mut changed = true;
    while changed {
        changed = false;
        // Try removing halves, quarters, ... then single elements.
        let mut span = (cur.len() / 2).max(1);
        'removal: while span >= 1 {
            let mut start = 0;
            while start + span <= cur.len() {
                let mut cand = cur.clone();
                cand.drain(start..start + span);
                if fails(&cand) {
                    cur = cand;
                    changed = true;
                    continue 'removal; // restart at same span
                }
                start += span;
            }
            if span == 1 {
                break;
            }
            span /= 2;
        }
        // Try shrinking element values.
        for i in 0..cur.len() {
            while cur[i] > 0 {
                let mut cand = cur.clone();
                cand[i] /= 2;
                if fails(&cand) {
                    cur = cand;
                    changed = true;
                } else {
                    break;
                }
            }
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_pass_when_property_holds() {
        Cases::new("add commutes", 50).run(|rng| {
            let a = rng.gen_range(1 << 30) as i64;
            let b = rng.gen_range(1 << 30) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn cases_report_failing_case_index() {
        let r = std::panic::catch_unwind(|| {
            Cases::new("always fails", 3).run(|_| panic!("boom"));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("case 0"), "got: {msg}");
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first = Vec::new();
        Cases::new("collect", 5).run(|rng| {
            // no assertion — just deterministic draws
            let _ = rng.next_u64();
        });
        for _ in 0..2 {
            let mut vals = Vec::new();
            for case in 0..5 {
                Cases::new("collect", 5).run_case(case, |rng| vals.push(rng.next_u64()));
            }
            if first.is_empty() {
                first = vals;
            } else {
                assert_eq!(first, vals);
            }
        }
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property violated iff the input contains a value >= 100.
        let fails = |xs: &[u64]| xs.iter().any(|&x| x >= 100);
        let shrunk = shrink_vec(vec![3, 250, 7, 900, 12], fails);
        // Minimal failing input is a single element in [100, 199]
        // (halving stops once v/2 < 100).
        assert_eq!(shrunk.len(), 1);
        assert!((100..200).contains(&shrunk[0]), "{shrunk:?}");
    }
}
