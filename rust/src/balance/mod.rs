//! Load-Balanced Subgraph Mapping — step (2) of the GraphGen+ workflow
//! and Algorithm 1 lines 3–13 of the paper.
//!
//! The coordinator builds a *balance table* mapping seed nodes to workers:
//! seeds are shuffled (line 4, "avoid sequential bias"), assigned
//! round-robin (line 11, `M[it] ← W[i mod |W|]`), and the remainder
//! `|S| mod |W|` is **discarded** (line 6, `max_i ← ⌊|S|/|W|⌋ × |W|`) so
//! every worker processes exactly the same number of subgraphs.
//!
//! For the E3 ablation two non-paper strategies are provided:
//! [`MappingStrategy::Contiguous`] (what GraphGen, the predecessor,
//! effectively does) and [`MappingStrategy::HashMod`].

use crate::graph::NodeId;
use crate::util::parallel_scan;
use crate::util::rng::{mix2, Xoshiro256};
use crate::util::stats::Samples;
use crate::util::workpool::{default_threads, WorkPool};

/// Seed→worker mapping strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingStrategy {
    /// Paper: shuffle, round-robin, discard remainder.
    ShuffledRoundRobin,
    /// Predecessor baseline: contiguous blocks of the *given* seed order.
    Contiguous,
    /// Stateless: worker = hash(seed) % |W| (no discard, possibly uneven).
    HashMod,
}

impl std::str::FromStr for MappingStrategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "shuffled-round-robin" | "paper" => Ok(Self::ShuffledRoundRobin),
            "contiguous" => Ok(Self::Contiguous),
            "hash" => Ok(Self::HashMod),
            other => Err(format!("unknown mapping strategy '{other}'")),
        }
    }
}

/// The balance table: which worker generates which seed's subgraph.
#[derive(Debug, Clone)]
pub struct BalanceTable {
    pub strategy: MappingStrategy,
    pub num_workers: usize,
    /// Assigned seeds in assignment order; `worker_of[i]` owns `seeds[i]`.
    pub seeds: Vec<NodeId>,
    pub worker_of: Vec<u32>,
    /// Seeds dropped to keep per-worker counts equal (paper semantics).
    pub discarded: Vec<NodeId>,
}

impl BalanceTable {
    /// Build the table. `shuffle_seed` drives line 4's shuffle
    /// (ShuffledRoundRobin) and the HashMod hash.
    pub fn build(
        seeds: &[NodeId],
        num_workers: usize,
        strategy: MappingStrategy,
        shuffle_seed: u64,
    ) -> Self {
        assert!(num_workers >= 1, "need at least one worker");
        match strategy {
            MappingStrategy::ShuffledRoundRobin => {
                let mut s = seeds.to_vec();
                let mut rng = Xoshiro256::seed_from_u64(mix2(shuffle_seed, 0xba1a)); // line 4
                rng.shuffle(&mut s);
                let max_i = (s.len() / num_workers) * num_workers; // line 6
                let discarded = s.split_off(max_i);
                let worker_of = (0..s.len()).map(|i| (i % num_workers) as u32).collect(); // line 11
                Self { strategy, num_workers, seeds: s, worker_of, discarded }
            }
            MappingStrategy::Contiguous => {
                let s = seeds.to_vec();
                let block = s.len().div_ceil(num_workers).max(1);
                let worker_of = (0..s.len())
                    .map(|i| ((i / block).min(num_workers - 1)) as u32)
                    .collect();
                Self { strategy, num_workers, seeds: s, worker_of, discarded: Vec::new() }
            }
            MappingStrategy::HashMod => {
                let s = seeds.to_vec();
                let worker_of = s
                    .iter()
                    .map(|&v| (mix2(shuffle_seed, v as u64) % num_workers as u64) as u32)
                    .collect();
                Self { strategy, num_workers, seeds: s, worker_of, discarded: Vec::new() }
            }
        }
    }

    /// Seeds assigned to `worker`, in assignment order.
    pub fn seeds_for(&self, worker: usize) -> Vec<NodeId> {
        let (starts, grouped) = self.by_worker(1);
        grouped[starts[worker] as usize..starts[worker + 1] as usize].to_vec()
    }

    /// All seeds grouped by owning worker, preserving assignment order
    /// within each group: `(starts, grouped)` with worker `w`'s seeds at
    /// `grouped[starts[w]..starts[w+1]]`. A counting sort whose offset
    /// spine is a (parallel) exclusive prefix scan of the per-worker
    /// histogram — byte-identical at every thread count.
    pub fn by_worker(&self, threads: usize) -> (Vec<u32>, Vec<NodeId>) {
        let mut starts: Vec<u32> =
            self.counts_par(threads).iter().map(|&c| c as u32).collect();
        starts.push(0);
        parallel_scan::exclusive_scan(WorkPool::global(), threads, &mut starts);
        // push(0) + exclusive scan leaves starts[w+1] - starts[w] =
        // counts[w] with the grand total in the final slot.
        let mut grouped = vec![0 as NodeId; self.seeds.len()];
        let mut cursor: Vec<u32> = starts[..self.num_workers].to_vec();
        // The scatter is sequential: stability (assignment order within a
        // worker) carries a cursor dependency.
        for (&s, &w) in self.seeds.iter().zip(&self.worker_of) {
            let c = &mut cursor[w as usize];
            grouped[*c as usize] = s;
            *c += 1;
        }
        (starts, grouped)
    }

    /// Per-worker seed counts.
    pub fn counts(&self) -> Vec<usize> {
        self.counts_par(default_threads())
    }

    /// [`counts`](Self::counts) with a thread budget: per-block partial
    /// histograms folded in block order (integer sums — identical at any
    /// thread count).
    pub fn counts_par(&self, threads: usize) -> Vec<usize> {
        const BLOCK: usize = 1 << 16;
        let n = self.worker_of.len();
        let nblocks = n.div_ceil(BLOCK);
        if threads <= 1 || nblocks <= 1 {
            let mut c = vec![0usize; self.num_workers];
            for &w in &self.worker_of {
                c[w as usize] += 1;
            }
            return c;
        }
        let partials = WorkPool::global().map_collect_labeled(
            nblocks,
            threads,
            1,
            "balance.hist",
            |b| {
                let mut c = vec![0usize; self.num_workers];
                for &w in &self.worker_of[b * BLOCK..((b + 1) * BLOCK).min(n)] {
                    c[w as usize] += 1;
                }
                c
            },
        );
        let mut c = vec![0usize; self.num_workers];
        for p in partials {
            for (acc, v) in c.iter_mut().zip(p) {
                *acc += v;
            }
        }
        c
    }

    /// Imbalance of an arbitrary per-seed cost function (max/mean over
    /// per-worker summed costs) — the E3 metric. Cost is typically the
    /// seed's expected sampling work (e.g. degree).
    pub fn cost_imbalance(&self, cost: impl Fn(NodeId) -> f64) -> f64 {
        let mut per_worker = vec![0.0f64; self.num_workers];
        for (&s, &w) in self.seeds.iter().zip(&self.worker_of) {
            per_worker[w as usize] += cost(s);
        }
        Samples::from_iter(per_worker).imbalance()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Cases;

    #[test]
    fn paper_semantics_counts_equal_and_remainder_discarded() {
        let seeds: Vec<NodeId> = (0..103).collect();
        let t = BalanceTable::build(&seeds, 10, MappingStrategy::ShuffledRoundRobin, 1);
        assert_eq!(t.seeds.len(), 100);
        assert_eq!(t.discarded.len(), 3);
        assert!(t.counts().iter().all(|&c| c == 10));
        // Nothing lost: assigned ∪ discarded == input.
        let mut all: Vec<NodeId> = t.seeds.iter().chain(&t.discarded).copied().collect();
        all.sort_unstable();
        assert_eq!(all, seeds);
    }

    #[test]
    fn shuffle_avoids_sequential_bias() {
        let seeds: Vec<NodeId> = (0..1000).collect();
        let t = BalanceTable::build(&seeds, 4, MappingStrategy::ShuffledRoundRobin, 7);
        // Worker 0 should not get only low ids: its mean seed id ≈ 500.
        let w0 = t.seeds_for(0);
        let mean: f64 = w0.iter().map(|&v| v as f64).sum::<f64>() / w0.len() as f64;
        assert!((mean - 500.0).abs() < 120.0, "mean {mean}");
    }

    #[test]
    fn contiguous_keeps_input_order() {
        let seeds: Vec<NodeId> = (0..10).collect();
        let t = BalanceTable::build(&seeds, 2, MappingStrategy::Contiguous, 0);
        assert_eq!(t.seeds_for(0), (0..5).collect::<Vec<_>>());
        assert_eq!(t.seeds_for(1), (5..10).collect::<Vec<_>>());
        assert!(t.discarded.is_empty());
    }

    #[test]
    fn paper_mapping_beats_contiguous_on_skewed_costs() {
        // Cost skewed by position: early seeds are 100x more expensive
        // (models id-correlated degree, common in crawled graphs).
        let seeds: Vec<NodeId> = (0..400).collect();
        let cost = |v: NodeId| if v < 40 { 100.0 } else { 1.0 };
        let paper = BalanceTable::build(&seeds, 8, MappingStrategy::ShuffledRoundRobin, 3);
        let contig = BalanceTable::build(&seeds, 8, MappingStrategy::Contiguous, 3);
        assert!(
            paper.cost_imbalance(cost) < contig.cost_imbalance(cost) / 2.0,
            "paper {} vs contiguous {}",
            paper.cost_imbalance(cost),
            contig.cost_imbalance(cost)
        );
    }

    #[test]
    fn property_every_assignment_valid() {
        Cases::new("balance table validity", 100).run(|rng| {
            let n = rng.gen_range(500) as usize;
            let w = 1 + rng.gen_range(16) as usize;
            let seeds: Vec<NodeId> = (0..n as u32).map(|_| rng.gen_range(1 << 20) as NodeId).collect();
            for strat in [
                MappingStrategy::ShuffledRoundRobin,
                MappingStrategy::Contiguous,
                MappingStrategy::HashMod,
            ] {
                let t = BalanceTable::build(&seeds, w, strat, rng.next_u64());
                assert_eq!(t.seeds.len(), t.worker_of.len());
                assert!(t.worker_of.iter().all(|&x| (x as usize) < w));
                assert_eq!(t.seeds.len() + t.discarded.len(), n);
                if strat == MappingStrategy::ShuffledRoundRobin {
                    let c = t.counts();
                    assert!(c.iter().all(|&x| x == c[0]), "equal counts: {c:?}");
                    assert!(t.discarded.len() < w);
                }
            }
        });
    }

    #[test]
    fn strategy_parses() {
        assert_eq!("paper".parse::<MappingStrategy>().unwrap(), MappingStrategy::ShuffledRoundRobin);
        assert!("x".parse::<MappingStrategy>().is_err());
    }
}
