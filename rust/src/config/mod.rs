//! Typed run configuration: JSON file + `--key value` CLI overrides.
//!
//! One [`RunConfig`] describes a whole pipeline run (workload, engine,
//! cluster, training). Precedence: defaults < `--config file.json` <
//! explicit CLI flags — the launcher passes CLI values through
//! [`RunConfig::apply_override`].

use std::path::Path;

use anyhow::{Context, Result};

use crate::balance::MappingStrategy;
use crate::cluster::collective::AllReduceAlgo;
use crate::engines::{EngineConfig, ReduceTopology};
use crate::sampler::FanoutSpec;
use crate::train::trainer::TrainConfig;
use crate::util::json::Json;

/// Everything one run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Generator spec, e.g. `planted:n=65536,e=524288,c=8`.
    pub graph: String,
    /// Graph generation seed.
    pub graph_seed: u64,
    /// Number of seed nodes (drawn 0..n or random).
    pub num_seeds: usize,
    pub engine: String,
    pub workers: usize,
    pub threads: usize,
    pub wave_size: usize,
    pub fanout: String,
    pub sample_seed: u64,
    pub mapping: String,
    pub reduce_arity: usize,
    /// `tree` or `flat`.
    pub reduce: String,
    // training
    pub artifacts: String,
    pub replicas: usize,
    pub lr: f64,
    pub allreduce: String,
    pub mode: String,
    pub pjrt_pool: usize,
    pub feature_seed: u64,
    /// Feature storage backend: `procedural`, `sharded`, or `tiered`
    /// (out-of-core compressed cold tier under a CLOCK hot tier).
    pub feature_backend: String,
    /// Hot-node feature cache budget in MiB (0 disables the cache).
    pub feature_cache_mb: usize,
    /// Total tiered-memory budget in MiB, split between the feature hot
    /// tier and the graph page cache (see `pipeline::split_memory_budget`).
    /// 0 = unlimited (resident behaviour); the `GG_MEMORY_BUDGET_MB` env
    /// var applies when this is 0.
    pub memory_budget_mb: usize,
    /// Overlap feature gather for batch t+1 with training on batch t.
    pub feature_prefetch: bool,
    /// Overlap hop work of future waves with reduce/emit of the current
    /// one (byte-identical output; scheduling only).
    pub wave_pipeline: bool,
    /// Look-ahead ring depth ceiling: waves the generation pipeline may
    /// run ahead of the one being emitted (≥ 1; ≥ 2 also speculates
    /// hop-2). The effective depth adapts within `[1, lookahead_depth]`
    /// from the measured stall mix.
    pub lookahead_depth: usize,
    /// Look-ahead worker pool size: speculator threads claiming future
    /// waves out of order (emission stays FIFO via the reorder buffer,
    /// so output bytes are identical at any value).
    pub lookahead_workers: usize,
    /// Worker threads reserved for feature gathers in the concurrent
    /// pipeline (0 = auto: the measured E7 knee when `BENCH_e7.json`
    /// exists, else a quarter of `threads`). The remainder goes to
    /// generation hop scans — see `pipeline::split_pool_budget`.
    pub gather_threads: usize,
    /// Chrome-trace timeline output path (empty = tracing off). The file
    /// loads in Perfetto / `chrome://tracing`; see DESIGN.md
    /// §Observability.
    pub trace_out: String,
    /// Seconds between metrics-registry snapshots appended to
    /// `obs_metrics.jsonl` (0 = snapshotting off).
    pub obs_snapshot_secs: u64,
    /// Pin pool workers (and wave speculators) to cores: worker slot i →
    /// core `i % cores`. Opt-in; no-op on unsupported platforms. The
    /// `GG_PIN_CORES` env var is an alternative switch.
    pub pin_cores: bool,
    /// Real worker *processes* for generation (0 = in-process, the
    /// deterministic oracle). Orthogonal to `workers`, which stays the
    /// balance-table granularity — so output bytes are identical at any
    /// process count (see `cluster::proc`).
    pub processes: usize,
    /// Shared run directory for a distributed run: config, socket path,
    /// heartbeat files, wave ledger, pid files. Empty = a fresh temp dir.
    pub run_dir: String,
    /// Worker/coordinator heartbeat period (milliseconds).
    pub heartbeat_ms: u64,
    /// Liveness lease: a rank whose heartbeat hasn't advanced for this
    /// long is declared lost and its in-flight waves are reclaimed.
    pub lease_ms: u64,
    /// Per-operation transport deadline (connect, send, mid-frame recv).
    pub op_deadline_ms: u64,
    /// Coordinator checkpoint period in emitted waves (0 = off): every N
    /// emitted waves the coordinator persists a durable checkpoint under
    /// the run dir so a killed coordinator can `--resume` byte-identically.
    pub checkpoint_waves: u64,
    /// Replacement `gg-worker` spawns allowed per rank before the rank is
    /// abandoned (its waves still migrate to surviving ranks).
    pub respawn_budget: u32,
    /// Deterministic chaos seed (0 = off): workers draw seeded fault
    /// schedules (kills, stalls, frame corruption, heartbeat freezes).
    /// Rides in the shared config.json so every process replays the same
    /// schedule; `GG_CHAOS_SEED` overrides.
    pub chaos: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            graph: "planted:n=16384,e=131072,c=8".into(),
            graph_seed: 7,
            num_seeds: 4096,
            engine: "graphgen+".into(),
            workers: 8,
            threads: crate::util::workpool::default_threads(),
            wave_size: 4096,
            fanout: "10,5".into(),
            sample_seed: 0x5eed,
            mapping: "paper".into(),
            reduce_arity: 4,
            reduce: "tree".into(),
            artifacts: "artifacts".into(),
            replicas: 2,
            lr: 0.05,
            allreduce: "ring".into(),
            mode: "concurrent".into(),
            pjrt_pool: 1,
            feature_seed: 5,
            feature_backend: "procedural".into(),
            feature_cache_mb: 0,
            memory_budget_mb: 0,
            feature_prefetch: false,
            wave_pipeline: true,
            lookahead_depth: 2,
            lookahead_workers: 2,
            gather_threads: 0,
            trace_out: String::new(),
            obs_snapshot_secs: 0,
            pin_cores: false,
            processes: 0,
            run_dir: String::new(),
            heartbeat_ms: 200,
            lease_ms: 2000,
            op_deadline_ms: 10_000,
            checkpoint_waves: 0,
            respawn_budget: 2,
            chaos: 0,
        }
    }
}

impl RunConfig {
    /// Load from a JSON object file; unknown keys are rejected (typo
    /// protection), missing keys keep defaults.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        let obj = j.as_obj().context("config root must be an object")?;
        let mut cfg = Self::default();
        for (k, v) in obj {
            let as_text = match v {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            };
            cfg.apply_override(k, &as_text)
                .with_context(|| format!("config key '{k}'"))?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(v: &str, key: &str) -> Result<T>
        where
            T::Err: std::fmt::Display,
        {
            v.parse::<T>().map_err(|e| anyhow::anyhow!("bad {key}='{v}': {e}"))
        }
        match key {
            "graph" => self.graph = value.into(),
            "graph_seed" => self.graph_seed = p(value, key)?,
            "num_seeds" => self.num_seeds = p(value, key)?,
            "engine" => self.engine = value.into(),
            "workers" => self.workers = p(value, key)?,
            "threads" => self.threads = p(value, key)?,
            "wave_size" => self.wave_size = p(value, key)?,
            "fanout" => self.fanout = value.into(),
            "sample_seed" => self.sample_seed = p(value, key)?,
            "mapping" => self.mapping = value.into(),
            "reduce_arity" => self.reduce_arity = p(value, key)?,
            "reduce" => self.reduce = value.into(),
            "artifacts" => self.artifacts = value.into(),
            "replicas" => self.replicas = p(value, key)?,
            "lr" => self.lr = p(value, key)?,
            "allreduce" => self.allreduce = value.into(),
            "mode" => self.mode = value.into(),
            "pjrt_pool" => self.pjrt_pool = p(value, key)?,
            "feature_seed" => self.feature_seed = p(value, key)?,
            "feature_backend" => self.feature_backend = value.into(),
            "feature_cache_mb" => self.feature_cache_mb = p(value, key)?,
            "memory_budget_mb" => self.memory_budget_mb = p(value, key)?,
            "feature_prefetch" => self.feature_prefetch = p(value, key)?,
            "wave_pipeline" => self.wave_pipeline = p(value, key)?,
            "lookahead_depth" => self.lookahead_depth = p(value, key)?,
            "lookahead_workers" => self.lookahead_workers = p(value, key)?,
            "gather_threads" => self.gather_threads = p(value, key)?,
            "trace_out" => self.trace_out = value.into(),
            "obs_snapshot_secs" => self.obs_snapshot_secs = p(value, key)?,
            "pin_cores" => self.pin_cores = p(value, key)?,
            "processes" => self.processes = p(value, key)?,
            "run_dir" => self.run_dir = value.into(),
            "heartbeat_ms" => self.heartbeat_ms = p(value, key)?,
            "lease_ms" => self.lease_ms = p(value, key)?,
            "op_deadline_ms" => self.op_deadline_ms = p(value, key)?,
            "checkpoint_waves" => self.checkpoint_waves = p(value, key)?,
            "respawn_budget" => self.respawn_budget = p(value, key)?,
            "chaos" => self.chaos = p(value, key)?,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Materialize the engine config.
    pub fn engine_config(&self) -> Result<EngineConfig> {
        let mapping: MappingStrategy =
            self.mapping.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        let reduce = match self.reduce.as_str() {
            "tree" => ReduceTopology::Tree { arity: self.reduce_arity.max(2) },
            "flat" => ReduceTopology::Flat,
            other => anyhow::bail!("unknown reduce topology '{other}'"),
        };
        Ok(EngineConfig {
            workers: self.workers.max(1),
            threads: self.threads.max(1),
            wave_size: self.wave_size.max(1),
            fanout: FanoutSpec::parse(&self.fanout)?,
            sample_seed: self.sample_seed,
            mapping,
            reduce,
            spill_dir: None,
            spill_compress: false,
            wave_pipeline: self.wave_pipeline,
            lookahead_depth: self.lookahead_depth.max(1),
            lookahead_workers: self.lookahead_workers.max(1),
            wave_delay: None,
        })
    }

    /// Materialize the train config.
    pub fn train_config(&self) -> Result<TrainConfig> {
        let allreduce: AllReduceAlgo =
            self.allreduce.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        Ok(TrainConfig {
            replicas: self.replicas.max(1),
            lr: self.lr as f32,
            allreduce,
            init_seed: 0x11,
            curve_every: 10,
            prefetch: self.feature_prefetch,
            ..Default::default()
        })
    }

    /// Render as pretty JSON (for `--dump-config`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("graph", self.graph.clone())
            .set("graph_seed", self.graph_seed)
            .set("num_seeds", self.num_seeds)
            .set("engine", self.engine.clone())
            .set("workers", self.workers)
            .set("threads", self.threads)
            .set("wave_size", self.wave_size)
            .set("fanout", self.fanout.clone())
            .set("sample_seed", self.sample_seed)
            .set("mapping", self.mapping.clone())
            .set("reduce_arity", self.reduce_arity)
            .set("reduce", self.reduce.clone())
            .set("artifacts", self.artifacts.clone())
            .set("replicas", self.replicas)
            .set("lr", self.lr)
            .set("allreduce", self.allreduce.clone())
            .set("mode", self.mode.clone())
            .set("pjrt_pool", self.pjrt_pool)
            .set("feature_seed", self.feature_seed)
            .set("feature_backend", self.feature_backend.clone())
            .set("feature_cache_mb", self.feature_cache_mb)
            .set("memory_budget_mb", self.memory_budget_mb)
            .set("feature_prefetch", self.feature_prefetch)
            .set("wave_pipeline", self.wave_pipeline)
            .set("lookahead_depth", self.lookahead_depth)
            .set("lookahead_workers", self.lookahead_workers)
            .set("gather_threads", self.gather_threads)
            .set("trace_out", self.trace_out.clone())
            .set("obs_snapshot_secs", self.obs_snapshot_secs)
            .set("pin_cores", self.pin_cores)
            .set("processes", self.processes)
            .set("run_dir", self.run_dir.clone())
            .set("heartbeat_ms", self.heartbeat_ms)
            .set("lease_ms", self.lease_ms)
            .set("op_deadline_ms", self.op_deadline_ms)
            .set("checkpoint_waves", self.checkpoint_waves)
            .set("respawn_budget", self.respawn_budget as u64)
            .set("chaos", self.chaos);
        o
    }

    /// Deterministic seed draw without replacement over a graph of `n`
    /// nodes. Lives on the config (not the launcher) because every
    /// process of a distributed run must derive the identical seed list
    /// from the shared `config.json` alone.
    pub fn seeds(&self, n: u32) -> Vec<u32> {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(self.sample_seed ^ 0x5eed_5eed);
        let take = self.num_seeds.min(n as usize);
        rng.sample_indices(n as usize, take).into_iter().map(|v| v as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_materialize() {
        let c = RunConfig::default();
        let e = c.engine_config().unwrap();
        assert_eq!(e.workers, 8);
        assert_eq!(e.fanout.fanouts, vec![10, 5]);
        let t = c.train_config().unwrap();
        assert_eq!(t.replicas, 2);
    }

    #[test]
    fn overrides_apply_and_reject_unknown() {
        let mut c = RunConfig::default();
        c.apply_override("workers", "16").unwrap();
        c.apply_override("fanout", "40,20").unwrap();
        assert_eq!(c.workers, 16);
        assert_eq!(c.engine_config().unwrap().fanout.fanouts, vec![40, 20]);
        assert!(c.apply_override("bogus", "1").is_err());
        assert!(c.apply_override("workers", "abc").is_err());
    }

    #[test]
    fn json_file_roundtrip() {
        let c = RunConfig::default();
        let dir = std::env::temp_dir().join(format!("ggcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        std::fs::write(&path, c.to_json().to_pretty()).unwrap();
        let loaded = RunConfig::from_json_file(&path).unwrap();
        assert_eq!(loaded.to_json(), c.to_json());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feature_store_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.feature_backend, "procedural");
        assert!(!c.train_config().unwrap().prefetch);
        c.apply_override("feature_backend", "sharded").unwrap();
        c.apply_override("feature_cache_mb", "64").unwrap();
        c.apply_override("feature_prefetch", "true").unwrap();
        assert_eq!(c.feature_backend, "sharded");
        assert_eq!(c.feature_cache_mb, 64);
        assert!(c.train_config().unwrap().prefetch);
        assert!(c.apply_override("feature_prefetch", "maybe").is_err());
        assert!(c.to_json().to_pretty().contains("feature_backend"));
    }

    #[test]
    fn pipeline_depth_and_budget_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.lookahead_depth, 2);
        assert_eq!(c.gather_threads, 0);
        assert_eq!(c.lookahead_workers, 2);
        c.apply_override("lookahead_depth", "4").unwrap();
        c.apply_override("lookahead_workers", "3").unwrap();
        c.apply_override("gather_threads", "3").unwrap();
        assert_eq!(c.engine_config().unwrap().lookahead_depth, 4);
        assert_eq!(c.engine_config().unwrap().lookahead_workers, 3);
        assert_eq!(c.gather_threads, 3);
        // Depth 0 clamps to 1 at materialization (never a dead pipeline).
        c.apply_override("lookahead_depth", "0").unwrap();
        assert_eq!(c.engine_config().unwrap().lookahead_depth, 1);
        // Worker count 0 clamps to 1 at materialization too.
        c.apply_override("lookahead_workers", "0").unwrap();
        assert_eq!(c.engine_config().unwrap().lookahead_workers, 1);
        assert!(c.to_json().to_pretty().contains("lookahead_depth"));
        assert!(c.to_json().to_pretty().contains("lookahead_workers"));
        assert!(c.to_json().to_pretty().contains("gather_threads"));
    }

    #[test]
    fn obs_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.trace_out, "");
        assert_eq!(c.obs_snapshot_secs, 0);
        c.apply_override("trace_out", "trace.json").unwrap();
        c.apply_override("obs_snapshot_secs", "5").unwrap();
        assert_eq!(c.trace_out, "trace.json");
        assert_eq!(c.obs_snapshot_secs, 5);
        assert!(c.apply_override("obs_snapshot_secs", "soon").is_err());
        assert!(c.to_json().to_pretty().contains("trace_out"));
        assert!(c.to_json().to_pretty().contains("obs_snapshot_secs"));
    }

    #[test]
    fn pin_cores_key_roundtrips() {
        let mut c = RunConfig::default();
        assert!(!c.pin_cores);
        c.apply_override("pin_cores", "true").unwrap();
        assert!(c.pin_cores);
        assert!(c.apply_override("pin_cores", "sometimes").is_err());
        assert!(c.to_json().to_pretty().contains("pin_cores"));
    }

    #[test]
    fn memory_budget_key_roundtrips() {
        let mut c = RunConfig::default();
        assert_eq!(c.memory_budget_mb, 0);
        c.apply_override("memory_budget_mb", "256").unwrap();
        assert_eq!(c.memory_budget_mb, 256);
        assert!(c.apply_override("memory_budget_mb", "lots").is_err());
        assert!(c.to_json().to_pretty().contains("memory_budget_mb"));
        // A set config value wins over the env fallback.
        assert_eq!(crate::storage::tier::memory_budget_mb(c.memory_budget_mb), 256);
    }

    #[test]
    fn distributed_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.processes, 0);
        assert_eq!((c.heartbeat_ms, c.lease_ms, c.op_deadline_ms), (200, 2000, 10_000));
        c.apply_override("processes", "4").unwrap();
        c.apply_override("run_dir", "/tmp/ggrun").unwrap();
        c.apply_override("heartbeat_ms", "100").unwrap();
        c.apply_override("lease_ms", "1500").unwrap();
        c.apply_override("op_deadline_ms", "5000").unwrap();
        assert_eq!(c.processes, 4);
        assert_eq!(c.run_dir, "/tmp/ggrun");
        assert_eq!((c.heartbeat_ms, c.lease_ms, c.op_deadline_ms), (100, 1500, 5000));
        assert!(c.apply_override("processes", "many").is_err());
        for key in ["processes", "run_dir", "heartbeat_ms", "lease_ms", "op_deadline_ms"] {
            assert!(c.to_json().to_pretty().contains(key), "{key} missing from json");
        }
    }

    #[test]
    fn recovery_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!((c.checkpoint_waves, c.respawn_budget, c.chaos), (0, 2, 0));
        c.apply_override("checkpoint_waves", "4").unwrap();
        c.apply_override("respawn_budget", "3").unwrap();
        c.apply_override("chaos", "12345").unwrap();
        assert_eq!((c.checkpoint_waves, c.respawn_budget, c.chaos), (4, 3, 12345));
        assert!(c.apply_override("checkpoint_waves", "often").is_err());
        assert!(c.apply_override("respawn_budget", "-1").is_err());
        for key in ["checkpoint_waves", "respawn_budget", "chaos"] {
            assert!(c.to_json().to_pretty().contains(key), "{key} missing from json");
        }
    }

    #[test]
    fn seed_draw_is_deterministic_and_config_derived() {
        let c = RunConfig { num_seeds: 100, sample_seed: 42, ..Default::default() };
        let a = c.seeds(1 << 16);
        let b = c.seeds(1 << 16);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        // Bounded by the graph size.
        assert_eq!(c.seeds(10).len(), 10);
        // A different sample seed draws a different set.
        let d = RunConfig { num_seeds: 100, sample_seed: 43, ..Default::default() };
        assert_ne!(a, d.seeds(1 << 16));
    }

    #[test]
    fn bad_reduce_topology_rejected() {
        let mut c = RunConfig::default();
        c.apply_override("reduce", "diagonal").unwrap();
        assert!(c.engine_config().is_err());
    }
}
