//! **GraphGen** — the offline predecessor (Jin et al., EuroSys'24 poster),
//! reconstructed as the paper describes its deltas:
//!
//! * same distributed edge-centric extraction, but **no balance table**:
//!   seeds map to workers in contiguous blocks of the input order;
//! * **flat aggregation**: every scan task's partial result funnels into a
//!   single aggregator (the hot-node bottleneck tree reduction fixes);
//! * **precomputed subgraphs**: every subgraph is serialized to spill
//!   shards on disk, and only after *all* generation finishes are they
//!   read back and handed to the consumer — the storage + I/O overhead
//!   GraphGen+ eliminates (E5), and the reason generation cannot overlap
//!   training (E6).

use crate::balance::MappingStrategy;
use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;
use crate::storage::SpillStore;
use crate::util::timer::{PhaseTimer, Stopwatch};

use super::common::{edge_centric_hop, plan_waves, WaveLanes};
use super::{EngineConfig, GenReport, ReduceTopology, SubgraphEngine, SubgraphSink};
use crate::util::workpool::WorkPool;

pub struct GraphGenOffline;

impl SubgraphEngine for GraphGenOffline {
    fn name(&self) -> &'static str {
        "graphgen"
    }

    fn generate(
        &self,
        graph: &Csr,
        seeds: &[NodeId],
        cfg: &EngineConfig,
        sink: &dyn SubgraphSink,
    ) -> anyhow::Result<GenReport> {
        let wall = Stopwatch::new();
        let mut phases = PhaseTimer::new();
        let fabric = Fabric::new(cfg.workers);
        let mut ledger = crate::cluster::WorkLedger::new(cfg.workers);
        // Predecessor semantics regardless of what the caller configured:
        // contiguous mapping + flat aggregation.
        let mut cfg = cfg.clone();
        cfg.mapping = MappingStrategy::Contiguous;
        cfg.reduce = ReduceTopology::Flat;
        let spill_dir = cfg.spill_dir.clone().unwrap_or_else(|| {
            std::env::temp_dir().join(format!("graphgen-spill-{}", std::process::id()))
        });
        let mut store = SpillStore::create(spill_dir, cfg.spill_compress)?;

        let pool = WorkPool::global();
        let spawned0 = pool.total_spawned();
        let mut lanes = WaveLanes::new();
        let (table, waves) = phases.time("map.balance", || plan_waves(seeds, &cfg));
        let mut subgraphs = 0u64;
        let mut sampled_nodes = 0u64;
        lanes.run(
            graph,
            &table,
            &waves,
            &cfg,
            &fabric,
            &mut ledger,
            &mut phases,
            edge_centric_hop,
            // Offline: the sink never sees in-flight waves (subgraphs go
            // to disk first), so the ring runs without admission gating.
            None,
            |phases, ledger, slots| {
                // Offline: subgraphs go to DISK, not to the consumer.
                phases.time("spill.write", || -> anyhow::Result<()> {
                    for (worker, sg) in slots.into_subgraphs() {
                        subgraphs += 1;
                        sampled_nodes += sg.num_nodes();
                        // Each worker writes (and training later reads) its
                        // own subgraphs: disk bytes ×2 for the round trip.
                        ledger.charge(
                            "spill",
                            worker as usize,
                            crate::cluster::WorkUnits {
                                disk_bytes: 2 * sg.encoded_len() as u64,
                                ..Default::default()
                            },
                        );
                        store.write(&sg)?;
                    }
                    Ok(())
                })
            },
        )?;
        phases.time("spill.write", || store.finish_writes())?;
        // Training-time read-back: decode every subgraph from disk and
        // deliver it (worker = contiguous block position, as generated).
        let workers = cfg.workers;
        let per_worker = (table.seeds.len() / workers.max(1)).max(1);
        let mut idx = 0usize;
        phases.time("spill.read", || {
            store.read_all(|sg| {
                let worker = (idx / per_worker).min(workers - 1);
                idx += 1;
                sink.accept(worker, sg)
            })
        })?;
        let spill_report = store.report().clone();
        store.cleanup()?;
        Ok(GenReport {
            engine: self.name(),
            subgraphs,
            sampled_nodes,
            wall: wall.elapsed(),
            phases,
            fabric: fabric.stats(),
            spill: Some(spill_report),
            discarded_seeds: table.discarded.len() as u64,
            ledger,
            scratch: lanes.scratch_stats(pool.total_spawned() - spawned0),
            wave_pipeline: lanes.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::engines::CollectSink;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg(tag: &str) -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 32,
            fanout: FanoutSpec::new(vec![4, 3]),
            sample_seed: 77,
            spill_dir: Some(std::env::temp_dir().join(format!(
                "ggtest-offline-{tag}-{}",
                std::process::id()
            ))),
            ..Default::default()
        }
    }

    #[test]
    fn produces_same_subgraphs_as_graphgen_plus() {
        // The engines differ in mapping/aggregation/storage — but sampling
        // decisions are shared, so the *set* of subgraphs per seed matches.
        let g = generator::from_spec("rmat:n=1024,e=8192", 4).unwrap().csr();
        let seeds: Vec<NodeId> = (0..64).collect();
        let off_sink = CollectSink::default();
        let on_sink = CollectSink::default();
        let off = GraphGenOffline.generate(&g, &seeds, &cfg("cmp"), &off_sink).unwrap();
        GraphGenPlus.generate(&g, &seeds, &cfg("cmp2"), &on_sink).unwrap();
        assert_eq!(off_sink.take_sorted(), on_sink.take_sorted());
        assert_eq!(off.subgraphs, 64);
    }

    #[test]
    fn reports_storage_overhead() {
        let g = generator::from_spec("rmat:n=512,e=4096", 2).unwrap().csr();
        let seeds: Vec<NodeId> = (0..64).collect();
        let sink = CollectSink::default();
        let report = GraphGenOffline.generate(&g, &seeds, &cfg("sto"), &sink).unwrap();
        let spill = report.spill.as_ref().expect("offline engine spills");
        assert_eq!(spill.subgraphs, 64);
        assert!(spill.disk_bytes > 0);
        assert!(report.phases.get("spill.read") > std::time::Duration::ZERO);
    }

    #[test]
    fn spill_dir_is_cleaned_up() {
        let g = generator::from_spec("er:n=128,e=512", 1).unwrap().csr();
        let c = cfg("clean");
        let sink = CollectSink::default();
        GraphGenOffline.generate(&g, &(0..16).collect::<Vec<_>>(), &c, &sink).unwrap();
        assert!(!c.spill_dir.unwrap().exists());
    }
}
