//! **AGL-style node-centric** baseline (Zhang et al., VLDB'20).
//!
//! AGL's MapReduce keys neighbor collection by *node*: all work for one
//! frontier node — scanning its full adjacency for every subgraph that
//! wants it — is a single sequential task on a single worker. The paper's
//! critique (§1): "a node-centric MapReduce paradigm ... serially
//! processes neighbor collection when high-degree nodes occur, creating
//! performance bottlenecks." On a hub with degree d wanted by s subgraphs
//! the task costs O(d·s) on one thread while other workers idle; the
//! node's entire adjacency also ships to one reducer (fan-in charged on
//! the fabric).

use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;

use crate::util::timer::{PhaseTimer, Stopwatch};
use crate::util::workpool::WorkPool;

use super::common::{assign_hop, plan_waves, Frame, ScratchArena, WaveLanes, WaveSlots};
use super::{EngineConfig, GenReport, SubgraphEngine, SubgraphSink};

pub struct AglNodeCentric;

impl SubgraphEngine for AglNodeCentric {
    fn name(&self) -> &'static str {
        "agl"
    }

    fn generate(
        &self,
        graph: &Csr,
        seeds: &[NodeId],
        cfg: &EngineConfig,
        sink: &dyn SubgraphSink,
    ) -> anyhow::Result<GenReport> {
        let wall = Stopwatch::new();
        let mut phases = PhaseTimer::new();
        let fabric = Fabric::new(cfg.workers);
        let mut ledger = crate::cluster::WorkLedger::new(cfg.workers);
        let pool = WorkPool::global();
        let spawned0 = pool.total_spawned();
        let mut lanes = WaveLanes::new();
        let (table, waves) = phases.time("map.balance", || plan_waves(seeds, cfg));
        let mut subgraphs = 0u64;
        let mut sampled_nodes = 0u64;
        lanes.run(
            graph,
            &table,
            &waves,
            cfg,
            &fabric,
            &mut ledger,
            &mut phases,
            node_centric_hop,
            Some(sink),
            |phases, _ledger, slots| {
                phases.time("emit", || -> anyhow::Result<()> {
                    for (worker, sg) in slots.into_subgraphs() {
                        subgraphs += 1;
                        sampled_nodes += sg.num_nodes();
                        sink.accept(worker as usize, sg)?;
                    }
                    Ok(())
                })
            },
        )?;
        Ok(GenReport {
            engine: self.name(),
            subgraphs,
            sampled_nodes,
            wall: wall.elapsed(),
            phases,
            fabric: fabric.stats(),
            spill: None,
            discarded_seeds: table.discarded.len() as u64,
            ledger,
            scratch: lanes.scratch_stats(pool.total_spawned() - spawned0),
            wave_pipeline: lanes.stats,
        })
    }
}

/// One node-centric hop round: one task per frontier *node*, never split.
pub(crate) fn node_centric_hop(
    g: &Csr,
    slots: &mut WaveSlots<'_>,
    hop: u32,
    cfg: &EngineConfig,
    fabric: &Fabric,
    ledger: &mut crate::cluster::WorkLedger,
    scratch: &mut ScratchArena,
) {
    let k = cfg.fanout.fanouts[(hop - 1) as usize] as usize;
    slots.fill_frontier_par(hop, &mut scratch.frontier, &mut scratch.offsets, cfg.threads);
    if scratch.frontier.is_empty() {
        return;
    }
    scratch.index.rebuild_par(&scratch.frontier, cfg.threads);
    scratch.nodes.clear();
    scratch.nodes.extend_from_slice(scratch.index.nodes());
    scratch.nodes.sort_unstable(); // deterministic task order
    // Node-centric shuffle + processing: each frontier node's FULL
    // adjacency travels to — and is scanned serially by — the single
    // worker responsible for that node. A hub's whole neighbor list ×
    // every interested subgraph lands on ONE worker's ledger: the
    // paper's "serially processes neighbor collection" bottleneck.
    let scan_phase = format!("hop{hop}.scan");
    for &v in &scratch.nodes {
        let src = (v as usize) % cfg.workers;
        let dst = (crate::util::rng::mix64(v as u64) as usize) % cfg.workers;
        let bytes = 4u64 * g.degree(v) as u64;
        if src != dst {
            fabric.charge(src, dst, bytes);
        }
        ledger.charge(
            &scan_phase,
            dst,
            crate::cluster::WorkUnits {
                scan_edge_entries: g.degree(v) as u64 * scratch.index.get(v).len() as u64,
                net_bytes: bytes,
                msgs: 1,
                ..Default::default()
            },
        );
    }
    // One sequential task per node: the hub's whole neighbor list × all
    // interested subgraphs runs on one thread (the AGL bottleneck).
    // Claim granularity is routed through the per-hop adaptive sizer
    // (measured per-item cost → ~target-sized claims) instead of the
    // fixed threads×8 divisor; chunking only changes scheduling, so the
    // output bytes are unaffected.
    let seeds = slots.seeds;
    let (index, nodes, frames) = (&scratch.index, &scratch.nodes, &scratch.frames);
    let n = nodes.len();
    let hop_idx = (hop - 1) as usize;
    let chunk = n.div_ceil(scratch.sizers[hop_idx].num_tasks(cfg)).max(1);
    // Chunk-granular timing rides in the result slots (two clock reads
    // per claimed chunk, none per node — see `ChunkClock`); the sizer
    // sees the summed CPU after collection.
    let clock = super::common::ChunkClock::new(chunk, n);
    let timed: Vec<(Frame, std::time::Duration)> = WorkPool::global()
        .map_collect(n, cfg.threads, chunk, |i| {
            clock.start(i);
            let v = nodes[i];
            let mut frame = frames.acquire();
            let entries = index.get(v);
            // A node's index entries carry ascending ordinals, so the
            // frame fills positionally — no sort, no hashing.
            frame.prepare(k, entries.iter().map(|&(_, ord)| ord));
            // Pins the cold page on a tiered graph, borrows when resident.
            let run = g.neighbors_ref(v);
            let neigh = &*run;
            for &(slot, ord) in entries {
                let seed = seeds[slot as usize];
                let base = crate::sampler::priority_base(cfg.sample_seed, hop, seed, v);
                let res = frame.tok_for(ord);
                let mut threshold = res.threshold();
                for &nbr in neigh {
                    let p = crate::sampler::priority_from_base(base, nbr);
                    if p < threshold {
                        res.insert(p, nbr);
                        threshold = res.threshold();
                    }
                }
            }
            (frame, clock.stop(i))
        });
    let mut cpu = std::time::Duration::ZERO;
    let mut partials = Vec::with_capacity(timed.len());
    for (frame, took) in timed {
        cpu += took;
        partials.push(frame);
    }
    scratch.sizers[hop_idx].record(n.div_ceil(chunk), cpu);
    // Merge: each ordinal lives in exactly one node's partial (an ordinal
    // is one frontier entry, owned by one node), and every frontier node
    // has a partial — so the union is dense and disjoint. Build the
    // merged frame as the identity ordinal list and copy each partial's
    // reservoirs into place: linear in frontier size, no pairwise folds.
    let mut acc = frames.acquire();
    for ord in 0..scratch.frontier.len() as u32 {
        acc.push_new(ord, k);
    }
    for p in &partials {
        for (ord, tok) in p.entries() {
            // acc's ordinal list is the identity, so position == ordinal.
            acc.tok_at(ord as usize).copy_from(tok);
        }
    }
    for p in partials {
        frames.release(p);
    }
    // Same assignment accounting as the edge-centric engines.
    let assign_phase = format!("hop{hop}.assign");
    for (ord, res) in acc.entries() {
        let slot = scratch.frontier[ord as usize].1 as usize;
        let dst = slots.worker_of[slot] as usize % cfg.workers;
        ledger.charge(
            &assign_phase,
            dst,
            crate::cluster::WorkUnits {
                merge_entries: res.len() as u64,
                net_bytes: 8 + 12 * res.len() as u64,
                msgs: 1,
                ..Default::default()
            },
        );
    }
    assign_hop(slots, hop, Some(&acc), &scratch.frontier, fabric, cfg.workers);
    frames.release(acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::engines::CollectSink;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 64,
            fanout: FanoutSpec::new(vec![4, 3]),
            sample_seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn matches_graphgen_plus_output() {
        let g = generator::from_spec("planted:n=1024,e=8192,c=4", 9).unwrap().csr();
        let seeds: Vec<NodeId> = (100..164).collect();
        let a = CollectSink::default();
        let b = CollectSink::default();
        AglNodeCentric.generate(&g, &seeds, &cfg(), &a).unwrap();
        GraphGenPlus.generate(&g, &seeds, &cfg(), &b).unwrap();
        assert_eq!(a.take_sorted(), b.take_sorted());
    }

    #[test]
    fn per_node_chunking_routes_through_task_sizer() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 9).unwrap().csr();
        let seeds: Vec<NodeId> = (0..128).collect();
        let report = AglNodeCentric
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        for hop in 0..2 {
            assert!(
                report.scratch.scan_tasks[hop] > 0,
                "hop {} sizer never recorded a round: {:?}",
                hop + 1,
                report.scratch
            );
            assert!(report.scratch.task_ewma_ns[hop] > 0, "{:?}", report.scratch);
        }
    }

    #[test]
    fn hub_fan_in_shows_on_fabric() {
        let g = generator::from_spec("star:n=4096,hubs=1", 1).unwrap().csr();
        // Seeds adjacent to the hub → hub lands on the hop-1 frontier...
        let seeds: Vec<NodeId> = vec![0, 10, 20, 30]; // includes hub itself
        let report = AglNodeCentric
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        // The hub's ~4095-edge adjacency must have been shipped whole.
        assert!(report.fabric.total_bytes >= 4 * 4000);
    }
}
