//! **AGL-style node-centric** baseline (Zhang et al., VLDB'20).
//!
//! AGL's MapReduce keys neighbor collection by *node*: all work for one
//! frontier node — scanning its full adjacency for every subgraph that
//! wants it — is a single sequential task on a single worker. The paper's
//! critique (§1): "a node-centric MapReduce paradigm ... serially
//! processes neighbor collection when high-degree nodes occur, creating
//! performance bottlenecks." On a hub with degree d wanted by s subgraphs
//! the task costs O(d·s) on one thread while other workers idle; the
//! node's entire adjacency also ships to one reducer (fan-in charged on
//! the fabric).

use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;

use crate::sampler::reservoir::TopK;
use crate::util::pool::parallel_map;
use crate::util::timer::{PhaseTimer, Stopwatch};

use super::common::{assign_hop, build_index, plan_waves, ReservoirMap, WaveSlots};
use super::{EngineConfig, GenReport, SubgraphEngine, SubgraphSink};

pub struct AglNodeCentric;

impl SubgraphEngine for AglNodeCentric {
    fn name(&self) -> &'static str {
        "agl"
    }

    fn generate(
        &self,
        graph: &Csr,
        seeds: &[NodeId],
        cfg: &EngineConfig,
        sink: &dyn SubgraphSink,
    ) -> anyhow::Result<GenReport> {
        let wall = Stopwatch::new();
        let mut phases = PhaseTimer::new();
        let fabric = Fabric::new(cfg.workers);
        let mut ledger = crate::cluster::WorkLedger::new(cfg.workers);
        let (table, waves) = phases.time("map.balance", || plan_waves(seeds, cfg));
        let mut subgraphs = 0u64;
        let mut sampled_nodes = 0u64;
        for wave in waves {
            let wave_seeds = table.seeds[wave.clone()].to_vec();
            let wave_workers = table.worker_of[wave].to_vec();
            let mut slots = WaveSlots::new(wave_seeds, wave_workers);
            for hop in 1..=cfg.fanout.hops() as u32 {
                phases.time(&format!("hop{hop}"), || {
                    node_centric_hop(graph, &mut slots, hop, cfg, &fabric, &mut ledger)
                });
            }
            phases.time("emit", || -> anyhow::Result<()> {
                for (worker, sg) in slots.into_subgraphs() {
                    subgraphs += 1;
                    sampled_nodes += sg.num_nodes();
                    sink.accept(worker as usize, sg)?;
                }
                Ok(())
            })?;
        }
        Ok(GenReport {
            engine: self.name(),
            subgraphs,
            sampled_nodes,
            wall: wall.elapsed(),
            phases,
            fabric: fabric.stats(),
            spill: None,
            discarded_seeds: table.discarded.len() as u64,
            ledger,
        })
    }
}

/// One node-centric hop round: one task per frontier *node*, never split.
fn node_centric_hop(
    g: &Csr,
    slots: &mut WaveSlots,
    hop: u32,
    cfg: &EngineConfig,
    fabric: &Fabric,
    ledger: &mut crate::cluster::WorkLedger,
) {
    let k = cfg.fanout.fanouts[(hop - 1) as usize] as usize;
    let frontier = slots.frontier(hop);
    if frontier.is_empty() {
        return;
    }
    let index = build_index(&frontier);
    let nodes: Vec<NodeId> = {
        let mut v: Vec<NodeId> = index.iter().map(|(n, _)| n).collect();
        v.sort_unstable(); // deterministic task order
        v
    };
    // Node-centric shuffle + processing: each frontier node's FULL
    // adjacency travels to — and is scanned serially by — the single
    // worker responsible for that node. A hub's whole neighbor list ×
    // every interested subgraph lands on ONE worker's ledger: the
    // paper's "serially processes neighbor collection" bottleneck.
    let scan_phase = format!("hop{hop}.scan");
    for &v in &nodes {
        let src = (v as usize) % cfg.workers;
        let dst = (crate::util::rng::mix64(v as u64) as usize) % cfg.workers;
        let bytes = 4u64 * g.degree(v) as u64;
        if src != dst {
            fabric.charge(src, dst, bytes);
        }
        ledger.charge(
            &scan_phase,
            dst,
            crate::cluster::WorkUnits {
                scan_edge_entries: g.degree(v) as u64 * index.get(v).len() as u64,
                net_bytes: bytes,
                msgs: 1,
                ..Default::default()
            },
        );
    }
    // One sequential task per node: the hub's whole neighbor list × all
    // interested subgraphs runs on one thread (the AGL bottleneck).
    let seeds = &slots.seeds;
    let partials: Vec<ReservoirMap> = parallel_map(&nodes, cfg.threads, |&v| {
        let mut map = ReservoirMap::default();
        let neigh = g.neighbors(v);
        for &(slot, pos) in index.get(v) {
            let seed = seeds[slot as usize];
            let base = crate::sampler::priority_base(cfg.sample_seed, hop, seed, v);
            let res = map
                .entry(super::common::slot_key(slot, pos))
                .or_insert_with(|| TopK::new(k));
            let mut threshold = res.threshold();
            for &nbr in neigh {
                let p = crate::sampler::priority_from_base(base, nbr);
                if p < threshold {
                    res.insert(p, nbr);
                    threshold = res.threshold();
                }
            }
        }
        map
    });
    // Merge (cheap: keys are disjoint across nodes except shared (slot,pos)
    // pairs, which only collide for hop-1 seeds wanted by one node).
    let merged = partials
        .into_iter()
        .fold(ReservoirMap::default(), super::common::merge_maps);
    // Same assignment accounting as the edge-centric engines.
    let assign_phase = format!("hop{hop}.assign");
    for (key, res) in merged.iter() {
        let slot = (key >> 32) as usize;
        let dst = slots.worker_of[slot] as usize % cfg.workers;
        ledger.charge(
            &assign_phase,
            dst,
            crate::cluster::WorkUnits {
                merge_entries: res.len() as u64,
                net_bytes: 8 + 12 * res.len() as u64,
                msgs: 1,
                ..Default::default()
            },
        );
    }
    assign_hop(slots, hop, merged, fabric, cfg.workers);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::engines::CollectSink;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 64,
            fanout: FanoutSpec::new(vec![4, 3]),
            sample_seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn matches_graphgen_plus_output() {
        let g = generator::from_spec("planted:n=1024,e=8192,c=4", 9).unwrap().csr();
        let seeds: Vec<NodeId> = (100..164).collect();
        let a = CollectSink::default();
        let b = CollectSink::default();
        AglNodeCentric.generate(&g, &seeds, &cfg(), &a).unwrap();
        GraphGenPlus.generate(&g, &seeds, &cfg(), &b).unwrap();
        assert_eq!(a.take_sorted(), b.take_sorted());
    }

    #[test]
    fn hub_fan_in_shows_on_fabric() {
        let g = generator::from_spec("star:n=4096,hubs=1", 1).unwrap().csr();
        // Seeds adjacent to the hub → hub lands on the hop-1 frontier...
        let seeds: Vec<NodeId> = vec![0, 10, 20, 30]; // includes hub itself
        let report = AglNodeCentric
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        // The hub's ~4095-edge adjacency must have been shipped whole.
        assert!(report.fabric.total_bytes >= 4 * 4000);
    }
}
