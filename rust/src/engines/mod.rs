//! Subgraph generation engines.
//!
//! Four implementations behind one trait, reproducing the paper's E1
//! comparison (DESIGN.md §5):
//!
//! | engine | paradigm | of the paper |
//! |---|---|---|
//! | [`graphgen_plus`] | edge-centric MapReduce, balance table, tree reduction, streams in-memory | **the contribution** |
//! | [`graphgen`] | edge-centric, contiguous mapping, flat aggregation, spills to disk | offline predecessor (EuroSys'24) |
//! | [`agl`] | node-centric MapReduce: one task per frontier node | AGL (VLDB'20) |
//! | [`sql_like`] | per-hop join materialization + sort + group-sample | "traditional SQL-like methods" |
//!
//! All engines use the same hash-priority sampling (see [`crate::sampler`]),
//! so **they produce identical subgraphs** for identical inputs — verified
//! by integration tests — and differ only in cost structure, which is the
//! point of the benchmark.

pub mod agl;
pub mod common;
pub mod graphgen;
pub mod graphgen_plus;
pub mod sql_like;

use std::time::Duration;

use crate::balance::MappingStrategy;
use crate::cluster::costmodel::{CostModel, SimBreakdown, WorkLedger};
use crate::cluster::FabricStats;
use crate::graph::csr::Csr;
use crate::graph::NodeId;
use crate::sampler::{FanoutSpec, Subgraph};
use crate::storage::SpillReport;
use crate::util::timer::PhaseTimer;

/// Where completed subgraphs go. Implementations: in-memory collection,
/// the training pipeline's bounded queue, or a discarding sink for pure
/// generation benchmarks.
pub trait SubgraphSink: Sync {
    /// Accept a completed subgraph generated on `worker`.
    fn accept(&self, worker: usize, sg: Subgraph) -> anyhow::Result<()>;

    /// Whether this sink wants [`wave_complete`](Self::wave_complete)
    /// notifications (computing a wave's unique-node set costs a sort, so
    /// engines skip it for sinks that don't care).
    fn wants_waves(&self) -> bool {
        false
    }

    /// Called once per completed wave, before its subgraphs are accepted,
    /// with the wave's sorted unique node ids
    /// ([`common::WaveSlots::unique_nodes`]) — the hook the pipeline uses
    /// to warm the feature cache a whole wave ahead of training.
    fn wave_complete(&self, _nodes: &[NodeId]) {}

    /// Non-blocking admission probe for the look-ahead wave ring: `false`
    /// while the sink sits above its backpressure high-water mark and no
    /// further speculative wave should be admitted (see
    /// [`common::WaveLanes`]). Sinks without backpressure always admit.
    fn lookahead_admit(&self) -> bool {
        true
    }

    /// Block until [`lookahead_admit`](Self::lookahead_admit) may succeed
    /// again (credits return when the consumer dequeues) or the sink
    /// shuts down — implementations must return promptly on shutdown so
    /// generation can surface the error instead of hanging.
    fn lookahead_wait(&self) {}

    /// Ring-admission notification: wave `seq` was handed to the
    /// look-ahead pool while the adaptive controller's effective depth
    /// was `depth`. Lets a backpressuring sink account its admission
    /// credits **per sequence**, bucketed by the same effective-depth
    /// axis the ring's occupancy histogram and decision trace use (see
    /// [`crate::pipeline::QueueSink::admits_by_depth`]). Default no-op.
    fn lookahead_admitted(&self, _seq: u64, _depth: usize) {}
}

/// Collects into a mutex-guarded vector (tests, small runs).
#[derive(Default)]
pub struct CollectSink {
    pub collected: std::sync::Mutex<Vec<Subgraph>>,
}

impl SubgraphSink for CollectSink {
    fn accept(&self, _worker: usize, sg: Subgraph) -> anyhow::Result<()> {
        self.collected.lock().unwrap().push(sg);
        Ok(())
    }
}

impl CollectSink {
    /// Take the collected subgraphs, sorted by seed for comparisons.
    pub fn take_sorted(&self) -> Vec<Subgraph> {
        let mut v = std::mem::take(&mut *self.collected.lock().unwrap());
        v.sort_by_key(|s| s.seed);
        v
    }
}

/// Counts and discards (pure generation throughput benchmarks).
#[derive(Default)]
pub struct NullSink {
    pub subgraphs: std::sync::atomic::AtomicU64,
    pub nodes: std::sync::atomic::AtomicU64,
}

impl SubgraphSink for NullSink {
    fn accept(&self, _worker: usize, sg: Subgraph) -> anyhow::Result<()> {
        use std::sync::atomic::Ordering;
        self.subgraphs.fetch_add(1, Ordering::Relaxed);
        self.nodes.fetch_add(sg.num_nodes(), Ordering::Relaxed);
        Ok(())
    }
}

/// Reduction topology for merging per-scan-task partial results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceTopology {
    /// Hierarchical tree with the given arity (paper; default arity 4).
    Tree { arity: usize },
    /// Single sequential aggregator (the hot-spot baseline).
    Flat,
}

/// Engine-independent generation settings.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Simulated cluster width (fabric accounting granularity).
    pub workers: usize,
    /// OS threads for scan/merge tasks.
    pub threads: usize,
    /// Seeds per generation wave (a wave ≈ the paper's "iteration": its
    /// subgraphs stream to the sink before the next wave starts).
    pub wave_size: usize,
    pub fanout: FanoutSpec,
    /// Sampling determinism seed (shared by all engines → same output).
    pub sample_seed: u64,
    pub mapping: MappingStrategy,
    pub reduce: ReduceTopology,
    /// Spill directory for the offline engine.
    pub spill_dir: Option<std::path::PathBuf>,
    /// Compress spill shards.
    pub spill_compress: bool,
    /// Overlap hop work of future waves with reduce/emit of the current
    /// one (look-ahead scratch-lane ring). Output bytes are identical
    /// either way — this only reorders the schedule; see
    /// [`common::WaveLanes`].
    pub wave_pipeline: bool,
    /// Look-ahead ring depth ceiling: how many waves may be in flight on
    /// the speculator pool ahead of the wave being emitted (≥ 1; depth
    /// ≥ 2 also speculates hop-2 of look-ahead waves when a worker would
    /// otherwise idle). The *effective* depth adapts within
    /// `[1, lookahead_depth]` from the measured stall taxonomy (see
    /// [`common::DepthController`]); admission is backpressured by the
    /// sink.
    pub lookahead_depth: usize,
    /// Look-ahead worker pool size: speculator threads that claim future
    /// waves **out of order** from the admission queue (clamped to the
    /// ring depth). A sequence-numbered reorder buffer keeps emission in
    /// FIFO wave order, so output bytes are identical at any value.
    pub lookahead_workers: usize,
    /// Test-only scheduling jitter: per-wave delays injected on the
    /// speculators so out-of-order completion can be forced
    /// deterministically (see [`crate::testkit::WaveDelay`]). `None` in
    /// production; timing only, never output.
    pub wave_delay: Option<crate::testkit::WaveDelay>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 8,
            threads: crate::util::workpool::default_threads(),
            wave_size: 4096,
            fanout: FanoutSpec::paper(),
            sample_seed: 0x5eed,
            mapping: MappingStrategy::ShuffledRoundRobin,
            reduce: ReduceTopology::Tree { arity: 4 },
            spill_dir: None,
            spill_compress: false,
            wave_pipeline: true,
            lookahead_depth: 2,
            lookahead_workers: 2,
            wave_delay: None,
        }
    }
}

/// Result of one generation run.
#[derive(Debug, Clone)]
pub struct GenReport {
    pub engine: &'static str,
    pub subgraphs: u64,
    /// Total sampled node slots (the paper's "nodes" in nodes/second).
    pub sampled_nodes: u64,
    pub wall: Duration,
    pub phases: PhaseTimer,
    pub fabric: FabricStats,
    /// Disk I/O report (offline engine only).
    pub spill: Option<SpillReport>,
    pub discarded_seeds: u64,
    /// Work counters for the simulated-cluster cost model.
    pub ledger: WorkLedger,
    /// Scratch-arena / work-pool reuse counters: steady-state hop rounds
    /// must show zero thread spawns and zero fresh frame allocations.
    pub scratch: common::ScratchStats,
    /// Wave-pipeline counters: overlapped waves and the bubble (time the
    /// wave loop stalled waiting for a prefetched hop-1).
    pub wave_pipeline: common::WavePipelineStats,
}

impl GenReport {
    /// The paper's headline generation metric (real wall clock — on this
    /// 1-core testbed, see [`sim`](Self::sim) for the cluster projection).
    pub fn nodes_per_sec(&self) -> f64 {
        self.sampled_nodes as f64 / self.wall.as_secs_f64()
    }

    /// Modeled cluster time under a cost model (DESIGN.md §2).
    pub fn sim(&self, model: &CostModel) -> SimBreakdown {
        model.breakdown(&self.ledger)
    }

    /// Modeled nodes/second on the simulated cluster.
    pub fn sim_nodes_per_sec(&self, model: &CostModel) -> f64 {
        self.sampled_nodes as f64 / self.sim(model).total_secs.max(1e-12)
    }

    /// JSON view for the unified report writer ([`crate::obs::report`]).
    /// The work ledger is omitted (cost-model input, not a result).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("engine", self.engine)
            .set("subgraphs", self.subgraphs)
            .set("sampled_nodes", self.sampled_nodes)
            .set("wall_s", self.wall.as_secs_f64())
            .set("nodes_per_sec", self.nodes_per_sec())
            .set("discarded_seeds", self.discarded_seeds);
        let mut phases = Json::obj();
        for (name, d) in self.phases.iter() {
            phases.set(name, d.as_secs_f64());
        }
        o.set("phases", phases);
        let mut fabric = Json::obj();
        fabric
            .set("workers", self.fabric.workers)
            .set("total_bytes", self.fabric.total_bytes)
            .set("total_messages", self.fabric.total_messages);
        o.set("fabric", fabric);
        if let Some(sp) = &self.spill {
            o.set("spill", sp.to_json());
        }
        let mut scratch = Json::obj();
        scratch
            .set("frames_allocated", self.scratch.frames_allocated)
            .set("frames_reused", self.scratch.frames_reused)
            .set("steady_frame_allocs", self.scratch.steady_frame_allocs)
            .set("pool_threads_spawned", self.scratch.pool_threads_spawned);
        o.set("scratch", scratch);
        let wp = &self.wave_pipeline;
        let mut wave = Json::obj();
        wave.set("waves", wp.waves)
            .set("overlapped_waves", wp.overlapped_waves)
            .set("deep_waves", wp.deep_waves)
            .set("bubble_s", wp.bubble.as_secs_f64())
            .set("lane_starved_stalls", wp.lane_starved_stalls)
            .set("queue_full_stalls", wp.queue_full_stalls)
            .set("queue_full_wait_s", wp.queue_full_wait.as_secs_f64())
            .set("gather_waits", wp.gather_waits)
            .set("gather_wait_s", wp.gather_wait.as_secs_f64())
            .set("deepen_steps", wp.deepen_steps)
            .set("shallow_steps", wp.shallow_steps)
            .set("effective_depth_last", wp.effective_depth_last as u64)
            .set("worker_scale_ups", wp.worker_scale_ups)
            .set("worker_scale_downs", wp.worker_scale_downs)
            .set("effective_workers_last", wp.effective_workers_last as u64);
        o.set("wave_pipeline", wave);
        o
    }

    pub fn render(&self) -> String {
        use crate::util::bytes::{fmt_bytes, fmt_rate, fmt_secs};
        let mut s = format!(
            "engine={} subgraphs={} nodes={} wall={} rate={} shuffle={} [{}]",
            self.engine,
            self.subgraphs,
            self.sampled_nodes,
            fmt_secs(self.wall.as_secs_f64()),
            fmt_rate(self.nodes_per_sec(), "nodes"),
            fmt_bytes(self.fabric.total_bytes),
            self.phases.render(),
        );
        if let Some(sp) = &self.spill {
            s.push_str(&format!(
                " storage={} write={} flush={} (wait={}) read={} (wait={}, overlapped={})",
                fmt_bytes(sp.disk_bytes),
                fmt_secs(sp.write_time.as_secs_f64()),
                fmt_secs(sp.flush_time.as_secs_f64()),
                fmt_secs(sp.flush_wait.as_secs_f64()),
                fmt_secs(sp.read_time.as_secs_f64()),
                fmt_secs(sp.read_wait.as_secs_f64()),
                sp.overlapped_reads,
            ));
        }
        // Sequential-schedule runs accrue gather-wait too — show the
        // taxonomy whenever any of it is populated, not only when the
        // ring overlapped (the pipelined-vs-sequential ablation needs
        // both sides).
        if self.wave_pipeline.overlapped_waves > 0 || self.wave_pipeline.gather_waits > 0 {
            let wp = &self.wave_pipeline;
            s.push_str(&format!(
                " overlap={}/{} deep={} bubble={} stalls[lane={} queue={}({}) gather={}({})] depth_ctl[eff={} +{}/-{}] workers_ctl[eff={} +{}/-{}]",
                wp.overlapped_waves,
                wp.waves,
                wp.deep_waves,
                fmt_secs(wp.bubble.as_secs_f64()),
                wp.lane_starved_stalls,
                wp.queue_full_stalls,
                fmt_secs(wp.queue_full_wait.as_secs_f64()),
                wp.gather_waits,
                fmt_secs(wp.gather_wait.as_secs_f64()),
                wp.effective_depth_last,
                wp.deepen_steps,
                wp.shallow_steps,
                wp.effective_workers_last,
                wp.worker_scale_ups,
                wp.worker_scale_downs,
            ));
        }
        s
    }
}

/// A subgraph generation engine. `Sync` so the pipeline driver can run
/// generation on a spawned thread while training consumes.
pub trait SubgraphEngine: Sync {
    fn name(&self) -> &'static str;

    /// Generate subgraphs for `seeds` over `graph`, streaming completed
    /// subgraphs into `sink`.
    fn generate(
        &self,
        graph: &Csr,
        seeds: &[NodeId],
        cfg: &EngineConfig,
        sink: &dyn SubgraphSink,
    ) -> anyhow::Result<GenReport>;
}

/// Construct an engine by name (CLI / bench dispatch).
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn SubgraphEngine>> {
    match name {
        "graphgen+" | "graphgen_plus" | "plus" => Ok(Box::new(graphgen_plus::GraphGenPlus)),
        "graphgen" | "offline" => Ok(Box::new(graphgen::GraphGenOffline)),
        "agl" | "node-centric" => Ok(Box::new(agl::AglNodeCentric)),
        "sql" | "sql-like" => Ok(Box::new(sql_like::SqlLike)),
        other => anyhow::bail!("unknown engine '{other}'"),
    }
}

/// The per-hop kernel behind an engine name — what a distributed worker
/// needs to regenerate individual waves via [`common::generate_wave`]
/// without running the engine's full schedule. Hop kernels fully
/// determine output bytes (schedules only reorder), so dispatching on the
/// kernel keeps multi-process runs byte-identical to the in-process
/// engine.
pub fn hop_fn_by_name(name: &str) -> anyhow::Result<common::HopFn> {
    match name {
        "graphgen+" | "graphgen_plus" | "plus" | "graphgen" | "offline" => {
            Ok(common::edge_centric_hop)
        }
        "agl" | "node-centric" => Ok(agl::node_centric_hop),
        "sql" | "sql-like" => Ok(sql_like::sql_hop),
        other => anyhow::bail!("unknown engine '{other}'"),
    }
}

/// Encodes every accepted subgraph in emission order into one byte
/// stream ([`Subgraph::encode_into`]) — the oracle side of the
/// distributed byte-equivalence contract, and the `--subgraph-bytes-out`
/// dump format.
#[derive(Default)]
pub struct EncodeSink {
    state: std::sync::Mutex<Vec<u8>>,
    pub subgraphs: std::sync::atomic::AtomicU64,
    pub nodes: std::sync::atomic::AtomicU64,
}

impl SubgraphSink for EncodeSink {
    fn accept(&self, _worker: usize, sg: Subgraph) -> anyhow::Result<()> {
        use std::sync::atomic::Ordering;
        self.subgraphs.fetch_add(1, Ordering::Relaxed);
        self.nodes.fetch_add(sg.num_nodes(), Ordering::Relaxed);
        sg.encode_into(&mut self.state.lock().unwrap());
        Ok(())
    }
}

impl EncodeSink {
    pub fn into_bytes(self) -> Vec<u8> {
        self.state.into_inner().unwrap()
    }
}
