//! **SQL-like** baseline — the "traditional SQL-like methods" the paper
//! reports a 27× speedup over.
//!
//! Production pipelines before GraphGen expressed k-hop expansion as SQL
//! over an edge table:
//!
//! ```sql
//! -- hop 1
//! CREATE TABLE hop1 AS
//!   SELECT s.seed, e.dst FROM seeds s JOIN edges e ON s.node = e.src;
//! -- sample AFTER materializing: ROW_NUMBER() OVER (PARTITION BY ...)
//! ```
//!
//! The cost structure this engine reproduces faithfully:
//! 1. **full join materialization** — every (subgraph, frontier, neighbor)
//!    row is allocated *before* any sampling happens (a SQL engine cannot
//!    push the top-k below the join);
//! 2. **shuffle + sort** — the window function requires a global sort by
//!    partition key, and every materialized row crosses the network
//!    (charged on the fabric);
//! 3. only then are the first k rows of each group kept.
//!
//! Sampling priorities are the same hash as everywhere else, so output
//! subgraphs are identical to GraphGen+'s — only ~f×deg× more bytes get
//! touched to produce them.

use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;

use crate::util::timer::{PhaseTimer, Stopwatch};
use crate::util::workpool::WorkPool;

use super::common::{plan_waves, ScanChunk, ScratchArena, WaveLanes, WaveSlots};
use super::{EngineConfig, GenReport, SubgraphEngine, SubgraphSink};

/// One materialized join-output row (what a SQL engine would shuffle).
/// 24 bytes, matching a (bigint, bigint, bigint) row layout.
#[derive(Debug, Clone, Copy)]
struct Row {
    /// group key: (slot, frontier position)
    key: u64,
    /// ORDER BY column for the window function (our sampling priority).
    order: u64,
    neighbor: NodeId,
    _pad: u32,
}

pub struct SqlLike;

impl SubgraphEngine for SqlLike {
    fn name(&self) -> &'static str {
        "sql-like"
    }

    fn generate(
        &self,
        graph: &Csr,
        seeds: &[NodeId],
        cfg: &EngineConfig,
        sink: &dyn SubgraphSink,
    ) -> anyhow::Result<GenReport> {
        let wall = Stopwatch::new();
        let mut phases = PhaseTimer::new();
        let fabric = Fabric::new(cfg.workers);
        let mut ledger = crate::cluster::WorkLedger::new(cfg.workers);
        let pool = WorkPool::global();
        let spawned0 = pool.total_spawned();
        let mut lanes = WaveLanes::new();
        let (table, waves) = phases.time("map.balance", || plan_waves(seeds, cfg));
        let mut subgraphs = 0u64;
        let mut sampled_nodes = 0u64;
        lanes.run(
            graph,
            &table,
            &waves,
            cfg,
            &fabric,
            &mut ledger,
            &mut phases,
            sql_hop,
            Some(sink),
            |phases, _ledger, slots| {
                phases.time("emit", || -> anyhow::Result<()> {
                    for (worker, sg) in slots.into_subgraphs() {
                        subgraphs += 1;
                        sampled_nodes += sg.num_nodes();
                        sink.accept(worker as usize, sg)?;
                    }
                    Ok(())
                })
            },
        )?;
        Ok(GenReport {
            engine: self.name(),
            subgraphs,
            sampled_nodes,
            wall: wall.elapsed(),
            phases,
            fabric: fabric.stats(),
            spill: None,
            discarded_seeds: table.discarded.len() as u64,
            ledger,
            scratch: lanes.scratch_stats(pool.total_spawned() - spawned0),
            wave_pipeline: lanes.stats,
        })
    }
}

/// One hop as JOIN → materialize → shuffle/sort → windowed top-k.
pub(crate) fn sql_hop(
    g: &Csr,
    slots: &mut WaveSlots<'_>,
    hop: u32,
    cfg: &EngineConfig,
    fabric: &Fabric,
    ledger: &mut crate::cluster::WorkLedger,
    scratch: &mut ScratchArena,
) {
    let k = cfg.fanout.fanouts[(hop - 1) as usize] as usize;
    slots.fill_frontier_par(hop, &mut scratch.frontier, &mut scratch.offsets, cfg.threads);
    if scratch.frontier.is_empty() {
        return;
    }
    scratch.index.rebuild_par(&scratch.frontier, cfg.threads);
    // --- JOIN: seeds ⋈ edges, fully materialized ------------------------
    // Parallel scan is allowed (SQL engines scan in parallel too); the
    // difference vs. GraphGen+ is that every row is allocated, none are
    // rejected early.
    scratch.nodes.clear();
    scratch.nodes.extend_from_slice(scratch.index.nodes());
    scratch.nodes.sort_unstable();
    scratch.chunks.clear();
    for &v in &scratch.nodes {
        scratch.chunks.push(ScanChunk { node: v, lo: 0, hi: g.degree(v) });
    }
    // Claim granularity is routed through the per-hop adaptive sizer
    // (measured per-chunk materialization cost → ~target-sized claims)
    // instead of the fixed threads×8 divisor; row order is per-index, so
    // the materialized table — and the output — is unaffected.
    let seeds = slots.seeds;
    let (index, chunks, offsets) = (&scratch.index, &scratch.chunks, &scratch.offsets);
    let n = chunks.len();
    let hop_idx = (hop - 1) as usize;
    let auto_chunk = n.div_ceil(scratch.sizers[hop_idx].num_tasks(cfg)).max(1);
    let pool = WorkPool::global();
    // Claim-chunk-granular timing rides in the result slots (two clock
    // reads per claimed chunk — see `ChunkClock`); the sizer sees the
    // summed CPU below.
    let clock = super::common::ChunkClock::new(auto_chunk, n);
    let row_chunks: Vec<(Vec<Row>, std::time::Duration)> =
        pool.map_collect(n, cfg.threads, auto_chunk, |ci| {
            clock.start(ci);
            let c = &chunks[ci];
            // Pins the cold page on a tiered graph, borrows when resident.
            let run = g.neighbors_ref(c.node);
            let neigh = &*run;
            let entries = index.get(c.node);
            let mut rows = Vec::with_capacity(neigh.len() * entries.len());
            for &(slot, ord) in entries {
                let seed = seeds[slot as usize];
                let pos = ord - offsets[slot as usize];
                let base = crate::sampler::priority_base(cfg.sample_seed, hop, seed, c.node);
                for &nbr in neigh {
                    rows.push(Row {
                        key: super::common::slot_key(slot, pos),
                        order: crate::sampler::priority_from_base(base, nbr),
                        neighbor: nbr,
                        _pad: 0,
                    });
                }
            }
            (rows, clock.stop(ci))
        });
    // Concatenate = the materialized join output table.
    let mut cpu = std::time::Duration::ZERO;
    let mut rows: Vec<Row> = Vec::with_capacity(row_chunks.iter().map(|(r, _)| r.len()).sum());
    for (mut c, took) in row_chunks {
        cpu += took;
        rows.append(&mut c);
    }
    scratch.sizers[hop_idx].record(n.div_ceil(auto_chunk), cpu);
    // --- SHUFFLE: every row crosses the network to its sort partition ---
    let w = cfg.workers;
    let mut per_dst_rows = vec![0u64; w];
    let mut per_dst_bytes = vec![0u64; w];
    for (i, r) in rows.iter().enumerate() {
        let src = i % w;
        // Hash partitioning on the group key (plain modulo would collapse
        // onto the low `pos` bits and starve most sort partitions).
        let dst = (crate::util::rng::mix64(r.key) as usize) % w;
        per_dst_rows[dst] += 1;
        if src != dst {
            fabric.charge(src, dst, 24);
            per_dst_bytes[dst] += 24;
        }
    }
    // Ledger: materialization (scan) is parallel over chunks; the sort +
    // shuffle is charged per receiving partition worker.
    let join_phase = format!("hop{hop}.join");
    let sort_phase = format!("hop{hop}.sort");
    ledger.charge(
        &join_phase,
        0,
        crate::cluster::WorkUnits::default(), // ensure phase exists
    );
    let row_counts = chunk_row_counts(&scratch.chunks, &scratch.index, g, w);
    for (wk, chunk_rows) in row_counts.into_iter().enumerate() {
        ledger.charge(
            &join_phase,
            wk,
            crate::cluster::WorkUnits { materialize_rows: chunk_rows, ..Default::default() },
        );
    }
    for wk in 0..w {
        ledger.charge(
            &sort_phase,
            wk,
            crate::cluster::WorkUnits {
                sort_rows: per_dst_rows[wk],
                net_bytes: per_dst_bytes[wk],
                msgs: 1,
                ..Default::default()
            },
        );
    }
    // --- SORT: global (PARTITION BY key ORDER BY order) -----------------
    rows.sort_unstable_by(|a, b| (a.key, a.order).cmp(&(b.key, b.order)));
    // --- WINDOW: keep ROW_NUMBER() <= k per group ------------------------
    // Group keys ascend, and `ordinal = offsets[slot] + pos` is monotone
    // in (slot, pos) — so groups stream straight into a dense frame.
    let mut merged = scratch.frames.acquire();
    let mut i = 0usize;
    while i < rows.len() {
        let key = rows[i].key;
        let (slot, pos) = ((key >> 32) as u32, (key & 0xffff_ffff) as u32);
        let ord = scratch.offsets[slot as usize] + pos;
        let res = merged.push_new(ord, k);
        let mut j = i;
        while j < rows.len() && rows[j].key == key {
            if j < i + k {
                res.insert(rows[j].order, rows[j].neighbor);
            }
            j += 1;
        }
        i = j;
    }
    super::common::assign_hop(slots, hop, Some(&merged), &scratch.frontier, fabric, cfg.workers);
    scratch.frames.release(merged);
}

/// Materialized row counts per simulated worker (scan chunk c runs on
/// worker c % w, producing deg × interested-subgraphs rows).
fn chunk_row_counts(
    chunks: &[ScanChunk],
    index: &crate::sampler::inverted::InvertedIndex,
    g: &Csr,
    w: usize,
) -> Vec<u64> {
    let mut per_worker = vec![0u64; w];
    for (c, chunk) in chunks.iter().enumerate() {
        let rows = (chunk.hi - chunk.lo) as u64 * index.get(chunk.node).len() as u64;
        per_worker[c % w] += rows;
    }
    let _ = g;
    per_worker
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::graphgen_plus::GraphGenPlus;
    use crate::engines::CollectSink;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 64,
            fanout: FanoutSpec::new(vec![4, 2]),
            sample_seed: 31,
            ..Default::default()
        }
    }

    #[test]
    fn matches_graphgen_plus_output() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 6).unwrap().csr();
        let seeds: Vec<NodeId> = (0..64).collect();
        let a = CollectSink::default();
        let b = CollectSink::default();
        SqlLike.generate(&g, &seeds, &cfg(), &a).unwrap();
        GraphGenPlus.generate(&g, &seeds, &cfg(), &b).unwrap();
        assert_eq!(a.take_sorted(), b.take_sorted());
    }

    #[test]
    fn join_chunking_routes_through_task_sizer() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 6).unwrap().csr();
        let seeds: Vec<NodeId> = (0..128).collect();
        let report = SqlLike
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        for hop in 0..2 {
            assert!(
                report.scratch.scan_tasks[hop] > 0,
                "hop {} sizer never recorded a round: {:?}",
                hop + 1,
                report.scratch
            );
            assert!(report.scratch.task_ewma_ns[hop] > 0, "{:?}", report.scratch);
        }
    }

    #[test]
    fn shuffles_far_more_bytes_than_graphgen_plus() {
        let g = generator::from_spec("rmat:n=2048,e=32768", 8).unwrap().csr();
        let seeds: Vec<NodeId> = (0..128).collect();
        let sql = SqlLike
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        let plus = GraphGenPlus
            .generate(&g, &seeds, &cfg(), &crate::engines::NullSink::default())
            .unwrap();
        assert!(
            sql.fabric.total_bytes > 3 * plus.fabric.total_bytes,
            "sql {} vs plus {}",
            sql.fabric.total_bytes,
            plus.fabric.total_bytes
        );
    }
}
