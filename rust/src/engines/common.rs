//! Shared machinery for the generation engines: wave state, frontier
//! indexing, edge-centric scan tasks and partial-result merging.
//!
//! Terminology (paper §2): generation proceeds in *waves* of seeds (a wave
//! corresponds to one training iteration's worth of subgraphs — completed
//! subgraphs stream to the sink between waves). Within a wave each hop is
//! one edge-centric MapReduce round:
//!
//! ```text
//! map    : scan edge chunks, probe the frontier inverted index,
//!          insert admitted neighbors into per-task TopK reservoirs
//! reduce : merge per-task partial maps (tree or flat topology)
//! assign : write merged reservoirs into each subgraph slot
//! ```

use crate::balance::BalanceTable;
use crate::cluster::costmodel::{WorkLedger, WorkUnits};
use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;
use crate::mapreduce::{flat_reduce, tree_reduce_with_fabric};
use crate::sampler::inverted::InvertedIndex;
use crate::sampler::reservoir::TopK;
use crate::sampler::Subgraph;
use crate::util::fxhash::FxHashMap;
use crate::util::pool::parallel_map;

use super::{EngineConfig, ReduceTopology};

/// In-progress subgraphs of one wave.
pub struct WaveSlots {
    /// Seed of each slot.
    pub seeds: Vec<NodeId>,
    /// Owning worker of each slot (from the balance table).
    pub worker_of: Vec<u32>,
    /// Sampled hop-1 neighbors per slot (filled by hop 1).
    pub hop1: Vec<Vec<NodeId>>,
    /// `hop2[slot][i]` = sampled neighbors of `hop1[slot][i]`.
    pub hop2: Vec<Vec<Vec<NodeId>>>,
}

impl WaveSlots {
    pub fn new(seeds: Vec<NodeId>, worker_of: Vec<u32>) -> Self {
        let n = seeds.len();
        assert_eq!(n, worker_of.len());
        Self { seeds, worker_of, hop1: vec![Vec::new(); n], hop2: vec![Vec::new(); n] }
    }

    /// Frontier entries for `hop` (1-based): (node, slot, position).
    pub fn frontier(&self, hop: u32) -> Vec<(NodeId, u32, u32)> {
        match hop {
            1 => self
                .seeds
                .iter()
                .enumerate()
                .map(|(slot, &s)| (s, slot as u32, 0))
                .collect(),
            2 => {
                let mut out = Vec::new();
                for (slot, h1) in self.hop1.iter().enumerate() {
                    for (i, &v) in h1.iter().enumerate() {
                        out.push((v, slot as u32, i as u32));
                    }
                }
                out
            }
            _ => panic!("2-hop engines only"),
        }
    }

    /// All distinct node ids this wave touches (seeds plus sampled hops):
    /// the generation-side hook for warming a feature cache or kicking
    /// off a wave-ahead gather before batches reach the trainer. This is
    /// a superset of what batch assembly reads — the batch layout
    /// additionally truncates each hop to the model's fanout
    /// ([`crate::featurestore::fetch::batch_ids`] applies that exactly).
    pub fn unique_nodes(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self
            .seeds
            .iter()
            .copied()
            .chain(self.hop1.iter().flatten().copied())
            .chain(self.hop2.iter().flatten().flatten().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Finalize into subgraphs, consuming the wave.
    pub fn into_subgraphs(self) -> impl Iterator<Item = (u32, Subgraph)> {
        self.seeds
            .into_iter()
            .zip(self.worker_of)
            .zip(self.hop1.into_iter().zip(self.hop2))
            .map(|((seed, worker), (hop1, hop2))| {
                (worker, Subgraph { seed, hop1, hop2 })
            })
    }
}

/// Reservoir map key: slot in the high half, frontier position low.
#[inline]
pub fn slot_key(slot: u32, pos: u32) -> u64 {
    ((slot as u64) << 32) | pos as u64
}

/// Partial (and final) reduction state of one hop round.
pub type ReservoirMap = FxHashMap<u64, TopK>;

/// Build the inverted index over a frontier.
pub fn build_index(frontier: &[(NodeId, u32, u32)]) -> InvertedIndex {
    let mut ix = InvertedIndex::with_capacity(frontier.len());
    for &(node, slot, pos) in frontier {
        ix.insert(node, slot, pos);
    }
    ix
}

/// One contiguous slice of a frontier node's adjacency list.
#[derive(Debug, Clone, Copy)]
pub struct ScanChunk {
    pub node: NodeId,
    pub lo: u32,
    pub hi: u32,
}

/// Split the frontier's adjacency into ~`num_tasks` edge-balanced scan
/// tasks. Hot nodes are split across tasks (`chunk_cap` edges per chunk) —
/// the essence of *edge-centric* parallelism: no single task is stuck with
/// a hub's entire neighbor list (contrast [`super::agl`]).
pub fn make_scan_tasks(
    g: &Csr,
    frontier_nodes: impl Iterator<Item = NodeId>,
    num_tasks: usize,
) -> Vec<Vec<ScanChunk>> {
    let mut chunks: Vec<ScanChunk> = Vec::new();
    let mut total_edges = 0u64;
    for v in frontier_nodes {
        let deg = g.degree(v);
        total_edges += deg as u64;
        if deg == 0 {
            continue;
        }
        chunks.push(ScanChunk { node: v, lo: 0, hi: deg });
    }
    if chunks.is_empty() {
        return Vec::new();
    }
    let num_tasks = num_tasks.max(1);
    let target = total_edges.div_ceil(num_tasks as u64).max(64);
    // Split chunks larger than the target so hubs spread across tasks.
    let mut split: Vec<ScanChunk> = Vec::with_capacity(chunks.len());
    for c in chunks {
        let deg = (c.hi - c.lo) as u64;
        if deg <= target {
            split.push(c);
        } else {
            let pieces = deg.div_ceil(target);
            let step = deg.div_ceil(pieces) as u32;
            let mut lo = c.lo;
            while lo < c.hi {
                let hi = (lo + step).min(c.hi);
                split.push(ScanChunk { node: c.node, lo, hi });
                lo = hi;
            }
        }
    }
    // First-fit pack into tasks of ~target edges.
    let mut tasks: Vec<Vec<ScanChunk>> = Vec::with_capacity(num_tasks);
    let mut cur: Vec<ScanChunk> = Vec::new();
    let mut cur_edges = 0u64;
    for c in split {
        cur_edges += (c.hi - c.lo) as u64;
        cur.push(c);
        if cur_edges >= target {
            tasks.push(std::mem::take(&mut cur));
            cur_edges = 0;
        }
    }
    if !cur.is_empty() {
        tasks.push(cur);
    }
    tasks
}

/// Scan one task's chunks, producing its partial reservoir map and the
/// number of edge-entries scanned (for the work ledger).
pub fn scan_task(
    g: &Csr,
    index: &InvertedIndex,
    task: &[ScanChunk],
    sample_seed: u64,
    hop: u32,
    k: usize,
    seeds: &[NodeId],
) -> (ReservoirMap, u64) {
    let mut map = ReservoirMap::default();
    let mut scanned = 0u64;
    for chunk in task {
        let neigh = &g.neighbors(chunk.node)[chunk.lo as usize..chunk.hi as usize];
        let entries = index.get(chunk.node);
        scanned += (neigh.len() * entries.len()) as u64;
        for &(slot, pos) in entries {
            let seed = seeds[slot as usize];
            // Hoist the loop-invariant half of the hash (§Perf): one
            // mix64 per edge instead of three.
            let base = crate::sampler::priority_base(sample_seed, hop, seed, chunk.node);
            let res = map
                .entry(slot_key(slot, pos))
                .or_insert_with(|| TopK::new(k));
            let mut threshold = res.threshold();
            for &nbr in neigh {
                let p = crate::sampler::priority_from_base(base, nbr);
                // Branchy fast-reject: skip the binary-search insert path
                // entirely for the overwhelming majority of candidates
                // once the reservoir is full.
                if p < threshold {
                    res.insert(p, nbr);
                    threshold = res.threshold();
                }
            }
        }
    }
    (map, scanned)
}

/// Record the reduce-phase work of merging `partials` under a topology.
///
/// Interpretation of the paper's two designs (§2 step 3, DESIGN.md §6):
///
/// * **Flat (GraphGen)** — workers send each subgraph's contributions
///   directly to its owning worker with no in-network aggregation ("all
///   workers communicate directly with a central aggregator [per
///   subgraph]"): a hot key's *entire* fan-in — every contribution from
///   every scan task — lands on one worker and is folded serially there.
/// * **Tree (GraphGen+)** — each subgraph's reservoirs are merged *on its
///   owning worker* (per the balance table), and a hot key's many
///   contributions are **pre-aggregated through the worker tree** before
///   reaching the owner ("each non-leaf worker partially processes and
///   aggregates its assigned subgraphs before passing the results to its
///   parent"). Reservoirs are top-k capped, so pre-aggregation bounds the
///   owner-side fan-in of a hot key at `arity` contributions of ≤ k
///   entries; the interior pre-aggregation work spreads evenly across the
///   tree's nodes. Consequently *both* of the paper's mechanisms show up
///   here: the mapping strategy determines the owner-work makespan, and
///   the tree flattens hot-key fan-in.
pub fn ledger_merge(
    ledger: &mut WorkLedger,
    phase: &str,
    partials: &[ReservoirMap],
    k: usize,
    reduce: super::ReduceTopology,
    worker_of: &[u32],
    workers: usize,
) {
    const BYTES_PER_ENTRY: u64 = 12;
    // Per-key contribution stats: (#partials containing it, total entries).
    let mut stats: FxHashMap<u64, (u32, u32)> = FxHashMap::default();
    for m in partials {
        for (&key, t) in m.iter() {
            let e = stats.entry(key).or_insert((0, 0));
            e.0 += 1;
            e.1 += t.len() as u32;
        }
    }
    match reduce {
        super::ReduceTopology::Flat => {
            // Direct-to-owner, no pre-aggregation: the owner absorbs the
            // full fan-in of each of its keys.
            let mut owner_work = vec![0u64; workers];
            let mut owner_msgs = vec![0u64; workers];
            for (&key, &(c, e)) in stats.iter() {
                let slot = (key >> 32) as usize;
                let owner = worker_of[slot] as usize % workers;
                owner_work[owner] += e as u64;
                owner_msgs[owner] += c as u64;
            }
            for (w, work) in owner_work.iter().enumerate() {
                ledger.charge(
                    phase,
                    w,
                    WorkUnits {
                        merge_entries: *work,
                        net_bytes: *work * BYTES_PER_ENTRY,
                        msgs: owner_msgs[w],
                        ..Default::default()
                    },
                );
            }
        }
        super::ReduceTopology::Tree { arity } => {
            let mut owner_work = vec![0u64; workers];
            let mut interior = 0u64;
            for (&key, &(c, e)) in stats.iter() {
                let slot = (key >> 32) as usize;
                let owner = worker_of[slot] as usize % workers;
                // Owner receives at most `arity` pre-aggregated
                // contributions of ≤ k entries each.
                let at_owner = (e as u64).min(c.min(arity as u32) as u64 * k as u64);
                owner_work[owner] += at_owner;
                interior += e as u64 - at_owner;
            }
            // Interior pre-aggregation parallelizes across tree nodes.
            let share = interior / workers as u64;
            for (w, work) in owner_work.iter().enumerate() {
                let moved = work + share;
                ledger.charge(
                    phase,
                    w,
                    WorkUnits {
                        merge_entries: moved,
                        net_bytes: moved * BYTES_PER_ENTRY,
                        msgs: arity as u64,
                        ..Default::default()
                    },
                );
            }
        }
    }
}

/// Serialized size of a partial map — drives reduce-phase fabric charges.
pub fn map_wire_bytes(m: &ReservoirMap) -> u64 {
    m.values().map(|t| 8 + 12 * t.len() as u64).sum()
}

/// Merge two reservoir maps (associative + commutative).
pub fn merge_maps(mut a: ReservoirMap, b: ReservoirMap) -> ReservoirMap {
    for (key, res) in b {
        match a.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().merge(&res),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(res);
            }
        }
    }
    a
}

/// Run one edge-centric hop round for `slots`, filling `hop1` or `hop2`.
///
/// Work is recorded on `ledger` per simulated worker / tree round so the
/// cost model can project cluster time (this testbed has a single core —
/// see [`crate::cluster::costmodel`]).
pub fn edge_centric_hop(
    g: &Csr,
    slots: &mut WaveSlots,
    hop: u32,
    cfg: &EngineConfig,
    fabric: &Fabric,
    ledger: &mut WorkLedger,
) {
    let k = cfg.fanout.fanouts[(hop - 1) as usize] as usize;
    let frontier = slots.frontier(hop);
    if frontier.is_empty() {
        return;
    }
    let index = build_index(&frontier);
    // Scan tasks play the role of the simulated workers' map tasks: use
    // a multiple of the cluster width so each worker gets several, and at
    // least a few per OS thread for stragglerless packing.
    let num_tasks = (cfg.workers * 4).max(cfg.threads * 4);
    let tasks = make_scan_tasks(g, index.iter().map(|(n, _)| n), num_tasks);
    // --- map phase (parallel) ---
    let scan_phase = format!("hop{hop}.scan");
    let results: Vec<(ReservoirMap, u64)> = parallel_map(&tasks, cfg.threads, |task| {
        scan_task(g, &index, task, cfg.sample_seed, hop, k, &slots.seeds)
    });
    let mut partials = Vec::with_capacity(results.len());
    for (t, (map, scanned)) in results.into_iter().enumerate() {
        ledger.charge(
            &scan_phase,
            t % cfg.workers,
            WorkUnits { scan_edge_entries: scanned, ..Default::default() },
        );
        partials.push(map);
    }
    // --- reduce phase (tree or flat) ---
    let merge_phase = format!("hop{hop}.merge");
    ledger_merge(ledger, &merge_phase, &partials, k, cfg.reduce, &slots.worker_of, cfg.workers);
    let size_of: &(dyn Fn(&ReservoirMap) -> u64 + Sync) = &map_wire_bytes;
    let merged = match cfg.reduce {
        ReduceTopology::Tree { arity } => {
            tree_reduce_with_fabric(partials, arity, merge_maps, Some((fabric, size_of)))
        }
        ReduceTopology::Flat => flat_reduce(partials, merge_maps, Some((fabric, &map_wire_bytes))),
    }
    .unwrap_or_default();
    // --- assignment phase: write reservoirs into slots; charge the edge
    // replication transfer reducer→owning worker ("append E to Graph(S)
    // on worker M[S]"). Per-worker net bytes expose mapping imbalance.
    let assign_phase = format!("hop{hop}.assign");
    for (key, res) in merged.iter() {
        let slot = (key >> 32) as usize;
        let dst = slots.worker_of[slot] as usize % cfg.workers;
        ledger.charge(
            &assign_phase,
            dst,
            WorkUnits {
                merge_entries: res.len() as u64,
                net_bytes: 8 + 12 * res.len() as u64,
                msgs: 1,
                ..Default::default()
            },
        );
    }
    assign_hop(slots, hop, merged, fabric, cfg.workers);
}

/// Write merged reservoirs into the wave's hop vectors.
pub fn assign_hop(slots: &mut WaveSlots, hop: u32, merged: ReservoirMap, fabric: &Fabric, workers: usize) {
    for (key, res) in merged {
        let slot = (key >> 32) as usize;
        let pos = (key & 0xffff_ffff) as usize;
        let dst = slots.worker_of[slot] as usize % workers;
        // The reducer that produced this reservoir hands it to the slot's
        // owning worker ("append E to Graph(S) on worker M[S]").
        let src = (key as usize) % workers;
        if src != dst {
            fabric.charge(src, dst, 8 + 12 * res.len() as u64);
        }
        match hop {
            1 => {
                debug_assert_eq!(pos, 0);
                slots.hop1[slot] = res.nodes().collect();
            }
            2 => {
                let h2 = &mut slots.hop2[slot];
                if h2.len() < slots.hop1[slot].len() {
                    h2.resize(slots.hop1[slot].len(), Vec::new());
                }
                h2[pos] = res.nodes().collect();
            }
            _ => unreachable!(),
        }
    }
    // Slots whose hop-1 nodes had no admitted hop-2 neighbors still need
    // correctly shaped hop2 groups.
    if hop == 2 {
        for (slot, h1) in slots.hop1.iter().enumerate() {
            slots.hop2[slot].resize(h1.len(), Vec::new());
        }
    }
}

/// Build the global balance table and slice it into waves.
pub fn plan_waves(
    seeds: &[NodeId],
    cfg: &EngineConfig,
) -> (BalanceTable, Vec<std::ops::Range<usize>>) {
    let table = BalanceTable::build(seeds, cfg.workers, cfg.mapping, cfg.sample_seed);
    let n = table.seeds.len();
    let mut waves = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + cfg.wave_size).min(n);
        waves.push(start..end);
        start = end;
    }
    (table, waves)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 64,
            fanout: FanoutSpec::new(vec![4, 3]),
            sample_seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn scan_tasks_cover_all_edges_once() {
        let g = generator::from_spec("star:n=512,hubs=1", 2).unwrap().csr();
        let frontier: Vec<NodeId> = (0..20).collect();
        let tasks = make_scan_tasks(&g, frontier.iter().copied(), 8);
        // Sum of chunk widths == sum of degrees; no overlap per node.
        let mut per_node: std::collections::HashMap<NodeId, Vec<(u32, u32)>> = Default::default();
        for t in &tasks {
            for c in t {
                per_node.entry(c.node).or_default().push((c.lo, c.hi));
            }
        }
        for v in frontier {
            let mut ranges = per_node.remove(&v).unwrap_or_default();
            ranges.sort_unstable();
            let mut covered = 0;
            for (lo, hi) in ranges {
                assert_eq!(lo, covered, "gap/overlap at node {v}");
                covered = hi;
            }
            assert_eq!(covered, g.degree(v), "node {v} not fully covered");
        }
        // The hub (node 0, degree ~511) must be split across chunks.
        let hub_chunks = tasks.iter().flatten().filter(|c| c.node == 0).count();
        assert!(hub_chunks > 1, "hub not split: {hub_chunks} chunk(s)");
    }

    #[test]
    fn hop_round_fills_slots_within_fanout() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 3).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..64).collect();
        let worker_of: Vec<u32> = seeds.iter().map(|&s| s % 4).collect();
        let mut slots = WaveSlots::new(seeds, worker_of);
        let mut ledger = WorkLedger::new(cfg.workers);
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger);
        edge_centric_hop(&g, &mut slots, 2, &cfg, &fabric, &mut ledger);
        for (slot, h1) in slots.hop1.iter().enumerate() {
            assert!(h1.len() <= 4);
            // hop1 ⊆ neighbors(seed)
            for v in h1 {
                assert!(g.neighbors(slots.seeds[slot]).contains(v));
            }
            assert_eq!(slots.hop2[slot].len(), h1.len());
            for (i, h2) in slots.hop2[slot].iter().enumerate() {
                assert!(h2.len() <= 3);
                for v in h2 {
                    assert!(g.neighbors(h1[i]).contains(v));
                }
            }
        }
    }

    #[test]
    fn hop_round_is_thread_count_invariant() {
        let g = generator::from_spec("rmat:n=512,e=4096", 5).unwrap().csr();
        let run = |threads: usize| {
            let mut c = cfg();
            c.threads = threads;
            let fabric = Fabric::new(c.workers);
            let seeds: Vec<NodeId> = (0..32).collect();
            let mut slots = WaveSlots::new(seeds.clone(), vec![0; 32]);
            let mut ledger = WorkLedger::new(c.workers);
            edge_centric_hop(&g, &mut slots, 1, &c, &fabric, &mut ledger);
            edge_centric_hop(&g, &mut slots, 2, &c, &fabric, &mut ledger);
            (slots.hop1, slots.hop2)
        };
        assert_eq!(run(1), run(8));
    }

    #[test]
    fn full_fanout_when_degree_allows() {
        // Complete-ish graph: every seed should get exactly f1 neighbors.
        let g = generator::from_spec("er:n=64,e=4000", 1).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..16).collect();
        let mut slots = WaveSlots::new(seeds, vec![0; 16]);
        let mut ledger = WorkLedger::new(cfg.workers);
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger);
        for (slot, h1) in slots.hop1.iter().enumerate() {
            let deg = g.degree(slots.seeds[slot]) as usize;
            assert_eq!(h1.len(), deg.min(4), "slot {slot}");
        }
    }

    #[test]
    fn unique_nodes_covers_all_hops_once() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 3).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..32).collect();
        let mut slots = WaveSlots::new(seeds.clone(), vec![0; 32]);
        let mut ledger = WorkLedger::new(cfg.workers);
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger);
        edge_centric_hop(&g, &mut slots, 2, &cfg, &fabric, &mut ledger);
        let ids = slots.unique_nodes();
        // Sorted, deduplicated, and covering every referenced node.
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        for &s in &slots.seeds {
            assert!(ids.binary_search(&s).is_ok());
        }
        for (slot, h1) in slots.hop1.iter().enumerate() {
            for &v in h1 {
                assert!(ids.binary_search(&v).is_ok());
            }
            for h2 in &slots.hop2[slot] {
                for &w in h2 {
                    assert!(ids.binary_search(&w).is_ok());
                }
            }
        }
    }

    #[test]
    fn plan_waves_slices_cover_table() {
        let seeds: Vec<NodeId> = (0..1000).collect();
        let (table, waves) = plan_waves(&seeds, &cfg());
        let covered: usize = waves.iter().map(|r| r.len()).sum();
        assert_eq!(covered, table.seeds.len());
        assert!(waves.iter().all(|r| r.len() <= 64));
    }
}
