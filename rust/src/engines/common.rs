//! Shared machinery for the generation engines: wave state, frontier
//! indexing, edge-centric scan tasks and partial-result merging.
//!
//! Terminology (paper §2): generation proceeds in *waves* of seeds (a wave
//! corresponds to one training iteration's worth of subgraphs — completed
//! subgraphs stream to the sink between waves). Within a wave each hop is
//! one edge-centric MapReduce round:
//!
//! ```text
//! map    : scan edge chunks, probe the frontier inverted index,
//!          insert admitted neighbors into per-task top-k reservoirs
//! reduce : merge per-task partial frames (tree or flat topology)
//! assign : write merged reservoirs into each subgraph slot
//! ```
//!
//! ## Dense reservoir frames & the scratch arena (PR 2)
//!
//! `slot_key(slot, pos)` enumerates a *known* frontier, so partial results
//! no longer live in per-task `FxHashMap<u64, TopK>` maps: a [`Frame`] is
//! a pair of parallel vecs — frontier-entry **ordinals** (sorted,
//! duplicate-free) and their [`TopK`] reservoirs — built from a reusable
//! [`FrameArena`]. Scan tasks fill frames, and the reduce phase merges two
//! frames with one linear zip over their ordinal lists instead of a
//! hashmap traversal; `TopK` buffers are `reset` and reused, never
//! reallocated. All per-hop working state (frontier vec, slot offsets,
//! inverted index, scan chunks, ledger stats, frames) lives in a per-run
//! [`ScratchArena`], so steady-state hop rounds perform **zero reservoir
//! heap allocations and zero thread spawns** (scan tasks run on the
//! persistent [`WorkPool`]) — the counters in
//! [`ScratchStats`](crate::engines::GenReport) prove it per run.

use crate::balance::BalanceTable;
use crate::cluster::costmodel::{WorkLedger, WorkUnits};
use crate::cluster::Fabric;
use crate::graph::csr::Csr;
use crate::graph::NodeId;
use crate::mapreduce::{flat_reduce, tree_reduce_with_fabric};
use crate::sampler::inverted::InvertedIndex;
use crate::sampler::reservoir::TopK;
use crate::sampler::Subgraph;
use crate::util::timer::PhaseTimer;
use crate::util::workpool::WorkPool;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{EngineConfig, ReduceTopology, SubgraphSink};

/// In-progress subgraphs of one wave. Seeds and worker assignments are
/// borrowed straight from the balance table — no per-wave copies.
pub struct WaveSlots<'a> {
    /// Seed of each slot.
    pub seeds: &'a [NodeId],
    /// Owning worker of each slot (from the balance table).
    pub worker_of: &'a [u32],
    /// Sampled hop-1 neighbors per slot (filled by hop 1).
    pub hop1: Vec<Vec<NodeId>>,
    /// `hop2[slot][i]` = sampled neighbors of `hop1[slot][i]`.
    pub hop2: Vec<Vec<Vec<NodeId>>>,
}

impl<'a> WaveSlots<'a> {
    pub fn new(seeds: &'a [NodeId], worker_of: &'a [u32]) -> Self {
        let n = seeds.len();
        assert_eq!(n, worker_of.len());
        Self { seeds, worker_of, hop1: vec![Vec::new(); n], hop2: vec![Vec::new(); n] }
    }

    /// Fill `out` with the frontier entries for `hop` (1-based):
    /// `(node, slot, position)`, ordinal = index in `out`. Also fills
    /// `offsets` with each slot's first ordinal, so
    /// `ordinal = offsets[slot] + position`. Both buffers are reused.
    pub fn fill_frontier(
        &self,
        hop: u32,
        out: &mut Vec<(NodeId, u32, u32)>,
        offsets: &mut Vec<u32>,
    ) {
        self.fill_frontier_par(hop, out, offsets, 1);
    }

    /// [`fill_frontier`](Self::fill_frontier) with a thread budget: the
    /// hop-2 slot offsets come from a parallel exclusive scan over the
    /// hop-1 lengths and the entries are scattered to their (positional,
    /// disjoint) ranges in parallel — byte-identical to the serial walk
    /// at every thread count.
    pub fn fill_frontier_par(
        &self,
        hop: u32,
        out: &mut Vec<(NodeId, u32, u32)>,
        offsets: &mut Vec<u32>,
        threads: usize,
    ) {
        out.clear();
        offsets.clear();
        match hop {
            1 => {
                for (slot, &s) in self.seeds.iter().enumerate() {
                    offsets.push(slot as u32);
                    out.push((s, slot as u32, 0));
                }
            }
            2 => {
                offsets.extend(self.hop1.iter().map(|h1| h1.len() as u32));
                let total = crate::util::parallel_scan::exclusive_scan(
                    WorkPool::global(),
                    threads,
                    offsets,
                );
                out.resize(total as usize, (0, 0, 0));
                let base = crate::util::workpool::RawParts(out.as_mut_ptr());
                let base = &base;
                let offs: &[u32] = offsets;
                WorkPool::global().run_labeled(
                    self.hop1.len(),
                    threads,
                    64,
                    "frontier.fill",
                    |slot| {
                        let h1 = &self.hop1[slot];
                        // SAFETY: slot ranges [offsets[slot],
                        // offsets[slot] + len) partition `out` (they are
                        // the exclusive scan of the lengths) and `out`
                        // outlives the blocking run.
                        let dst = unsafe {
                            std::slice::from_raw_parts_mut(
                                base.0.add(offs[slot] as usize),
                                h1.len(),
                            )
                        };
                        for (i, (&v, d)) in h1.iter().zip(dst.iter_mut()).enumerate() {
                            *d = (v, slot as u32, i as u32);
                        }
                    },
                );
            }
            _ => panic!("2-hop engines only"),
        }
    }

    /// All distinct node ids this wave touches (seeds plus sampled hops):
    /// the generation-side hook for warming a feature cache or kicking
    /// off a wave-ahead gather before batches reach the trainer. This is
    /// a superset of what batch assembly reads — the batch layout
    /// additionally truncates each hop to the model's fanout
    /// ([`crate::featurestore::fetch::batch_ids`] applies that exactly).
    pub fn unique_nodes(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self
            .seeds
            .iter()
            .copied()
            .chain(self.hop1.iter().flatten().copied())
            .chain(self.hop2.iter().flatten().flatten().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Finalize into subgraphs, consuming the wave.
    pub fn into_subgraphs(self) -> impl Iterator<Item = (u32, Subgraph)> + 'a {
        self.seeds
            .iter()
            .copied()
            .zip(self.worker_of.iter().copied())
            .zip(self.hop1.into_iter().zip(self.hop2))
            .map(|((seed, worker), (hop1, hop2))| {
                (worker, Subgraph { seed, hop1, hop2 })
            })
    }
}

/// Reservoir wire key: slot in the high half, frontier position low.
/// (Frames key on frontier ordinals; this key survives as the simulated
/// wire/routing identity so fabric charges match the previous layout.)
#[inline]
pub fn slot_key(slot: u32, pos: u32) -> u64 {
    ((slot as u64) << 32) | pos as u64
}

// ---------------------------------------------------------------------------
// Dense reservoir frames
// ---------------------------------------------------------------------------

/// Partial (and final) reduction state of one hop round: reservoirs for a
/// sorted, duplicate-free set of frontier-entry ordinals. The `toks` vec
/// may be longer than `ords` — the excess are pooled [`TopK`] buffers kept
/// warm for reuse; only the first `ords.len()` entries are live.
#[derive(Debug, Default)]
pub struct Frame {
    ords: Vec<u32>,
    toks: Vec<TopK>,
}

impl Frame {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.ords.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.ords.is_empty()
    }

    /// Drop the live entries (buffers are retained for reuse).
    pub fn clear(&mut self) {
        self.ords.clear();
    }

    /// Live `(ordinal, reservoir)` pairs in ascending ordinal order.
    #[inline]
    pub fn entries(&self) -> impl Iterator<Item = (u32, &TopK)> {
        self.ords.iter().copied().zip(self.toks.iter())
    }

    /// Serialized size — drives reduce-phase fabric charges (same formula
    /// as the old hashmap layout: 8 key bytes + 12 per entry).
    pub fn wire_bytes(&self) -> u64 {
        self.entries().map(|(_, t)| 8 + 12 * t.len() as u64).sum()
    }

    /// Prepare for a scan: collect the (unsorted, possibly duplicated)
    /// ordinals this task can touch, sort + dedup them, and arm one
    /// reservoir of capacity `k` per ordinal — reusing pooled buffers.
    pub fn prepare(&mut self, k: usize, ords: impl Iterator<Item = u32>) {
        self.ords.clear();
        self.ords.extend(ords);
        self.ords.sort_unstable();
        self.ords.dedup();
        for i in 0..self.ords.len() {
            if i < self.toks.len() {
                self.toks[i].reset(k);
            } else {
                self.toks.push(TopK::new(k));
            }
        }
    }

    /// The reservoir for a prepared ordinal (panics if not prepared).
    #[inline]
    pub fn tok_for(&mut self, ord: u32) -> &mut TopK {
        let pos = self.ords.binary_search(&ord).expect("ordinal not prepared");
        &mut self.toks[pos]
    }

    /// Direct positional access (for dense/identity frames where the
    /// position is known — skips the binary search of [`tok_for`]).
    #[inline]
    pub fn tok_at(&mut self, pos: usize) -> &mut TopK {
        debug_assert!(pos < self.ords.len());
        &mut self.toks[pos]
    }

    /// Append a fresh empty reservoir for `ord` (must ascend) and return
    /// it; reuses a pooled buffer when available.
    pub fn push_new(&mut self, ord: u32, k: usize) -> &mut TopK {
        debug_assert!(self.ords.last().map_or(true, |&l| l < ord), "ordinals must ascend");
        let idx = self.ords.len();
        self.ords.push(ord);
        if idx < self.toks.len() {
            self.toks[idx].reset(k);
        } else {
            self.toks.push(TopK::new(k));
        }
        &mut self.toks[idx]
    }

    /// Merge two frames into `out` with one linear zip over their ordinal
    /// lists — the dense replacement for hashmap-entry merging.
    pub fn merge_from(a: &Frame, b: &Frame, out: &mut Frame) {
        out.clear();
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.ords.len() && j < b.ords.len() {
            let (oa, ob) = (a.ords[i], b.ords[j]);
            if oa < ob {
                out.push_new(oa, a.toks[i].k()).copy_from(&a.toks[i]);
                i += 1;
            } else if ob < oa {
                out.push_new(ob, b.toks[j].k()).copy_from(&b.toks[j]);
                j += 1;
            } else {
                out.push_new(oa, a.toks[i].k()).assign_merged(&a.toks[i], &b.toks[j]);
                i += 1;
                j += 1;
            }
        }
        while i < a.ords.len() {
            out.push_new(a.ords[i], a.toks[i].k()).copy_from(&a.toks[i]);
            i += 1;
        }
        while j < b.ords.len() {
            out.push_new(b.ords[j], b.toks[j].k()).copy_from(&b.toks[j]);
            j += 1;
        }
    }
}

/// Freelist shard count: a small power of two comfortably above the
/// thread counts this testbed runs, so each claimant usually owns a shard.
const FRAME_SHARDS: usize = 16;

/// Pool of reusable [`Frame`]s shared by the scan tasks and the reduce
/// tree of one engine run. The freelist is sharded by
/// [`worker_slot`](crate::util::workpool::worker_slot): each thread pushes
/// and pops its own shard, so the steady-state acquire path is an
/// uncontended lock — the cross-thread mutex pop is gone. A thread whose
/// shard is empty steals from the others before allocating, which keeps
/// the `steady_frame_allocs` zero-allocation invariant intact.
#[derive(Debug)]
pub struct FrameArena {
    shards: Vec<Mutex<Vec<Frame>>>,
    /// Shard of the most recent release — where a stealing acquirer looks
    /// first, so releases that concentrate on one thread (e.g. the
    /// submitter folding a flat reduce) don't force full shard walks.
    last_release: AtomicUsize,
    allocated: AtomicU64,
    reused: AtomicU64,
    steady_allocs: AtomicU64,
    warm: AtomicBool,
}

impl Default for FrameArena {
    fn default() -> Self {
        Self {
            shards: (0..FRAME_SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
            last_release: AtomicUsize::new(0),
            allocated: AtomicU64::new(0),
            reused: AtomicU64::new(0),
            steady_allocs: AtomicU64::new(0),
            warm: AtomicBool::new(false),
        }
    }
}

impl FrameArena {
    #[inline]
    fn home(&self) -> usize {
        crate::util::workpool::worker_slot() % self.shards.len()
    }

    /// Take a cleared frame: own shard first, then the last-release shard,
    /// then the remaining shards; allocate last.
    pub fn acquire(&self) -> Frame {
        let n = self.shards.len();
        let home = self.home();
        let hint = self.last_release.load(Ordering::Relaxed) % n;
        let probe = |s: usize| -> Option<Frame> { self.shards[s].lock().unwrap().pop() };
        let mut found = probe(home);
        if found.is_none() && hint != home {
            found = probe(hint);
        }
        if found.is_none() {
            for i in 1..n {
                let s = (home + i) % n;
                if s == hint {
                    continue;
                }
                found = probe(s);
                if found.is_some() {
                    break;
                }
            }
        }
        if let Some(mut f) = found {
            f.clear();
            self.reused.fetch_add(1, Ordering::Relaxed);
            return f;
        }
        self.allocated.fetch_add(1, Ordering::Relaxed);
        if self.warm.load(Ordering::Relaxed) {
            self.steady_allocs.fetch_add(1, Ordering::Relaxed);
        }
        Frame::new()
    }

    /// Return a frame (and its reservoir buffers) to the caller's shard.
    pub fn release(&self, f: Frame) {
        let s = self.home();
        self.shards[s].lock().unwrap().push(f);
        self.last_release.store(s, Ordering::Relaxed);
    }

    /// Declare warm-up over: later `acquire` misses count as steady-state
    /// allocations. `slack` extra frames are stocked (spread across the
    /// shards) to absorb ±1 jitter in the per-wave task count. Stocking
    /// happens before the flag flips so a racing `acquire` can never see
    /// warm-but-unstocked.
    pub fn mark_warm(&self, slack: usize) {
        if self.warm.load(Ordering::Relaxed) {
            return;
        }
        for i in 0..slack {
            self.shards[i % self.shards.len()].lock().unwrap().push(Frame::new());
            self.allocated.fetch_add(1, Ordering::Relaxed);
        }
        self.warm.store(true, Ordering::Relaxed);
    }
}

/// Allocation/reuse counters of one engine run (exposed in
/// [`GenReport`](super::GenReport) — the acceptance hook proving that
/// steady-state hop rounds reuse the pool and arena instead of
/// re-spawning/re-allocating).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScratchStats {
    /// Frames allocated fresh (warm-up plus jitter slack).
    pub frames_allocated: u64,
    /// Frame acquisitions served from the pool.
    pub frames_reused: u64,
    /// Fresh allocations after the first wave — 0 in steady state.
    pub steady_frame_allocs: u64,
    /// OS threads the persistent work pool spawned during this run — 0
    /// once the process-wide pool is warm.
    pub pool_threads_spawned: u64,
    /// Scan-task count the adaptive sizer chose for the last hop-1/hop-2
    /// round (0 = that hop never ran a sized round).
    pub scan_tasks: [u64; 2],
    /// EWMA per-task cost estimate per hop, nanoseconds.
    pub task_ewma_ns: [u64; 2],
}

/// Adaptive scan-task sizing: derives the number of edge-balanced scan
/// tasks for the next round of a hop from the measured per-task wall time
/// of that hop's earlier rounds (EWMA), instead of the fixed
/// `4×(workers|threads)` multiple. Small waves stop over-splitting (task
/// dispatch overhead dominates sub-~100 µs tasks) while the fixed multiple
/// remains the **ceiling**, so a warm [`FrameArena`]'s high-water mark is
/// never exceeded and the steady-state zero-allocation invariant holds.
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskSizer {
    /// EWMA of one task's estimated CPU time, ns.
    ewma_task_ns: f64,
    /// Tasks used by the last recorded round.
    last_tasks: u64,
    rounds: u64,
}

impl TaskSizer {
    const ALPHA: f64 = 0.4;

    /// Target per-task CPU time in ns: long enough to amortize
    /// claim/dispatch overhead, short enough to pack threads without
    /// straggler tails. Default 120 µs; overridable once per process via
    /// `GG_TASK_TARGET_US` (the E2 sweep validates the default across
    /// cluster scales).
    pub fn target_task_ns() -> f64 {
        static CACHED: OnceLock<f64> = OnceLock::new();
        *CACHED.get_or_init(|| {
            std::env::var("GG_TASK_TARGET_US")
                .ok()
                .and_then(|v| v.parse::<f64>().ok())
                .filter(|us| *us > 0.0)
                .map(|us| us * 1_000.0)
                .unwrap_or(120_000.0)
        })
    }

    /// Tasks to use for the next round of this hop.
    pub fn num_tasks(&self, cfg: &EngineConfig) -> usize {
        let base = (cfg.workers * 4).max(cfg.threads * 4);
        if self.rounds == 0 || self.ewma_task_ns <= 0.0 {
            return base;
        }
        // Re-split the last round's estimated total cost into target-sized
        // tasks; never drop below one task per worker/thread and never
        // rise above the warm-up multiple (frame-arena high-water mark).
        // The count is rounded up to a power of two so the choice is
        // insensitive to sub-2× timing noise — runs on the same workload
        // settle on the same task count, keeping the task-count-dependent
        // parts of the simulated accounting (merge fan-in, reduce-tree
        // fabric bytes) stable in practice.
        let total_ns = self.ewma_task_ns * self.last_tasks as f64;
        let want = (total_ns / Self::target_task_ns()).ceil() as usize;
        want.next_power_of_two().clamp(cfg.workers.max(cfg.threads), base)
    }

    /// Record a finished round: `tasks` ran for `cpu` in total (the sum of
    /// per-task times measured *inside* the job, so pool queueing and
    /// other jobs' runtime never pollute the estimate).
    pub fn record(&mut self, tasks: usize, cpu: std::time::Duration) {
        if tasks == 0 {
            return;
        }
        let per = cpu.as_nanos() as f64 / tasks as f64;
        self.ewma_task_ns = if self.rounds == 0 {
            per
        } else {
            Self::ALPHA * per + (1.0 - Self::ALPHA) * self.ewma_task_ns
        };
        self.last_tasks = tasks as u64;
        self.rounds += 1;
    }

    /// `(last task count, EWMA per-task ns)` for reports.
    pub fn snapshot(&self) -> (u64, u64) {
        (self.last_tasks, self.ewma_task_ns as u64)
    }
}

thread_local! {
    /// Start stamp of the claim chunk the current thread is scanning
    /// (see [`ChunkClock`]).
    static CHUNK_T0: std::cell::Cell<Option<Instant>> = const { std::cell::Cell::new(None) };
}

/// Claim-chunk-granular timing for per-item `map_collect` engines (AGL's
/// per-node tasks, SQL's per-chunk materialization): the work pool claims
/// `chunk`-strided index ranges and each range runs consecutively on one
/// thread, so stamping a thread-local start on a chunk's first index and
/// reading it on the chunk's last costs **two clock reads per claimed
/// chunk** instead of two per item — the same granularity the
/// edge-centric engines get from their per-task timing. Feeds
/// [`TaskSizer::record`] through the per-index result slots.
#[derive(Debug, Clone, Copy)]
pub struct ChunkClock {
    chunk: usize,
    n: usize,
}

impl ChunkClock {
    pub fn new(chunk: usize, n: usize) -> Self {
        Self { chunk: chunk.max(1), n }
    }

    /// Call at the top of the per-index closure.
    #[inline]
    pub fn start(&self, i: usize) {
        if i % self.chunk == 0 {
            CHUNK_T0.with(|t| t.set(Some(Instant::now())));
        }
    }

    /// Call at the end of the per-index closure: returns the chunk's
    /// elapsed time on its final index, `Duration::ZERO` otherwise.
    #[inline]
    pub fn stop(&self, i: usize) -> Duration {
        if i % self.chunk == self.chunk - 1 || i + 1 == self.n {
            CHUNK_T0.with(|t| t.take()).map_or(Duration::ZERO, |t0| t0.elapsed())
        } else {
            Duration::ZERO
        }
    }
}

/// Per-run scratch state threaded through every hop round: all buffers
/// are reused across hops and waves.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Current hop's frontier entries `(node, slot, position)`.
    pub frontier: Vec<(NodeId, u32, u32)>,
    /// `ordinal = offsets[slot] + position` for the current frontier.
    pub offsets: Vec<u32>,
    /// Inverted index over the current frontier (rebuilt in place).
    pub index: InvertedIndex,
    /// Flat scan-chunk storage; tasks are ranges into it.
    pub chunks: Vec<ScanChunk>,
    /// Scan tasks as `(lo, hi)` ranges into `chunks`.
    pub tasks: Vec<(u32, u32)>,
    /// Per-ordinal `(contributing tasks, total entries)` ledger stats.
    pub ord_stats: Vec<(u32, u32)>,
    /// Sorted frontier-node scratch (node-centric + SQL engines).
    pub nodes: Vec<NodeId>,
    /// Reservoir frame pool.
    pub frames: FrameArena,
    /// Adaptive scan-task sizers, one per hop (edge-centric engines).
    pub sizers: [TaskSizer; 2],
}

impl ScratchArena {
    /// Called by engines once the first wave completes: warm-up is over.
    /// The slack absorbs bounded wave-to-wave jitter — ±1-2 scan tasks
    /// from edge-count rounding, plus one transient output frame per
    /// in-flight parallel merge — so steady-state waves never miss.
    pub fn mark_warm(&self) {
        self.frames.mark_warm(16);
    }

    /// Snapshot the run's reuse counters.
    pub fn stats(&self, pool_threads_spawned: u64) -> ScratchStats {
        let (t1, e1) = self.sizers[0].snapshot();
        let (t2, e2) = self.sizers[1].snapshot();
        ScratchStats {
            frames_allocated: self.frames.allocated.load(Ordering::Relaxed),
            frames_reused: self.frames.reused.load(Ordering::Relaxed),
            steady_frame_allocs: self.frames.steady_allocs.load(Ordering::Relaxed),
            pool_threads_spawned,
            scan_tasks: [t1, t2],
            task_ewma_ns: [e1, e2],
        }
    }
}

// ---------------------------------------------------------------------------
// Scan tasks
// ---------------------------------------------------------------------------

/// One contiguous slice of a frontier node's adjacency list.
#[derive(Debug, Clone, Copy)]
pub struct ScanChunk {
    pub node: NodeId,
    pub lo: u32,
    pub hi: u32,
}

/// Split the frontier's adjacency into ~`num_tasks` edge-balanced scan
/// tasks, written into the reusable `chunks`/`tasks` buffers (tasks are
/// `(lo, hi)` ranges over `chunks`). Hot nodes are split across tasks —
/// the essence of *edge-centric* parallelism: no single task is stuck
/// with a hub's entire neighbor list (contrast [`super::agl`]).
pub fn fill_scan_tasks(
    g: &Csr,
    nodes: &[NodeId],
    num_tasks: usize,
    chunks: &mut Vec<ScanChunk>,
    tasks: &mut Vec<(u32, u32)>,
) {
    chunks.clear();
    tasks.clear();
    let mut total_edges = 0u64;
    for &v in nodes {
        total_edges += g.degree(v) as u64;
    }
    if total_edges == 0 {
        return;
    }
    let num_tasks = num_tasks.max(1);
    let target = total_edges.div_ceil(num_tasks as u64).max(64);
    let mut task_start = 0u32;
    let mut cur_edges = 0u64;
    let mut close_if_full =
        |chunks: &mut Vec<ScanChunk>, tasks: &mut Vec<(u32, u32)>, cur_edges: &mut u64| {
            if *cur_edges >= target {
                tasks.push((task_start, chunks.len() as u32));
                task_start = chunks.len() as u32;
                *cur_edges = 0;
            }
        };
    for &v in nodes {
        let deg = g.degree(v);
        if deg == 0 {
            continue;
        }
        if deg as u64 <= target {
            chunks.push(ScanChunk { node: v, lo: 0, hi: deg });
            cur_edges += deg as u64;
            close_if_full(chunks, tasks, &mut cur_edges);
        } else {
            // Split hubs into ≤target pieces so they spread across tasks.
            let pieces = (deg as u64).div_ceil(target);
            let step = (deg as u64).div_ceil(pieces) as u32;
            let mut lo = 0u32;
            while lo < deg {
                let hi = (lo + step).min(deg);
                chunks.push(ScanChunk { node: v, lo, hi });
                cur_edges += (hi - lo) as u64;
                close_if_full(chunks, tasks, &mut cur_edges);
                lo = hi;
            }
        }
    }
    if task_start < chunks.len() as u32 {
        tasks.push((task_start, chunks.len() as u32));
    }
}

/// Scan one task's chunks into its reservoir `frame`, returning the
/// number of edge-entries scanned (for the work ledger).
pub fn scan_task(
    g: &Csr,
    index: &InvertedIndex,
    task: &[ScanChunk],
    sample_seed: u64,
    hop: u32,
    k: usize,
    seeds: &[NodeId],
    frame: &mut Frame,
) -> u64 {
    frame.prepare(
        k,
        task.iter().flat_map(|c| index.get(c.node).iter().map(|&(_, ord)| ord)),
    );
    let mut scanned = 0u64;
    for chunk in task {
        // `neighbors_ref` pins the cold page when the graph is tiered
        // (faults charge `tier.fault`; usually pre-warmed a wave ahead
        // by the speculative hop's prefetch) and borrows when resident.
        let run = g.neighbors_ref(chunk.node);
        let neigh = &run[chunk.lo as usize..chunk.hi as usize];
        let entries = index.get(chunk.node);
        scanned += (neigh.len() * entries.len()) as u64;
        for &(slot, ord) in entries {
            let seed = seeds[slot as usize];
            // Hoist the loop-invariant half of the hash (§Perf): one
            // mix64 per edge instead of three.
            let base = crate::sampler::priority_base(sample_seed, hop, seed, chunk.node);
            let res = frame.tok_for(ord);
            let mut threshold = res.threshold();
            for &nbr in neigh {
                let p = crate::sampler::priority_from_base(base, nbr);
                // Branchy fast-reject: skip the binary-search insert path
                // entirely for the overwhelming majority of candidates
                // once the reservoir is full.
                if p < threshold {
                    res.insert(p, nbr);
                    threshold = res.threshold();
                }
            }
        }
    }
    scanned
}

/// Record the reduce-phase work of merging `partials` under a topology.
///
/// Interpretation of the paper's two designs (§2 step 3, DESIGN.md §6):
///
/// * **Flat (GraphGen)** — workers send each subgraph's contributions
///   directly to its owning worker with no in-network aggregation ("all
///   workers communicate directly with a central aggregator [per
///   subgraph]"): a hot key's *entire* fan-in — every contribution from
///   every scan task — lands on one worker and is folded serially there.
/// * **Tree (GraphGen+)** — each subgraph's reservoirs are merged *on its
///   owning worker* (per the balance table), and a hot key's many
///   contributions are **pre-aggregated through the worker tree** before
///   reaching the owner ("each non-leaf worker partially processes and
///   aggregates its assigned subgraphs before passing the results to its
///   parent"). Reservoirs are top-k capped, so pre-aggregation bounds the
///   owner-side fan-in of a hot key at `arity` contributions of ≤ k
///   entries; the interior pre-aggregation work spreads evenly across the
///   tree's nodes. Consequently *both* of the paper's mechanisms show up
///   here: the mapping strategy determines the owner-work makespan, and
///   the tree flattens hot-key fan-in.
///
/// Per-key contribution stats accumulate into the dense `ord_stats`
/// scratch vec (`ordinal → (#tasks, total entries)`) — no hashmap.
#[allow(clippy::too_many_arguments)]
pub fn ledger_merge(
    ledger: &mut WorkLedger,
    phase: &str,
    partials: &[Frame],
    frontier: &[(NodeId, u32, u32)],
    ord_stats: &mut Vec<(u32, u32)>,
    k: usize,
    reduce: super::ReduceTopology,
    worker_of: &[u32],
    workers: usize,
) {
    const BYTES_PER_ENTRY: u64 = 12;
    ord_stats.clear();
    ord_stats.resize(frontier.len(), (0, 0));
    for f in partials {
        for (ord, t) in f.entries() {
            let e = &mut ord_stats[ord as usize];
            e.0 += 1;
            e.1 += t.len() as u32;
        }
    }
    match reduce {
        super::ReduceTopology::Flat => {
            // Direct-to-owner, no pre-aggregation: the owner absorbs the
            // full fan-in of each of its keys.
            let mut owner_work = vec![0u64; workers];
            let mut owner_msgs = vec![0u64; workers];
            for (ord, &(c, e)) in ord_stats.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let slot = frontier[ord].1 as usize;
                let owner = worker_of[slot] as usize % workers;
                owner_work[owner] += e as u64;
                owner_msgs[owner] += c as u64;
            }
            for (w, work) in owner_work.iter().enumerate() {
                ledger.charge(
                    phase,
                    w,
                    WorkUnits {
                        merge_entries: *work,
                        net_bytes: *work * BYTES_PER_ENTRY,
                        msgs: owner_msgs[w],
                        ..Default::default()
                    },
                );
            }
        }
        super::ReduceTopology::Tree { arity } => {
            let mut owner_work = vec![0u64; workers];
            let mut interior = 0u64;
            for (ord, &(c, e)) in ord_stats.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let slot = frontier[ord].1 as usize;
                let owner = worker_of[slot] as usize % workers;
                // Owner receives at most `arity` pre-aggregated
                // contributions of ≤ k entries each.
                let at_owner = (e as u64).min(c.min(arity as u32) as u64 * k as u64);
                owner_work[owner] += at_owner;
                interior += e as u64 - at_owner;
            }
            // Interior pre-aggregation parallelizes across tree nodes.
            let share = interior / workers as u64;
            for (w, work) in owner_work.iter().enumerate() {
                let moved = work + share;
                ledger.charge(
                    phase,
                    w,
                    WorkUnits {
                        merge_entries: moved,
                        net_bytes: moved * BYTES_PER_ENTRY,
                        msgs: arity as u64,
                        ..Default::default()
                    },
                );
            }
        }
    }
}

/// Run one edge-centric hop round for `slots`, filling `hop1` or `hop2`.
///
/// Work is recorded on `ledger` per simulated worker / tree round so the
/// cost model can project cluster time (this testbed has a single core —
/// see [`crate::cluster::costmodel`]). Scan tasks run on the persistent
/// [`WorkPool`]; all transient state draws from `scratch`.
pub fn edge_centric_hop(
    g: &Csr,
    slots: &mut WaveSlots<'_>,
    hop: u32,
    cfg: &EngineConfig,
    fabric: &Fabric,
    ledger: &mut WorkLedger,
    scratch: &mut ScratchArena,
) {
    let k = cfg.fanout.fanouts[(hop - 1) as usize] as usize;
    slots.fill_frontier_par(hop, &mut scratch.frontier, &mut scratch.offsets, cfg.threads);
    if scratch.frontier.is_empty() {
        return;
    }
    scratch.index.rebuild_par(&scratch.frontier, cfg.threads);
    // Scan tasks play the role of the simulated workers' map tasks. Their
    // count is chosen by the per-hop adaptive sizer: warm-up rounds use a
    // multiple of the cluster width / thread count, later rounds re-split
    // the measured cost into target-sized tasks (never above the warm-up
    // multiple — the frame arena's high-water mark).
    let hop_idx = (hop - 1) as usize;
    let num_tasks = scratch.sizers[hop_idx].num_tasks(cfg);
    fill_scan_tasks(g, scratch.index.nodes(), num_tasks, &mut scratch.chunks, &mut scratch.tasks);
    // Tiered graph: fault this frontier's cold adjacency pages in bulk
    // before the scan fans out, so scan tasks hit the hot tier instead
    // of stalling one fault at a time. Under the look-ahead ring this
    // runs on a speculator a wave ahead of reduce/emit — the prefetch
    // *is* the wave-ahead warming for topology, the way `WaveWarmer`
    // warms features. No-op on resident graphs.
    g.prefetch_pages(scratch.index.nodes(), cfg.threads);
    // --- map phase (persistent pool, results into pre-sized slots) ------
    let scan_phase = format!("hop{hop}.scan");
    let (index, chunks, tasks, frames) =
        (&scratch.index, &scratch.chunks, &scratch.tasks, &scratch.frames);
    let seeds = slots.seeds;
    let ntasks = tasks.len();
    let results: Vec<(Frame, u64, Duration)> =
        WorkPool::global().map_collect_labeled(ntasks, cfg.threads, 1, "hop.scan", |t| {
            // Per-task clock, started inside the job: the sizer must see
            // task cost, not time spent queued behind another job on the
            // single-slot pool (the pipelined schedule queues routinely).
            let t0 = Instant::now();
            let (lo, hi) = tasks[t];
            let mut frame = frames.acquire();
            let scanned = scan_task(
                g,
                index,
                &chunks[lo as usize..hi as usize],
                cfg.sample_seed,
                hop,
                k,
                seeds,
                &mut frame,
            );
            (frame, scanned, t0.elapsed())
        });
    // Ledger: the map work is edge-balanced across the simulated cluster
    // regardless of how many OS-level tasks carried it — charge it evenly
    // so the scan phase's modeled time is a pure function of config +
    // input. (Downstream, the merge fan-in and reduce-tree fabric charges
    // still see the partial-frame count; the sizer's power-of-two
    // quantization keeps that count stable across runs in practice.)
    let mut partials = Vec::with_capacity(results.len());
    let mut total_scanned = 0u64;
    let mut scan_cpu = Duration::ZERO;
    for (frame, scanned, took) in results {
        total_scanned += scanned;
        scan_cpu += took;
        partials.push(frame);
    }
    let w = cfg.workers as u64;
    for worker in 0..cfg.workers {
        let share = total_scanned / w + u64::from((worker as u64) < total_scanned % w);
        ledger.charge(
            &scan_phase,
            worker,
            WorkUnits { scan_edge_entries: share, ..Default::default() },
        );
    }
    scratch.sizers[hop_idx].record(ntasks, scan_cpu);
    // --- reduce phase (tree or flat) ---
    let merge_phase = format!("hop{hop}.merge");
    ledger_merge(
        ledger,
        &merge_phase,
        &partials,
        &scratch.frontier,
        &mut scratch.ord_stats,
        k,
        cfg.reduce,
        slots.worker_of,
        cfg.workers,
    );
    let frames = &scratch.frames;
    let merge = |a: Frame, b: Frame| {
        let mut out = frames.acquire();
        Frame::merge_from(&a, &b, &mut out);
        frames.release(a);
        frames.release(b);
        out
    };
    let size_of: &(dyn Fn(&Frame) -> u64 + Sync) = &|f: &Frame| f.wire_bytes();
    let size_of_flat: &dyn Fn(&Frame) -> u64 = &|f: &Frame| f.wire_bytes();
    let merged = match cfg.reduce {
        ReduceTopology::Tree { arity } => {
            tree_reduce_with_fabric(partials, arity, merge, Some((fabric, size_of)))
        }
        ReduceTopology::Flat => flat_reduce(partials, merge, Some((fabric, size_of_flat))),
    };
    // --- assignment phase: write reservoirs into slots; charge the edge
    // replication transfer reducer→owning worker ("append E to Graph(S)
    // on worker M[S]"). Per-worker net bytes expose mapping imbalance.
    if let Some(m) = &merged {
        let assign_phase = format!("hop{hop}.assign");
        for (ord, res) in m.entries() {
            let slot = scratch.frontier[ord as usize].1 as usize;
            let dst = slots.worker_of[slot] as usize % cfg.workers;
            ledger.charge(
                &assign_phase,
                dst,
                WorkUnits {
                    merge_entries: res.len() as u64,
                    net_bytes: 8 + 12 * res.len() as u64,
                    msgs: 1,
                    ..Default::default()
                },
            );
        }
    }
    assign_hop(slots, hop, merged.as_ref(), &scratch.frontier, fabric, cfg.workers);
    if let Some(m) = merged {
        frames.release(m);
    }
}

/// Write a merged reservoir frame into the wave's hop vectors.
pub fn assign_hop(
    slots: &mut WaveSlots<'_>,
    hop: u32,
    merged: Option<&Frame>,
    frontier: &[(NodeId, u32, u32)],
    fabric: &Fabric,
    workers: usize,
) {
    if let Some(frame) = merged {
        for (ord, res) in frame.entries() {
            let (_, slot32, pos32) = frontier[ord as usize];
            let (slot, pos) = (slot32 as usize, pos32 as usize);
            let dst = slots.worker_of[slot] as usize % workers;
            // The reducer that produced this reservoir hands it to the
            // slot's owning worker ("append E to Graph(S) on worker
            // M[S]"); routing identity is the wire key, as before.
            let src = (slot_key(slot32, pos32) as usize) % workers;
            if src != dst {
                fabric.charge(src, dst, 8 + 12 * res.len() as u64);
            }
            match hop {
                1 => {
                    debug_assert_eq!(pos, 0);
                    slots.hop1[slot] = res.nodes().collect();
                }
                2 => {
                    let h2 = &mut slots.hop2[slot];
                    if h2.len() < slots.hop1[slot].len() {
                        h2.resize(slots.hop1[slot].len(), Vec::new());
                    }
                    h2[pos] = res.nodes().collect();
                }
                _ => unreachable!(),
            }
        }
    }
    // Slots whose hop-1 nodes had no admitted hop-2 neighbors still need
    // correctly shaped hop2 groups.
    if hop == 2 {
        for (slot, h1) in slots.hop1.iter().enumerate() {
            slots.hop2[slot].resize(h1.len(), Vec::new());
        }
    }
}

// ---------------------------------------------------------------------------
// Depth-k look-ahead wave ring
// ---------------------------------------------------------------------------

/// Look-ahead depths tracked individually by the occupancy histogram;
/// deeper rings fold into the last bucket.
pub const MAX_TRACKED_DEPTH: usize = 8;

/// Cap on the recorded adaptive-depth decision trace (counters keep
/// accumulating past it; only the per-decision detail is bounded).
pub const MAX_DEPTH_TRACE: usize = 256;

/// One adaptive-depth decision: the controller closed a stall window and
/// moved the effective look-ahead depth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DepthDecision {
    /// Wave ordinal (within the run) at which the new depth took effect.
    pub wave: u64,
    /// New effective look-ahead depth.
    pub depth: u32,
    /// New effective speculator worker count (1 when the controller is
    /// not scaling workers).
    pub workers: u32,
    /// Lane-starved stall rate EWMA (stalled waves / wave) at decision
    /// time.
    pub starve_ewma: f32,
    /// Queue-full admission stall rate EWMA (stalls / wave) at decision
    /// time.
    pub queue_ewma: f32,
}

/// Stall-driven adaptive look-ahead depth: retunes the *effective* ring
/// depth within `[1, lookahead_depth]` from an EWMA over the measured
/// stall taxonomy, one decision per wave window.
///
/// * **lane-starved ⇒ deepen** — the wave loop waited for a prefetched
///   wave that was not ready, so the ring should run further ahead;
/// * **queue-full ⇒ shallow** — admission stalled on training-queue
///   backpressure, so running further ahead only parks speculative waves
///   against the high-water mark (and churns the warmed cache window).
///
/// Both rates are folded per window (`window()` waves) with EWMA weight
/// [`ALPHA`](Self::ALPHA); a small deadband keeps a clean pipeline from
/// oscillating. The queue signal wins ties: backpressure means the
/// consumer is the bottleneck, and deepening cannot help.
///
/// With [`with_workers`](Self::with_workers) the controller also steps
/// the **effective speculator worker count** within `[1, max_workers]`
/// from the same EWMAs: starvation means the pool cannot keep the ring
/// full, so another worker helps; queue backpressure means speculators
/// only pile waves against the admission gate, so one parks. Worker
/// steps ride the same window cadence and are reported in the same
/// [`DepthDecision`] trace as depth steps.
#[derive(Debug)]
pub struct DepthController {
    max_depth: usize,
    depth: usize,
    max_workers: usize,
    workers: usize,
    window: u64,
    waves: u64,
    win_waves: u64,
    win_starved: u64,
    win_queue: u64,
    starve_ewma: f64,
    queue_ewma: f64,
}

impl DepthController {
    const ALPHA: f64 = 0.5;
    /// Stall rate (per wave) below which a window counts as clean.
    const DEADBAND: f64 = 0.05;

    pub fn new(max_depth: usize) -> Self {
        let max_depth = max_depth.max(1);
        Self {
            max_depth,
            depth: max_depth,
            max_workers: 1,
            workers: 1,
            window: ((max_depth * 2).max(4)) as u64,
            waves: 0,
            win_waves: 0,
            win_starved: 0,
            win_queue: 0,
            starve_ewma: 0.0,
            queue_ewma: 0.0,
        }
    }

    /// Also scale the speculator worker count within `[1, max_workers]`
    /// (both start at the maximum, like the depth).
    pub fn with_workers(mut self, max_workers: usize) -> Self {
        self.max_workers = max_workers.max(1);
        self.workers = self.max_workers;
        self
    }

    /// Effective depth currently in force.
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Effective speculator worker count currently in force.
    #[inline]
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Waves per decision window.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Record one retired wave; closes a window every `window()` waves
    /// and returns the decision when the effective depth changed.
    pub fn on_wave(&mut self, lane_starved: bool, queue_stalls: u64) -> Option<DepthDecision> {
        self.waves += 1;
        self.win_waves += 1;
        self.win_starved += lane_starved as u64;
        self.win_queue += queue_stalls;
        if self.win_waves < self.window {
            return None;
        }
        let starve_rate = self.win_starved as f64 / self.win_waves as f64;
        let queue_rate = self.win_queue as f64 / self.win_waves as f64;
        self.starve_ewma = Self::ALPHA * starve_rate + (1.0 - Self::ALPHA) * self.starve_ewma;
        self.queue_ewma = Self::ALPHA * queue_rate + (1.0 - Self::ALPHA) * self.queue_ewma;
        self.win_waves = 0;
        self.win_starved = 0;
        self.win_queue = 0;
        let old = self.depth;
        let old_workers = self.workers;
        if self.queue_ewma > Self::DEADBAND && self.queue_ewma >= self.starve_ewma {
            self.depth = (self.depth - 1).max(1);
            self.workers = (self.workers - 1).max(1);
        } else if self.starve_ewma > Self::DEADBAND {
            self.depth = (self.depth + 1).min(self.max_depth);
            self.workers = (self.workers + 1).min(self.max_workers);
        }
        // A worker never outruns the ring: at most one speculator per
        // look-ahead lane currently in force.
        self.workers = self.workers.min(self.depth).max(1);
        if self.depth == old && self.workers == old_workers {
            return None;
        }
        Some(DepthDecision {
            wave: self.waves,
            depth: self.depth as u32,
            workers: self.workers as u32,
            starve_ewma: self.starve_ewma as f32,
            queue_ewma: self.queue_ewma as f32,
        })
    }
}

/// Closable MPMC queue the look-ahead workers claim wave requests from
/// (`std::sync::mpsc` receivers are single-consumer, so the M-worker pool
/// needs its own; [`crate::pipeline::BoundedQueue`] is deliberately not
/// reused — it carries capacity/backpressure/stats machinery this hot
/// path doesn't want, lacks `try_pop`, and pulling it in would point a
/// dependency from `engines` back at `pipeline`). Push order is
/// admission = sequence order; workers pop FIFO but *finish* out of
/// order — the reorder buffer on the consume side restores FIFO
/// emission.
struct ReqQueue<T> {
    state: Mutex<(VecDeque<T>, bool)>,
    ready: Condvar,
}

impl<T> ReqQueue<T> {
    fn new() -> Self {
        Self { state: Mutex::new((VecDeque::new(), false)), ready: Condvar::new() }
    }

    /// False if the queue was already closed (item dropped).
    fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.1 {
            return false;
        }
        st.0.push_back(item);
        drop(st);
        // notify_all, not notify_one: with gated pops the woken worker
        // may be throttled off and go straight back to sleep — every
        // waiter must get a chance to re-check its gate or the item
        // strands until close.
        self.ready.notify_all();
        true
    }

    /// Blocking pop; `None` once closed and drained.
    fn pop(&self) -> Option<T> {
        self.pop_gated(|| true)
    }

    /// Blocking pop that only claims an item while `gate()` holds —
    /// the worker-scaling throttle: a worker whose index is at or above
    /// the effective worker count parks here (still draining to `None`
    /// on close) until [`wake_all`](Self::wake_all) re-checks it.
    fn pop_gated(&self, gate: impl Fn() -> bool) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.1 {
                // Closed: active workers drain what's left; throttled
                // ones exit at once (nobody re-notifies after close).
                return if gate() { st.0.pop_front() } else { None };
            }
            if gate() {
                if let Some(v) = st.0.pop_front() {
                    return Some(v);
                }
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Unpark every waiter so gated pops re-evaluate their gate (called
    /// after the controller moves the effective worker count).
    fn wake_all(&self) {
        let _st = self.state.lock().unwrap();
        self.ready.notify_all();
    }

    fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().0.pop_front()
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        drop(st);
        self.ready.notify_all();
    }
}

/// Closes a [`ReqQueue`] on drop — held by the consume loop *and* every
/// worker, so any early exit (emit error, worker panic) unparks the rest
/// of the pool instead of deadlocking the scope join.
struct CloseReqQueue<'a, T>(&'a ReqQueue<T>);

impl<T> Drop for CloseReqQueue<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Counters of the wave pipeline (exposed in
/// [`GenReport`](super::GenReport) and surfaced — bubble, stall taxonomy,
/// effective-depth histogram and the adaptive controller's decision
/// trace — through [`PipelineReport`](crate::pipeline::PipelineReport)).
#[derive(Debug, Clone, Default)]
pub struct WavePipelineStats {
    /// Waves processed by the run.
    pub waves: u64,
    /// Waves whose hop-1 scan was prefetched while an earlier wave was
    /// still reducing/emitting.
    pub overlapped_waves: u64,
    /// Waves whose hop-2 was also speculated on the look-ahead worker
    /// (ring depth ≥ 2, no newer hop-1 request pending, and the caller
    /// still holding an earlier prefetched wave — i.e. genuine idle
    /// time).
    pub deep_waves: u64,
    /// Wall time the wave loop spent waiting for a prefetched wave that
    /// was not ready yet — the **lane-starved** pipeline bubble. 0 =
    /// fully hidden.
    pub bubble: Duration,
    /// Times the wave loop found no prefetched wave ready (each wait
    /// contributes to [`bubble`](Self::bubble)).
    pub lane_starved_stalls: u64,
    /// Times ring admission stalled on training-queue backpressure
    /// ([`SubgraphSink::lookahead_admit`] said no).
    pub queue_full_stalls: u64,
    /// Wall time spent in those admission stalls.
    pub queue_full_wait: Duration,
    /// Wave-completion hooks executed on the wave loop (the feature-cache
    /// warming gather) — the **gather-wait** component of the taxonomy.
    pub gather_waits: u64,
    /// Wall time those hooks held the wave loop.
    pub gather_wait: Duration,
    /// `occupancy[d]` counts waves retired while the adaptive
    /// controller's **effective depth** was `d` (clamped to
    /// [`MAX_TRACKED_DEPTH`]`-1`) — the same axis the controller's
    /// decision trace and the sink's per-sequence admission credits use.
    /// (It previously bucketed by raw in-flight lane count, a different
    /// axis from the per-wave credit grants entirely.) Totals match the
    /// credits wave for wave; an individual wave can land one bucket
    /// apart when a window boundary moves the depth between its
    /// admission and its retirement. Steady state concentrates at the
    /// configured depth; mass in lower buckets means backpressure
    /// shallowed the ring.
    pub occupancy: [u64; MAX_TRACKED_DEPTH],
    /// Times the adaptive controller deepened the effective depth
    /// (lane-starved pressure).
    pub deepen_steps: u64,
    /// Times the adaptive controller shallowed it (queue-full pressure).
    pub shallow_steps: u64,
    /// Effective depth in force when the last pipelined run finished
    /// (0 = the ring never ran).
    pub effective_depth_last: u32,
    /// Times the adaptive controller grew the effective speculator
    /// worker count (lane-starved pressure).
    pub worker_scale_ups: u64,
    /// Times the adaptive controller shrank it (queue-full pressure).
    pub worker_scale_downs: u64,
    /// Effective speculator worker count in force when the last
    /// pipelined run finished (0 = the ring never ran).
    pub effective_workers_last: u32,
    /// The controller's decision trace, in order (capped at
    /// [`MAX_DEPTH_TRACE`] entries; the step counters above keep
    /// counting past the cap).
    pub depth_trace: Vec<DepthDecision>,
}

/// Stall/occupancy counters one pipelined `run` call accumulates before
/// folding into [`WavePipelineStats`].
#[derive(Debug, Default)]
struct RingCounters {
    overlapped: u64,
    bubble: Duration,
    lane_starved: u64,
    queue_full_stalls: u64,
    queue_full_wait: Duration,
    gather_waits: u64,
    gather_wait: Duration,
    occupancy: [u64; MAX_TRACKED_DEPTH],
    deepen: u64,
    shallow: u64,
    eff_last: u32,
    worker_up: u64,
    worker_down: u64,
    eff_workers_last: u32,
    trace: Vec<DepthDecision>,
}

/// Block on the sink's admission gate before handing a speculative wave
/// to the look-ahead worker (the training-queue backpressure hook).
fn admission_gate(sink: Option<&dyn SubgraphSink>, stalls: &mut u64, wait: &mut Duration) {
    if let Some(s) = sink {
        if !s.lookahead_admit() {
            let t0 = Instant::now();
            s.lookahead_wait();
            let waited = t0.elapsed();
            *stalls += 1;
            *wait += waited;
            crate::obs::trace::instant(
                "stall.queue_full",
                &[("wait_us", waited.as_micros() as f64)],
            );
        }
    }
}

/// One engine hop round: fills `hop` of `slots`, drawing all transient
/// state from `scratch`. Every engine's hop implementation has this exact
/// shape, which is what lets one wave driver pipeline all four.
pub type HopFn = for<'a> fn(
    &Csr,
    &mut WaveSlots<'a>,
    u32,
    &EngineConfig,
    &Fabric,
    &mut WorkLedger,
    &mut ScratchArena,
);

/// A ring of [`ScratchArena`] lanes plus the shared per-wave loop of all
/// four engines. With [`EngineConfig::wave_pipeline`] enabled, a pool of
/// [`EngineConfig::lookahead_workers`] long-lived speculator threads
/// claims up to `effective_depth` future waves **out of order** from a
/// shared request queue while the current wave's remaining
/// hops/reduce/emit drain on the caller's thread; lanes rotate through
/// the ring as waves complete. Every request carries its wave sequence
/// number, and a **reorder buffer** on the consume side parks
/// out-of-order completions until their turn — waves are still reduced
/// and emitted in FIFO sequence order, so the output bytes are identical
/// to the sequential schedule at every (depth × workers × threads)
/// combination. At depth ≥ 2 an otherwise-idle worker also *speculates
/// hop-2* of its wave — but only when no newer hop-1 request is pending
/// **and** the caller still holds an earlier prefetched wave, so deep
/// prefetch fills genuine idle time instead of stealing work the caller
/// would start immediately; the caller's thread skips straight to emit
/// for such waves.
///
/// The ring depth itself is **adaptive**: a [`DepthController`] retunes
/// the effective depth within `[1, lookahead_depth]` each wave window
/// from the measured stall taxonomy — lane-starved waves deepen it,
/// queue-full admission stalls shallow it — and records every decision
/// in [`WavePipelineStats::depth_trace`].
///
/// Admission is **backpressured by the sink**: before handing a wave to
/// the pool, the ring consults [`SubgraphSink::lookahead_admit`] and
/// blocks in [`SubgraphSink::lookahead_wait`] while the training queue
/// sits above its high-water mark (credits return on dequeue), so
/// generation can never run unboundedly ahead of the trainer. Each
/// successful admission is reported per sequence through
/// [`SubgraphSink::lookahead_admitted`] together with the effective
/// depth that granted it.
///
/// The schedule is a pure reordering: every hop consumes exactly the
/// inputs it would see sequentially (waves are mutually independent and
/// hop 1 depends only on the balance table), reservoirs are a pure
/// function of the candidate multiset, and waves emit in sequence order
/// from the caller's thread — so the produced subgraph bytes are
/// **identical** to the sequential schedule at every depth and worker
/// count (the determinism barrier asserted by
/// `tests/pipeline_overlap.rs`, including forced out-of-order completion
/// via [`EngineConfig::wave_delay`]).
#[derive(Debug, Default)]
pub struct WaveLanes {
    lanes: Vec<ScratchArena>,
    /// Pipeline counters accumulated across `run` calls.
    pub stats: WavePipelineStats,
}

impl WaveLanes {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_lanes(&mut self, n: usize) {
        while self.lanes.len() < n {
            self.lanes.push(ScratchArena::default());
        }
    }

    /// Aggregate scratch counters over every lane (sizer snapshot comes
    /// from lane 0; all lanes carry full waves through the ring, so any
    /// lane's sizers have seen both hops).
    pub fn scratch_stats(&self, pool_threads_spawned: u64) -> ScratchStats {
        let mut total = ScratchStats { pool_threads_spawned, ..Default::default() };
        for (i, lane) in self.lanes.iter().enumerate() {
            let s = lane.stats(0);
            total.frames_allocated += s.frames_allocated;
            total.frames_reused += s.frames_reused;
            total.steady_frame_allocs += s.steady_frame_allocs;
            if i == 0 {
                total.scan_tasks = s.scan_tasks;
                total.task_ewma_ns = s.task_ewma_ns;
            }
        }
        total
    }

    /// Run every wave of `table`: all hops via `hop`, the sink's
    /// wave-completion hook (timed as gather-wait), then `emit` with the
    /// finished [`WaveSlots`] (called in wave order on this thread).
    /// `sink` also provides the look-ahead admission gate; pass `None`
    /// for engines whose sink never sees in-flight waves (offline spill).
    #[allow(clippy::too_many_arguments)]
    pub fn run<'t>(
        &mut self,
        g: &Csr,
        table: &'t BalanceTable,
        waves: &[std::ops::Range<usize>],
        cfg: &EngineConfig,
        fabric: &Fabric,
        ledger: &mut WorkLedger,
        phases: &mut PhaseTimer,
        hop: HopFn,
        sink: Option<&dyn SubgraphSink>,
        mut emit: impl FnMut(&mut PhaseTimer, &mut WorkLedger, WaveSlots<'t>) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let hops = cfg.fanout.hops() as u32;
        self.stats.waves += waves.len() as u64;
        let wave_hook = sink.filter(|s| s.wants_waves());
        if !cfg.wave_pipeline || waves.len() < 2 {
            // Sequential schedule: one lane, hops back to back.
            self.ensure_lanes(1);
            let mut gather_waits = 0u64;
            let mut gather_wait = Duration::ZERO;
            for (wi, wave) in waves.iter().enumerate() {
                let wave_span = crate::obs::trace::span("wave").arg("seq", wi as f64);
                let lane = &mut self.lanes[0];
                let mut slots = WaveSlots::new(
                    &table.seeds[wave.clone()],
                    &table.worker_of[wave.clone()],
                );
                for h in 1..=hops {
                    phases.time(&format!("hop{h}"), || {
                        hop(g, &mut slots, h, cfg, fabric, ledger, lane)
                    });
                }
                if let Some(s) = wave_hook {
                    let t0 = Instant::now();
                    let gather_span = crate::obs::trace::span("gather.warm");
                    s.wave_complete(&slots.unique_nodes());
                    drop(gather_span);
                    gather_wait += t0.elapsed();
                    gather_waits += 1;
                }
                emit(&mut *phases, &mut *ledger, slots)?;
                drop(wave_span);
                if wi == 0 {
                    self.lanes[0].mark_warm();
                }
            }
            self.stats.gather_waits += gather_waits;
            self.stats.gather_wait += gather_wait;
            return Ok(());
        }
        // --- depth-k pipelined schedule, M out-of-order workers -----------
        // `depth` look-ahead lanes plus one for the wave in hand; the
        // speculator pool never needs more workers than lanes.
        let depth = cfg.lookahead_depth.max(1).min(waves.len() - 1);
        let m_workers = cfg.lookahead_workers.max(1).min(depth);
        let speculate = depth >= 2 && hops >= 2;
        self.ensure_lanes(depth + 1);
        let mut spare: Vec<ScratchArena> = std::mem::take(&mut self.lanes);
        let mut lane0 = spare.pop().expect("ring lane");
        // Prefetched waves the caller has not consumed yet (buffered in
        // the result channel or parked in the reorder buffer). Hop-2
        // speculation is gated on this being ≥ 1: only when the caller is
        // still busy with an earlier wave is deepening the next one free —
        // otherwise the worker would steal hop-2 work the caller would
        // start immediately, converting caller busy time into measured
        // bubble for no wall-clock gain.
        let outstanding = AtomicUsize::new(0);
        // Effective speculator worker count, stepped by the controller
        // alongside the depth: worker `widx` only claims requests while
        // `widx < eff_workers` (a soft throttle — it finishes whatever
        // it already holds). Scaling the pool changes only *which*
        // worker runs a wave, never wave content or emission order, so
        // output bytes stay identical at every effective worker count.
        let eff_workers = AtomicUsize::new(m_workers);
        // Shared request queue: admission pushes `(seq, range, lane)` in
        // sequence order; any idle worker claims the head. Completion
        // order is whatever the pool produces — the reorder buffer below
        // restores FIFO.
        let reqq: ReqQueue<(u64, std::ops::Range<usize>, ScratchArena)> = ReqQueue::new();
        let outcome = std::thread::scope(
            |s| -> anyhow::Result<(Vec<(WorkLedger, PhaseTimer, u64)>, RingCounters)> {
                let mut c = RingCounters::default();
                let (res_tx, res_rx) =
                    mpsc::channel::<(u64, WaveSlots<'t>, ScratchArena, u32)>();
                let outstanding = &outstanding;
                let eff_workers = &eff_workers;
                let reqq = &reqq;
                // If the consume loop bails early (emit error), closing
                // the request queue on drop unparks every worker so the
                // scope can join them.
                let _close = CloseReqQueue(reqq);
                // Long-lived speculator pool: M spawns per run, not per
                // wave. Each worker owns its own ledger/timer; all merge
                // back after the loop (ledger charges are commutative
                // sums, so the merged totals equal the sequential
                // schedule's regardless of which worker ran which wave).
                let mut helpers = Vec::with_capacity(m_workers);
                for widx in 0..m_workers {
                    let res_tx = res_tx.clone();
                    helpers.push(s.spawn(move || {
                        // Stable frame-arena shard across runs: speculator
                        // threads are respawned per run, so without a
                        // pinned slot each respawn would burn a fresh
                        // monotonic id and drift away from the shard its
                        // predecessor's warm frames were released to.
                        crate::util::workpool::pin_worker_slot(
                            crate::util::workpool::speculator_slot(widx),
                        );
                        crate::obs::trace::set_track(crate::obs::trace::Track::Speculator(
                            widx as u16,
                        ));
                        // Any worker exit (panic included) closes the
                        // queue so its peers exit and the caller's recv
                        // disconnects instead of hanging.
                        let _close = CloseReqQueue(reqq);
                        let mut hledger = WorkLedger::new(cfg.workers);
                        let mut hphases = PhaseTimer::new();
                        let mut deep = 0u64;
                        let mut pending: Option<(
                            u64,
                            std::ops::Range<usize>,
                            ScratchArena,
                        )> = None;
                        loop {
                            let (seq, range, mut lane) = match pending.take() {
                                Some(m) => m,
                                None => match reqq.pop_gated(|| {
                                    widx < eff_workers.load(Ordering::Relaxed)
                                }) {
                                    Some(m) => m,
                                    None => break,
                                },
                            };
                            // Test-only jitter: lets the overlap tests
                            // force wave w+2 to finish before w+1.
                            if let Some(d) = cfg.wave_delay {
                                d.apply(seq as usize);
                            }
                            let mut wave_span =
                                crate::obs::trace::span("wave.spec").arg("seq", seq as f64);
                            let mut slots = WaveSlots::new(
                                &table.seeds[range.clone()],
                                &table.worker_of[range],
                            );
                            hphases.time("hop1", || {
                                hop(g, &mut slots, 1, cfg, fabric, &mut hledger, &mut lane)
                            });
                            let mut done = 1u32;
                            if speculate {
                                // Breadth first: a pending hop-1 request
                                // (for any worker) beats deepening this
                                // wave; and speculation only fills genuine
                                // idle time — the caller must still be
                                // holding an earlier prefetched wave.
                                match reqq.try_pop() {
                                    Some(next) => pending = Some(next),
                                    None => {
                                        if outstanding.load(Ordering::Relaxed) >= 1 {
                                            hphases.time("hop2", || {
                                                hop(
                                                    g,
                                                    &mut slots,
                                                    2,
                                                    cfg,
                                                    fabric,
                                                    &mut hledger,
                                                    &mut lane,
                                                )
                                            });
                                            done = 2;
                                            deep += 1;
                                        }
                                    }
                                }
                            }
                            wave_span.push_arg("hops", done as f64);
                            drop(wave_span);
                            outstanding.fetch_add(1, Ordering::Relaxed);
                            if res_tx.send((seq, slots, lane, done)).is_err() {
                                break;
                            }
                        }
                        (hledger, hphases, deep)
                    }));
                }
                // Workers hold the only senders: recv disconnects when
                // the whole pool has exited.
                drop(res_tx);
                // Admit waves in sequence order up to the controller's
                // effective depth, each behind the sink's backpressure
                // gate; credits are granted per sequence at that depth.
                let admit = |next_admit: &mut usize,
                             in_flight: &mut usize,
                             spare: &mut Vec<ScratchArena>,
                             c: &mut RingCounters,
                             eff: usize|
                 -> anyhow::Result<()> {
                    while *next_admit < waves.len() && *in_flight < eff {
                        admission_gate(sink, &mut c.queue_full_stalls, &mut c.queue_full_wait);
                        let lane = spare.pop().expect("ring lane");
                        let seq = *next_admit as u64;
                        if !reqq.push((seq, waves[*next_admit].clone(), lane)) {
                            anyhow::bail!("wave prefetcher exited early");
                        }
                        if let Some(sk) = sink {
                            sk.lookahead_admitted(seq, eff);
                        }
                        *next_admit += 1;
                        *in_flight += 1;
                    }
                    Ok(())
                };
                // Wave 0's hop-1 runs inline; the ring fills behind it.
                let mut slots0 = WaveSlots::new(
                    &table.seeds[waves[0].clone()],
                    &table.worker_of[waves[0].clone()],
                );
                phases.time("hop1", || {
                    hop(g, &mut slots0, 1, cfg, fabric, ledger, &mut lane0)
                });
                let mut ctl = DepthController::new(depth).with_workers(m_workers);
                let mut next_admit = 1usize;
                let mut in_flight = 0usize;
                admit(&mut next_admit, &mut in_flight, &mut spare, &mut c, ctl.depth())?;
                let mut cur = Some((slots0, lane0, 1u32));
                // Reorder buffer: completions whose turn has not come yet
                // (at most `depth` entries, so a linear scan is fine).
                let mut stash: Vec<(u64, WaveSlots<'t>, ScratchArena, u32)> =
                    Vec::with_capacity(depth);
                for wi in 0..waves.len() {
                    let (mut slots, mut lane, done) = cur.take().expect("current wave in hand");
                    let wave_span = crate::obs::trace::span("wave").arg("seq", wi as f64);
                    for h in (done + 1)..=hops {
                        phases.time(&format!("hop{h}"), || {
                            hop(g, &mut slots, h, cfg, fabric, ledger, &mut lane)
                        });
                    }
                    // Idempotent: stocks the slack on the lane's first
                    // full wave, no-ops afterwards.
                    lane.mark_warm();
                    // The lane is free as soon as its hops are done: hand
                    // it back to the ring *before* emitting, so look-ahead
                    // hop work also overlaps the emit.
                    spare.push(lane);
                    let q_before = c.queue_full_stalls;
                    admit(&mut next_admit, &mut in_flight, &mut spare, &mut c, ctl.depth())?;
                    if let Some(s) = wave_hook {
                        let t0 = Instant::now();
                        let gather_span = crate::obs::trace::span("gather.warm");
                        s.wave_complete(&slots.unique_nodes());
                        drop(gather_span);
                        c.gather_wait += t0.elapsed();
                        c.gather_waits += 1;
                    }
                    emit(&mut *phases, &mut *ledger, slots)?;
                    drop(wave_span);
                    let mut starved = false;
                    if wi + 1 < waves.len() {
                        // Histogram bucket = the effective depth in force
                        // as this wave retires — the same axis as the
                        // controller trace and the per-sequence admission
                        // credits (totals agree; a wave admitted just
                        // before a window boundary may sit one bucket
                        // apart from its credit).
                        c.occupancy[ctl.depth().min(MAX_TRACKED_DEPTH - 1)] += 1;
                        let want = (wi + 1) as u64;
                        let next = loop {
                            if let Some(pos) = stash.iter().position(|(sq, ..)| *sq == want) {
                                let (_, sl, la, d) = stash.swap_remove(pos);
                                break (sl, la, d);
                            }
                            match res_rx.try_recv() {
                                Ok(m) => {
                                    stash.push(m);
                                    continue;
                                }
                                Err(mpsc::TryRecvError::Empty) => {}
                                Err(mpsc::TryRecvError::Disconnected) => {
                                    return Err(anyhow::anyhow!(
                                        "wave prefetcher exited early"
                                    ))
                                }
                            }
                            // The wave whose turn it is isn't done: one
                            // lane-starved stall, however many
                            // out-of-order completions land while we wait.
                            if !starved {
                                starved = true;
                                c.lane_starved += 1;
                                crate::obs::trace::instant(
                                    "stall.lane_starved",
                                    &[("wave", want as f64)],
                                );
                            }
                            let wait = Instant::now();
                            let wait_span = crate::obs::trace::span("wave.wait");
                            let m = res_rx.recv().map_err(|_| {
                                anyhow::anyhow!("wave prefetcher exited early")
                            })?;
                            drop(wait_span);
                            c.bubble += wait.elapsed();
                            stash.push(m);
                        };
                        outstanding.fetch_sub(1, Ordering::Relaxed);
                        c.overlapped += 1;
                        in_flight -= 1;
                        cur = Some(next);
                    }
                    // Close the controller's books on this wave; a window
                    // boundary may move the effective depth used by the
                    // next iteration's admission.
                    let before = ctl.depth();
                    let workers_before = ctl.workers();
                    if let Some(d) = ctl.on_wave(starved, c.queue_full_stalls - q_before) {
                        if (d.depth as usize) > before {
                            c.deepen += 1;
                        } else if (d.depth as usize) < before {
                            c.shallow += 1;
                        }
                        if (d.workers as usize) > workers_before {
                            c.worker_up += 1;
                        } else if (d.workers as usize) < workers_before {
                            c.worker_down += 1;
                        }
                        if (d.workers as usize) != workers_before {
                            // Publish the new worker count and re-check
                            // every gated pop — a scale-up must unpark
                            // throttled workers immediately.
                            eff_workers.store(d.workers as usize, Ordering::Relaxed);
                            reqq.wake_all();
                        }
                        crate::obs::trace::instant(
                            "depth.decision",
                            &[
                                ("wave", d.wave as f64),
                                ("depth", d.depth as f64),
                                ("workers", d.workers as f64),
                                ("starve_ewma", d.starve_ewma as f64),
                                ("queue_ewma", d.queue_ewma as f64),
                            ],
                        );
                        if c.trace.len() < MAX_DEPTH_TRACE {
                            c.trace.push(d);
                        }
                    }
                }
                reqq.close();
                let mut outs = Vec::with_capacity(helpers.len());
                for h in helpers {
                    outs.push(
                        h.join()
                            .map_err(|_| anyhow::anyhow!("wave prefetcher panicked"))?,
                    );
                }
                c.eff_last = ctl.depth() as u32;
                c.eff_workers_last = ctl.workers() as u32;
                Ok((outs, c))
            },
        );
        let (worker_outs, c) = outcome?;
        for (hledger, hphases, deep) in &worker_outs {
            ledger.merge(hledger);
            phases.merge(hphases);
            self.stats.deep_waves += deep;
        }
        while spare.len() < depth + 1 {
            spare.push(ScratchArena::default());
        }
        self.lanes = spare;
        self.stats.bubble += c.bubble;
        self.stats.overlapped_waves += c.overlapped;
        self.stats.lane_starved_stalls += c.lane_starved;
        self.stats.queue_full_stalls += c.queue_full_stalls;
        self.stats.queue_full_wait += c.queue_full_wait;
        self.stats.gather_waits += c.gather_waits;
        self.stats.gather_wait += c.gather_wait;
        self.stats.deepen_steps += c.deepen;
        self.stats.shallow_steps += c.shallow;
        self.stats.effective_depth_last = c.eff_last;
        self.stats.worker_scale_ups += c.worker_up;
        self.stats.worker_scale_downs += c.worker_down;
        self.stats.effective_workers_last = c.eff_workers_last;
        self.stats.depth_trace.extend(c.trace);
        for (dst, src) in self.stats.occupancy.iter_mut().zip(c.occupancy.iter()) {
            *dst += src;
        }
        Ok(())
    }
}

/// Build the global balance table and slice it into waves.
pub fn plan_waves(
    seeds: &[NodeId],
    cfg: &EngineConfig,
) -> (BalanceTable, Vec<std::ops::Range<usize>>) {
    let table = BalanceTable::build(seeds, cfg.workers, cfg.mapping, cfg.sample_seed);
    let n = table.seeds.len();
    let mut waves = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + cfg.wave_size).min(n);
        waves.push(start..end);
        start = end;
    }
    (table, waves)
}

/// Deterministic identity of a planned wave layout: any process that
/// builds the same balance table from the same config gets the same
/// hash. Distributed workers compare it against the coordinator's plan
/// before claiming work, so a config drift (different seeds, mapping,
/// shuffle seed, cluster width) aborts instead of silently producing
/// different bytes.
pub fn table_hash(table: &BalanceTable) -> u64 {
    crate::util::fxhash::fxhash(&(&table.seeds, &table.worker_of))
}

/// Regenerate one wave of `table` in isolation — the distributed wave
/// ledger's unit of work and recovery. A wave is a pure function of
/// `(graph, table slice, cfg)`: within-wave output is slot order and
/// waves share no state (the property the engine-equivalence suite pins
/// across threads/pipelining), so *any* process — including a survivor
/// reclaiming a killed worker's wave — reproduces its bytes exactly.
pub fn generate_wave<'t>(
    g: &Csr,
    table: &'t BalanceTable,
    wave: std::ops::Range<usize>,
    cfg: &EngineConfig,
    hop: HopFn,
    fabric: &Fabric,
    ledger: &mut WorkLedger,
    scratch: &mut ScratchArena,
) -> WaveSlots<'t> {
    let mut slots = WaveSlots::new(&table.seeds[wave.clone()], &table.worker_of[wave]);
    for h in 1..=cfg.fanout.fanouts.len() as u32 {
        hop(g, &mut slots, h, cfg, fabric, ledger, scratch);
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampler::FanoutSpec;

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 4,
            threads: 4,
            wave_size: 64,
            fanout: FanoutSpec::new(vec![4, 3]),
            sample_seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn scan_tasks_cover_all_edges_once() {
        let g = generator::from_spec("star:n=512,hubs=1", 2).unwrap().csr();
        let frontier: Vec<NodeId> = (0..20).collect();
        let mut chunks = Vec::new();
        let mut tasks = Vec::new();
        fill_scan_tasks(&g, &frontier, 8, &mut chunks, &mut tasks);
        // Every chunk belongs to exactly one task, in order.
        let mut covered_chunks = 0u32;
        for &(lo, hi) in &tasks {
            assert_eq!(lo, covered_chunks, "tasks must tile the chunk vec");
            assert!(hi > lo);
            covered_chunks = hi;
        }
        assert_eq!(covered_chunks as usize, chunks.len());
        // Sum of chunk widths == sum of degrees; no overlap per node.
        let mut per_node: std::collections::HashMap<NodeId, Vec<(u32, u32)>> = Default::default();
        for c in &chunks {
            per_node.entry(c.node).or_default().push((c.lo, c.hi));
        }
        for v in frontier {
            let mut ranges = per_node.remove(&v).unwrap_or_default();
            ranges.sort_unstable();
            let mut covered = 0;
            for (lo, hi) in ranges {
                assert_eq!(lo, covered, "gap/overlap at node {v}");
                covered = hi;
            }
            assert_eq!(covered, g.degree(v), "node {v} not fully covered");
        }
        // The hub (node 0, degree ~511) must be split across chunks.
        let hub_chunks = chunks.iter().filter(|c| c.node == 0).count();
        assert!(hub_chunks > 1, "hub not split: {hub_chunks} chunk(s)");
    }

    #[test]
    fn frame_merge_matches_hashmap_semantics() {
        // Two frames with overlapping ordinals merge like the old
        // hashmap-entry merge: union of keys, TopK-merged values.
        let mut a = Frame::new();
        a.push_new(1, 2).insert(10, 100);
        a.push_new(3, 2).insert(30, 300);
        let mut b = Frame::new();
        let t = b.push_new(3, 2);
        t.insert(5, 50);
        t.insert(40, 400);
        b.push_new(7, 2).insert(70, 700);
        let mut out = Frame::new();
        Frame::merge_from(&a, &b, &mut out);
        let got: Vec<(u32, Vec<NodeId>)> =
            out.entries().map(|(o, t)| (o, t.nodes().collect())).collect();
        assert_eq!(got, vec![(1, vec![100]), (3, vec![50, 300]), (7, vec![700])]);
    }

    #[test]
    fn frame_arena_reuses_buffers() {
        let arena = FrameArena::default();
        let f1 = arena.acquire();
        arena.release(f1);
        let mut f2 = arena.acquire();
        // Stale state must not leak through a release/acquire cycle.
        assert!(f2.is_empty());
        f2.push_new(0, 1).insert(1, 1);
        arena.release(f2);
        arena.mark_warm(0);
        let f3 = arena.acquire();
        assert!(f3.is_empty());
        let stats_reused = arena.reused.load(Ordering::Relaxed);
        assert_eq!(stats_reused, 2);
        assert_eq!(arena.steady_allocs.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn hop_round_fills_slots_within_fanout() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 3).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..64).collect();
        let worker_of: Vec<u32> = seeds.iter().map(|&s| s % 4).collect();
        let mut slots = WaveSlots::new(&seeds, &worker_of);
        let mut ledger = WorkLedger::new(cfg.workers);
        let mut scratch = ScratchArena::default();
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger, &mut scratch);
        edge_centric_hop(&g, &mut slots, 2, &cfg, &fabric, &mut ledger, &mut scratch);
        for (slot, h1) in slots.hop1.iter().enumerate() {
            assert!(h1.len() <= 4);
            // hop1 ⊆ neighbors(seed)
            for v in h1 {
                assert!(g.neighbors(slots.seeds[slot]).contains(v));
            }
            assert_eq!(slots.hop2[slot].len(), h1.len());
            for (i, h2) in slots.hop2[slot].iter().enumerate() {
                assert!(h2.len() <= 3);
                for v in h2 {
                    assert!(g.neighbors(h1[i]).contains(v));
                }
            }
        }
    }

    #[test]
    fn hop_round_is_thread_count_invariant() {
        let g = generator::from_spec("rmat:n=512,e=4096", 5).unwrap().csr();
        let run = |threads: usize| {
            let mut c = cfg();
            c.threads = threads;
            let fabric = Fabric::new(c.workers);
            let seeds: Vec<NodeId> = (0..32).collect();
            let worker_of = vec![0u32; 32];
            let mut slots = WaveSlots::new(&seeds, &worker_of);
            let mut ledger = WorkLedger::new(c.workers);
            let mut scratch = ScratchArena::default();
            edge_centric_hop(&g, &mut slots, 1, &c, &fabric, &mut ledger, &mut scratch);
            edge_centric_hop(&g, &mut slots, 2, &c, &fabric, &mut ledger, &mut scratch);
            (slots.hop1, slots.hop2)
        };
        assert_eq!(run(1), run(8));
    }

    #[test]
    fn full_fanout_when_degree_allows() {
        // Complete-ish graph: every seed should get exactly f1 neighbors.
        let g = generator::from_spec("er:n=64,e=4000", 1).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..16).collect();
        let worker_of = vec![0u32; 16];
        let mut slots = WaveSlots::new(&seeds, &worker_of);
        let mut ledger = WorkLedger::new(cfg.workers);
        let mut scratch = ScratchArena::default();
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger, &mut scratch);
        for (slot, h1) in slots.hop1.iter().enumerate() {
            let deg = g.degree(slots.seeds[slot]) as usize;
            assert_eq!(h1.len(), deg.min(4), "slot {slot}");
        }
    }

    #[test]
    fn unique_nodes_covers_all_hops_once() {
        let g = generator::from_spec("rmat:n=1024,e=8192", 3).unwrap().csr();
        let cfg = cfg();
        let fabric = Fabric::new(cfg.workers);
        let seeds: Vec<NodeId> = (0..32).collect();
        let worker_of = vec![0u32; 32];
        let mut slots = WaveSlots::new(&seeds, &worker_of);
        let mut ledger = WorkLedger::new(cfg.workers);
        let mut scratch = ScratchArena::default();
        edge_centric_hop(&g, &mut slots, 1, &cfg, &fabric, &mut ledger, &mut scratch);
        edge_centric_hop(&g, &mut slots, 2, &cfg, &fabric, &mut ledger, &mut scratch);
        let ids = slots.unique_nodes();
        // Sorted, deduplicated, and covering every referenced node.
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        for &s in slots.seeds {
            assert!(ids.binary_search(&s).is_ok());
        }
        for (slot, h1) in slots.hop1.iter().enumerate() {
            for &v in h1 {
                assert!(ids.binary_search(&v).is_ok());
            }
            for h2 in &slots.hop2[slot] {
                for &w in h2 {
                    assert!(ids.binary_search(&w).is_ok());
                }
            }
        }
    }

    #[test]
    fn frontier_offsets_locate_every_entry() {
        let seeds: Vec<NodeId> = (0..8).collect();
        let worker_of = vec![0u32; 8];
        let mut slots = WaveSlots::new(&seeds, &worker_of);
        // Uneven hop1 shapes exercise the offset math.
        for (slot, h1) in slots.hop1.iter_mut().enumerate() {
            *h1 = (0..(slot % 3) as NodeId).collect();
        }
        let (mut frontier, mut offsets) = (Vec::new(), Vec::new());
        slots.fill_frontier(2, &mut frontier, &mut offsets);
        for (ord, &(_, slot, pos)) in frontier.iter().enumerate() {
            assert_eq!(offsets[slot as usize] + pos, ord as u32);
        }
    }

    #[test]
    fn plan_waves_slices_cover_table() {
        let seeds: Vec<NodeId> = (0..1000).collect();
        let (table, waves) = plan_waves(&seeds, &cfg());
        let covered: usize = waves.iter().map(|r| r.len()).sum();
        assert_eq!(covered, table.seeds.len());
        assert!(waves.iter().all(|r| r.len() <= 64));
    }

    #[test]
    fn depth_controller_shallows_on_queue_and_deepens_on_starvation() {
        let mut ctl = DepthController::new(4);
        assert_eq!(ctl.depth(), 4, "starts at the configured maximum");
        let w = ctl.window();
        // Three windows of sustained queue-full stalls: one shallow step
        // per window, down to the floor.
        let mut decisions = Vec::new();
        for _ in 0..w * 3 {
            if let Some(d) = ctl.on_wave(false, 2) {
                decisions.push(d);
            }
        }
        assert_eq!(ctl.depth(), 1, "sustained backpressure must shallow to 1");
        assert_eq!(decisions.len(), 3);
        assert!(decisions.iter().all(|d| d.queue_ewma > d.starve_ewma));
        // Sustained lane starvation: deepens back once the stale queue
        // EWMA decays below the starvation EWMA.
        for _ in 0..w * 8 {
            ctl.on_wave(true, 0);
        }
        assert_eq!(ctl.depth(), 4, "sustained starvation must deepen to the max");
        // Never leaves [1, max] no matter how long the pressure lasts.
        for _ in 0..w * 50 {
            ctl.on_wave(false, 5);
        }
        assert_eq!(ctl.depth(), 1);
        for _ in 0..w * 50 {
            ctl.on_wave(true, 0);
        }
        assert_eq!(ctl.depth(), 4);
    }

    #[test]
    fn depth_controller_holds_steady_when_clean() {
        // No stalls at all: the deadband keeps the depth parked at max
        // and the trace stays empty.
        let mut ctl = DepthController::new(3);
        for _ in 0..ctl.window() * 20 {
            assert!(ctl.on_wave(false, 0).is_none());
        }
        assert_eq!(ctl.depth(), 3);
    }

    #[test]
    fn depth_controller_scales_workers_with_depth() {
        let mut ctl = DepthController::new(4).with_workers(3);
        assert_eq!(ctl.workers(), 3, "starts at the configured maximum");
        let w = ctl.window();
        // Sustained backpressure parks workers along with the depth.
        let mut decisions = Vec::new();
        for _ in 0..w * 4 {
            if let Some(d) = ctl.on_wave(false, 2) {
                decisions.push(d);
            }
        }
        assert_eq!(ctl.depth(), 1);
        assert_eq!(ctl.workers(), 1, "sustained backpressure must park down to 1 worker");
        assert!(decisions.iter().all(|d| d.workers >= 1 && d.workers <= 3));
        // Sustained starvation grows the pool back, never past the max
        // and never past the effective depth.
        for _ in 0..w * 12 {
            if let Some(d) = ctl.on_wave(true, 0) {
                assert!(d.workers as usize <= d.depth as usize);
            }
        }
        assert_eq!(ctl.depth(), 4);
        assert_eq!(ctl.workers(), 3, "recovers to max_workers, not max_depth");
    }

    #[test]
    fn depth_controller_default_keeps_one_worker() {
        // Without with_workers the controller must behave exactly as
        // before worker scaling existed: workers pinned at 1.
        let mut ctl = DepthController::new(4);
        for _ in 0..ctl.window() * 6 {
            if let Some(d) = ctl.on_wave(true, 0) {
                assert_eq!(d.workers, 1);
            }
            ctl.on_wave(false, 3);
        }
        assert_eq!(ctl.workers(), 1);
    }

    #[test]
    fn req_queue_gated_pop_parks_and_wakes() {
        let q: ReqQueue<u32> = ReqQueue::new();
        let gate = std::sync::atomic::AtomicBool::new(false);
        let got = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                // Parks while the gate is closed even though an item is
                // queued; claims it once wake_all re-checks the gate.
                if let Some(v) = q.pop_gated(|| gate.load(Ordering::Relaxed)) {
                    got.store(v as u64, Ordering::Relaxed);
                }
            });
            assert!(q.push(7));
            std::thread::sleep(Duration::from_millis(10));
            assert_eq!(got.load(Ordering::Relaxed), 0, "gated worker must not claim");
            gate.store(true, Ordering::Relaxed);
            q.wake_all();
        });
        assert_eq!(got.load(Ordering::Relaxed), 7);
        // A throttled worker drains to None on close instead of hanging.
        assert!(q.push(9));
        q.close();
        assert_eq!(q.pop_gated(|| false), None);
        assert_eq!(q.pop_gated(|| true), Some(9), "active worker still drains after close");
    }

    #[test]
    fn req_queue_is_fifo_and_close_unparks() {
        let q: ReqQueue<u32> = ReqQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        let done = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                assert_eq!(q.pop(), None, "close must drain to None");
                done.store(true, Ordering::Relaxed);
            });
            std::thread::sleep(Duration::from_millis(10));
            q.close();
        });
        assert!(done.load(Ordering::Relaxed));
        assert!(!q.push(3), "push after close must be refused");
    }
}
