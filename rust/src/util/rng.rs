//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available in this offline environment, so we
//! implement the two small generators the system needs:
//!
//! * [`SplitMix64`] — used for seeding and for stateless hash-style
//!   "random" values (e.g. the per-edge sampling priorities that make the
//!   distributed reservoir deterministic and mergeable).
//! * [`Xoshiro256`] — xoshiro256** 1.0, the general-purpose generator used
//!   by graph generators, seed shuffling and feature synthesis.
//!
//! Everything in this module is fully deterministic given a seed, which is
//! a hard requirement: the same experiment config must generate the same
//! graph, the same seed assignment and the same sampled subgraphs on every
//! run (and on every *worker*, regardless of execution order).

/// SplitMix64 step: advances `state` and returns the next output.
///
/// Reference: Sebastiano Vigna, <https://prng.di.unimi.it/splitmix64.c>.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of a single value (one SplitMix64 output step).
///
/// Used as a cheap, high-quality hash for sampling priorities and feature
/// synthesis. `mix64(x) == mix64(y)` iff `x == y` for our purposes.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// Combine two values into one 64-bit hash (order-sensitive).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    mix64(mix64(a) ^ b.rotate_left(32))
}

/// Combine three values into one 64-bit hash (order-sensitive).
#[inline]
pub fn mix3(a: u64, b: u64, c: u64) -> u64 {
    mix64(mix2(a, b) ^ c.rotate_left(16))
}

/// A `SplitMix64` generator, mainly used to seed [`Xoshiro256`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        splitmix64(&mut self.state)
    }
}

/// xoshiro256** 1.0 — fast, high-quality 64-bit PRNG.
///
/// Reference: David Blackman & Sebastiano Vigna,
/// <https://prng.di.unimi.it/xoshiro256starstar.c>.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64, as
    /// recommended by the xoshiro authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Widening multiply; rejection loop terminates quickly in practice.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic, speed is irrelevant at our call sites).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range((j + 1) as u64) as usize;
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Zipf-distributed value in `[0, n)` with exponent `s`, via rejection
    /// sampling (Devroye). Used to synthesize heavy-tailed degree targets.
    pub fn gen_zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n > 0);
        if s <= 0.0 {
            return self.gen_range(n);
        }
        let nf = n as f64;
        let t = if (s - 1.0).abs() < 1e-9 {
            1.0 + nf.ln()
        } else {
            (nf.powf(1.0 - s) - s) / (1.0 - s)
        };
        loop {
            let u = self.gen_f64();
            let inv = if (s - 1.0).abs() < 1e-9 {
                (u * t).exp()
            } else {
                let y = u * t * (1.0 - s) + s;
                if y <= 0.0 {
                    continue;
                }
                y.powf(1.0 / (1.0 - s))
            };
            let x = inv.floor().max(1.0).min(nf);
            let k = x as u64;
            let ratio = (x / inv).powf(s) * if k == 1 { 1.0 } else { inv / x };
            if self.gen_f64() * ratio.max(1.0) <= ratio {
                return k - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (from the reference implementation).
        let mut s = 0u64;
        assert_eq!(splitmix64(&mut s), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(&mut s), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(splitmix64(&mut s), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(1);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        // With overwhelming probability the shuffle moved something.
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Xoshiro256::seed_from_u64(5);
        for &(n, k) in &[(10usize, 10usize), (100, 7), (1, 1), (50, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "indices must be distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 1000u64;
        let mut count0 = 0;
        let trials = 20_000;
        for _ in 0..trials {
            let v = r.gen_zipf(n, 1.1);
            assert!(v < n);
            if v == 0 {
                count0 += 1;
            }
        }
        // Rank 1 should dominate heavily under zipf(1.1); uniform would
        // give trials/1000 = 20.
        assert!(count0 > trials / 100, "rank0 count {count0} not heavy-tailed");
    }

    #[test]
    fn gen_normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gen_normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn mix_functions_differ_on_inputs() {
        assert_ne!(mix64(1), mix64(2));
        assert_ne!(mix2(1, 2), mix2(2, 1));
        assert_ne!(mix3(1, 2, 3), mix3(3, 2, 1));
    }
}
