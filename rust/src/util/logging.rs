//! Tiny `log` facade backend (env_logger is unavailable offline).
//!
//! Level comes from `GG_LOG` (error|warn|info|debug|trace), default `info`.
//! Output goes to stderr with elapsed-time prefixes so pipeline traces are
//! easy to correlate with throughput numbers.

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INIT: Once = Once::new();

struct StderrLogger {
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Reads `GG_LOG` for the level.
pub fn init() {
    INIT.call_once(|| {
        Lazy::force(&START);
        let level = match std::env::var("GG_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger { level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
