//! Dependency-free utility substrates.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so the usual ecosystem crates (`rand`, `serde`, `rayon`,
//! `clap`, `criterion`, `proptest`) are re-implemented here at the scale
//! this project needs. Each submodule documents which crate it stands in
//! for.

pub mod bytes;
pub mod crc32;
pub mod fxhash;
pub mod json;
pub mod logging;
pub mod parallel_scan;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod workpool;
