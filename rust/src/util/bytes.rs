//! Human-readable byte / count / rate formatting for reports.

/// `1536` → `"1.50 KiB"`.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// `5_900_000.0` → `"5.90 M"`.
pub fn fmt_count(n: f64) -> String {
    let a = n.abs();
    if a >= 1e9 {
        format!("{:.2} G", n / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} M", n / 1e6)
    } else if a >= 1e3 {
        format!("{:.2} k", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

/// Rate with unit, e.g. `fmt_rate(5.9e6, "nodes")` → `"5.90 M nodes/s"`.
pub fn fmt_rate(per_sec: f64, unit: &str) -> String {
    format!("{} {unit}/s", fmt_count(per_sec))
}

/// Seconds with adaptive precision: `0.000012` → `"12.0 µs"`.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(1023), "1023 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn counts_and_rates() {
        assert_eq!(fmt_count(999.0), "999");
        assert_eq!(fmt_count(5_900_000.0), "5.90 M");
        assert_eq!(fmt_rate(1500.0, "edges"), "1.50 k edges/s");
    }

    #[test]
    fn secs() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0125), "12.50 ms");
        assert_eq!(fmt_secs(12e-6), "12.0 µs");
        assert_eq!(fmt_secs(5e-9), "5 ns");
    }
}
