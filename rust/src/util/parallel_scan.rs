//! Single-pass chained (decoupled-lookback) parallel prefix scan.
//!
//! Every wave of the generation pipeline used to pay a *sequential*
//! prefix sum: CSR offsets, inverted-index slot offsets, frontier write
//! cursors, partition histograms. This module runs those scans on the
//! persistent [`WorkPool`] with the classic single-pass chained-scan
//! protocol (Merrill & Garland's decoupled lookback; see the
//! Koenvisser/workassisting and multi-dimensional-parallel-scan exemplars
//! in SNIPPETS.md):
//!
//! * the input is split into fixed-size **blocks**, claimed in ascending
//!   order from the pool's atomic work index (submitter assists, exactly
//!   like [`WorkPool::run`]);
//! * each block folds its local aggregate, publishes it
//!   (`AGGREGATE_AVAILABLE`), then **looks back** over its predecessors
//!   summing published aggregates until it meets a block whose inclusive
//!   prefix is final (`PREFIX_AVAILABLE`) — no barrier, no second pass
//!   over the data;
//! * with the exclusive prefix in hand it scans its slice in place and
//!   publishes its own inclusive prefix, unblocking successors.
//!
//! Status-word layout: each block owns three `AtomicU64` words — `state`
//! (0 = initialized, 1 = aggregate available, 2 = prefix available),
//! `aggregate` (sum of the block's input) and `prefix` (inclusive prefix
//! through the block). Values are stored Relaxed *before* the Release
//! store of `state`; readers Acquire-load `state` and then read the value
//! Relaxed, so the release sequence publishes the value with the flag.
//!
//! Termination: the pool hands block indices out in ascending order, so
//! when block `i` is claimed every predecessor is finished or actively
//! being processed by another participant, and block 0 always publishes a
//! final prefix immediately — lookback chains bottom out and every spin
//! has a producer making progress. A scan submitted from *inside* a pool
//! job (`IN_POOL_WORKER`) degrades to in-order inline execution, where
//! every block hits the predecessor-final fast path.
//!
//! Determinism: the element types are unsigned integers, whose wrapping
//! addition is associative and commutative — any block split and any
//! lookback order produces byte-identical output, which the converted
//! call sites (CSR build, inverted index, frontier offsets, partition
//! histograms) rely on across thread counts.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::util::workpool::{RawParts, WorkPool};

/// Element of a parallel scan: an unsigned integer whose wrapping sum is
/// associative + commutative (the byte-identity requirement) and which
/// round-trips through the block state's `u64` status words.
pub trait ScanValue: Copy + Send + Sync + 'static {
    const ZERO: Self;
    fn wadd(self, other: Self) -> Self;
    fn to_u64(self) -> u64;
    fn from_u64(v: u64) -> Self;
}

macro_rules! scan_value {
    ($($t:ty),*) => {$(
        impl ScanValue for $t {
            const ZERO: Self = 0;
            #[inline]
            fn wadd(self, other: Self) -> Self {
                self.wrapping_add(other)
            }
            #[inline]
            fn to_u64(self) -> u64 {
                self as u64
            }
            #[inline]
            fn from_u64(v: u64) -> Self {
                v as Self
            }
        }
    )*};
}

scan_value!(u32, u64, usize);

const STATE_INITIALIZED: u64 = 0;
const STATE_AGGREGATE_AVAILABLE: u64 = 1;
const STATE_PREFIX_AVAILABLE: u64 = 2;

/// Per-block state machine of one in-flight scan (see module docs for
/// the status-word protocol).
struct BlockState {
    state: AtomicU64,
    aggregate: AtomicU64,
    prefix: AtomicU64,
}

impl BlockState {
    fn new() -> Self {
        Self {
            state: AtomicU64::new(STATE_INITIALIZED),
            aggregate: AtomicU64::new(0),
            prefix: AtomicU64::new(0),
        }
    }
}

thread_local! {
    /// Reused block-state buffer of the submitting thread (steady-state
    /// scans allocate nothing). Taken out for the duration of a scan so a
    /// nested scan simply allocates fresh instead of aliasing.
    static TEMP: RefCell<Vec<BlockState>> = const { RefCell::new(Vec::new()) };
}

/// Elements per block: sized so one block is roughly one
/// [`TaskSizer::target_task_ns`](crate::engines::common::TaskSizer)
/// task at ~1 element/ns scan throughput, rounded to a power of two and
/// clamped to [2^12, 2^16]. Cached once per process like the target
/// itself.
pub fn block_size() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        let target = crate::engines::common::TaskSizer::target_task_ns();
        (target as usize).next_power_of_two().clamp(1 << 12, 1 << 16)
    })
}

/// Below this input length the parallel machinery cannot win (fewer than
/// two blocks) and the scan runs sequentially.
pub fn crossover() -> usize {
    2 * block_size()
}

/// Sequential in-place inclusive scan; returns the total.
pub fn inclusive_scan_seq<T: ScanValue>(data: &mut [T]) -> T {
    let mut acc = T::ZERO;
    for x in data.iter_mut() {
        acc = acc.wadd(*x);
        *x = acc;
    }
    acc
}

/// Sequential in-place exclusive scan; returns the total.
pub fn exclusive_scan_seq<T: ScanValue>(data: &mut [T]) -> T {
    let mut acc = T::ZERO;
    for x in data.iter_mut() {
        let v = *x;
        *x = acc;
        acc = acc.wadd(v);
    }
    acc
}

/// In-place inclusive prefix scan (`out[i] = sum(in[..=i])`) on `pool`,
/// byte-identical to [`inclusive_scan_seq`] at every thread count.
/// Returns the total.
pub fn inclusive_scan<T: ScanValue>(pool: &WorkPool, threads: usize, data: &mut [T]) -> T {
    scan_in_place_tuned(pool, threads, data, true, block_size(), None)
}

/// In-place exclusive prefix scan (`out[i] = sum(in[..i])`) on `pool`,
/// byte-identical to [`exclusive_scan_seq`] at every thread count.
/// Returns the total.
pub fn exclusive_scan<T: ScanValue>(pool: &WorkPool, threads: usize, data: &mut [T]) -> T {
    scan_in_place_tuned(pool, threads, data, false, block_size(), None)
}

/// Tuned entry point: explicit block size plus an optional per-block
/// `hook(block_index)` invoked before the block is processed. The hook
/// exists so tests can stall one block and prove the lookback chain (not
/// a barrier) resolves the others; production callers use
/// [`inclusive_scan`] / [`exclusive_scan`].
#[doc(hidden)]
pub fn scan_in_place_tuned<T: ScanValue>(
    pool: &WorkPool,
    threads: usize,
    data: &mut [T],
    inclusive: bool,
    block: usize,
    hook: Option<&(dyn Fn(usize) + Sync)>,
) -> T {
    let n = data.len();
    let block = block.max(1);
    if threads <= 1 || n < 2 * block {
        metrics().seq_runs.inc();
        return if inclusive { inclusive_scan_seq(data) } else { exclusive_scan_seq(data) };
    }
    let nblocks = n.div_ceil(block);
    let mut temp = TEMP.with(|t| std::mem::take(&mut *t.borrow_mut()));
    if temp.len() < nblocks {
        temp.resize_with(nblocks, BlockState::new);
    }
    for s in temp.iter().take(nblocks) {
        s.state.store(STATE_INITIALIZED, Ordering::Relaxed);
    }
    let states = &temp[..nblocks];
    let lookback_waits = AtomicU64::new(0);
    let base = RawParts(data.as_mut_ptr());
    let base = &base;
    let span = crate::obs::trace::span("scan.blocks")
        .arg("blocks", nblocks as f64)
        .arg("n", n as f64);
    pool.run_labeled(nblocks, threads, 1, "scan.block", |b| {
        if let Some(h) = hook {
            h(b);
        }
        let start = b * block;
        let end = (start + block).min(n);
        // SAFETY: block index ranges are disjoint (each index is claimed
        // exactly once) and `data` outlives the blocking `run_labeled`.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        // Fast path: the predecessor's inclusive prefix is already final
        // (always true for block 0, and for every block when the claims
        // run in order on one thread) — scan directly, no second pass.
        let known = if b == 0 {
            Some(T::ZERO)
        } else {
            let prev = &states[b - 1];
            if prev.state.load(Ordering::Acquire) == STATE_PREFIX_AVAILABLE {
                Some(T::from_u64(prev.prefix.load(Ordering::Relaxed)))
            } else {
                None
            }
        };
        let prefix = match known {
            Some(p) => p,
            None => {
                // Reduce first, publish the aggregate, then look back.
                let mut agg = T::ZERO;
                for &v in slice.iter() {
                    agg = agg.wadd(v);
                }
                states[b].aggregate.store(agg.to_u64(), Ordering::Relaxed);
                states[b].state.store(STATE_AGGREGATE_AVAILABLE, Ordering::Release);
                let mut acc = T::ZERO;
                let mut j = b - 1;
                loop {
                    match states[j].state.load(Ordering::Acquire) {
                        STATE_PREFIX_AVAILABLE => {
                            acc = T::from_u64(states[j].prefix.load(Ordering::Relaxed)).wadd(acc);
                            break;
                        }
                        STATE_AGGREGATE_AVAILABLE => {
                            acc = T::from_u64(states[j].aggregate.load(Ordering::Relaxed))
                                .wadd(acc);
                            // Block 0 publishes a final prefix directly,
                            // so j > 0 here and the chain keeps walking.
                            j -= 1;
                        }
                        _ => {
                            // Predecessor still folding: its claimant is
                            // live (claims are handed out in ascending
                            // order), so spinning terminates.
                            lookback_waits.fetch_add(1, Ordering::Relaxed);
                            std::hint::spin_loop();
                        }
                    }
                }
                acc
            }
        };
        let mut acc = prefix;
        if inclusive {
            for x in slice.iter_mut() {
                acc = acc.wadd(*x);
                *x = acc;
            }
        } else {
            for x in slice.iter_mut() {
                let v = *x;
                *x = acc;
                acc = acc.wadd(v);
            }
        }
        states[b].prefix.store(acc.to_u64(), Ordering::Relaxed);
        states[b].state.store(STATE_PREFIX_AVAILABLE, Ordering::Release);
    });
    // run_labeled's completion protocol (remaining-count under the pool
    // mutex) orders every block's stores before this read.
    let total = T::from_u64(states[nblocks - 1].prefix.load(Ordering::Acquire));
    let waits = lookback_waits.load(Ordering::Relaxed);
    drop(span);
    metrics().parallel_runs.inc();
    metrics().blocks.add(nblocks as u64);
    metrics().lookback_waits.add(waits);
    if waits > 0 {
        crate::obs::trace::instant("scan.lookback_waits", &[("waits", waits as f64)]);
    }
    TEMP.with(|t| *t.borrow_mut() = temp);
    total
}

struct ScanMetrics {
    seq_runs: crate::obs::metrics::Counter,
    parallel_runs: crate::obs::metrics::Counter,
    blocks: crate::obs::metrics::Counter,
    lookback_waits: crate::obs::metrics::Counter,
}

/// Registry handles are looked up once (the registry takes a lock); the
/// scan hot path only touches atomics.
fn metrics() -> &'static ScanMetrics {
    static M: OnceLock<ScanMetrics> = OnceLock::new();
    M.get_or_init(|| ScanMetrics {
        seq_runs: crate::obs::metrics::counter("scan.seq_runs"),
        parallel_runs: crate::obs::metrics::counter("scan.parallel_runs"),
        blocks: crate::obs::metrics::counter("scan.blocks"),
        lookback_waits: crate::obs::metrics::counter("scan.lookback_waits"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_u32s(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n).map(|_| (rng.next_u64() & 0xffff) as u32).collect()
    }

    #[test]
    fn inclusive_matches_sequential() {
        for n in [0usize, 1, 5, 1000, 10_000] {
            let input = random_u32s(n, n as u64);
            let mut seq = input.clone();
            let total_seq = inclusive_scan_seq(&mut seq);
            for threads in [1, 2, 8] {
                let mut par = input.clone();
                // Small block size to force the parallel path.
                let total =
                    scan_in_place_tuned(WorkPool::global(), threads, &mut par, true, 64, None);
                assert_eq!(par, seq, "n={n} threads={threads}");
                assert_eq!(total, total_seq);
            }
        }
    }

    #[test]
    fn exclusive_matches_sequential() {
        for n in [0usize, 1, 129, 4096] {
            let input: Vec<u64> = random_u32s(n, 7 + n as u64).iter().map(|&v| v as u64).collect();
            let mut seq = input.clone();
            let total_seq = exclusive_scan_seq(&mut seq);
            for threads in [1, 2, 8] {
                let mut par = input.clone();
                let total =
                    scan_in_place_tuned(WorkPool::global(), threads, &mut par, false, 32, None);
                assert_eq!(par, seq, "n={n} threads={threads}");
                assert_eq!(total, total_seq);
            }
        }
    }

    #[test]
    fn usize_and_public_entry_points() {
        let input: Vec<usize> = (0..crossover() + 3).map(|i| i % 7).collect();
        let mut seq = input.clone();
        let t0 = inclusive_scan_seq(&mut seq);
        let mut par = input.clone();
        let t1 = inclusive_scan(WorkPool::global(), 8, &mut par);
        assert_eq!(par, seq);
        assert_eq!(t0, t1);
        let mut seq_x = input.clone();
        let t2 = exclusive_scan_seq(&mut seq_x);
        let mut par_x = input;
        let t3 = exclusive_scan(WorkPool::global(), 8, &mut par_x);
        assert_eq!(par_x, seq_x);
        assert_eq!(t2, t3);
    }

    #[test]
    fn below_crossover_stays_sequential_and_identical() {
        let input = random_u32s(crossover() - 1, 3);
        let mut seq = input.clone();
        inclusive_scan_seq(&mut seq);
        let mut par = input;
        inclusive_scan(WorkPool::global(), 8, &mut par);
        assert_eq!(par, seq);
    }

    #[test]
    fn block_size_is_pow2_and_clamped() {
        let b = block_size();
        assert!(b.is_power_of_two());
        assert!((1 << 12..=1 << 16).contains(&b));
        assert_eq!(crossover(), 2 * b);
    }

    #[test]
    fn scan_nested_inside_pool_job_is_correct() {
        // A scan submitted from inside a pool job runs inline in block
        // order (IN_POOL_WORKER): every block must hit the fast path and
        // the result must still match the sequential scan.
        let input = random_u32s(1000, 11);
        let mut expect = input.clone();
        inclusive_scan_seq(&mut expect);
        let results = WorkPool::global().map_collect(4, 4, 1, |_| {
            let mut data = input.clone();
            scan_in_place_tuned(WorkPool::global(), 8, &mut data, true, 16, None);
            data
        });
        for r in results {
            assert_eq!(r, expect);
        }
    }
}
