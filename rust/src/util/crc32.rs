//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
//! guarding framed wire messages and coordinator checkpoint files.
//!
//! Hand-rolled table-driven implementation: the offline build bans new
//! dependencies, and a 256-entry table is all the speed the control
//! plane needs (wave payload bodies are checksummed once per frame,
//! far from the generation hot path).

/// Lazily built 256-entry lookup table for the reflected polynomial.
fn table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Streaming CRC-32 state, for checksumming without materializing the
/// full buffer.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in data {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a buffer.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 2654435761 >> 13) as u8).collect();
        let mut s = Crc32::new();
        for chunk in data.chunks(7) {
            s.update(chunk);
        }
        assert_eq!(s.finish(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        for i in 0..64 {
            data[i] ^= 0x10;
            assert_ne!(crc32(&data), base, "flip at byte {i} undetected");
            data[i] ^= 0x10;
        }
    }
}
