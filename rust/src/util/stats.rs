//! Summary statistics and histograms for benchmark and metrics reporting.

/// Online accumulator plus exact percentiles over recorded samples.
///
/// Stores all samples (f64); intended for benchmark iteration counts,
/// per-worker load distributions and latency series — thousands to a few
/// million points, not unbounded telemetry.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    data: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter(values: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in values {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.data.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.data.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.data.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.data.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.data.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.data.iter().map(|v| (v - m) * (v - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Exact percentile by linear interpolation; `q` in `[0, 100]`.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.data.len();
        let rank = (q / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.data[lo]
        } else {
            let w = rank - lo as f64;
            self.data[lo] * (1.0 - w) + self.data[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// max/mean — the load-imbalance factor used in the E3 balance tables
    /// (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            return 1.0;
        }
        self.max() / m
    }

    /// Coefficient of variation (stddev / mean).
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            return 0.0;
        }
        self.stddev() / m
    }

    /// Compact one-line summary, e.g. for log output.
    pub fn summary(&mut self) -> String {
        if self.is_empty() {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.4} p50={:.4} p95={:.4} min={:.4} max={:.4} sd={:.4}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.min(),
            self.max(),
            self.stddev()
        )
    }
}

/// Fixed-bucket log-scale histogram for latencies (nanosecond input).
///
/// Buckets are powers of two from 1ns (<2ns) up to ~1.15s (2^60 capped),
/// which is plenty for in-process event latencies.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64], count: 0, sum: 0 }
    }

    pub fn record(&mut self, value_ns: u64) {
        let idx = 64 - value_ns.max(1).leading_zeros() as usize - 1;
        self.buckets[idx.min(63)] += 1;
        self.count += 1;
        self.sum += value_ns as u128;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum as f64 / self.count as f64
    }

    /// Approximate quantile: returns the upper bound of the bucket that
    /// contains the q-quantile observation.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Samples::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.len(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944487358056).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Samples::from_iter([10.0, 20.0, 30.0, 40.0]);
        assert!((s.percentile(0.0) - 10.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 40.0).abs() < 1e-12);
        assert!((s.median() - 25.0).abs() < 1e-12);
        assert!((s.percentile(25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let mut s = Samples::from_iter([5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.median(), 3.0);
        s.push(6.0);
        assert!((s.median() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn imbalance_factor() {
        let balanced = Samples::from_iter([10.0, 10.0, 10.0, 10.0]);
        assert!((balanced.imbalance() - 1.0).abs() < 1e-12);
        let skewed = Samples::from_iter([40.0, 0.0, 0.0, 0.0]);
        assert!((skewed.imbalance() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_samples() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert_eq!(s.summary(), "n=0");
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let mut h = LogHistogram::new();
        for _ in 0..900 {
            h.record(1_000); // ~1us
        }
        for _ in 0..100 {
            h.record(1_000_000); // ~1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ns(0.5);
        assert!(p50 >= 1_000 && p50 < 4_096, "p50={p50}");
        let p99 = h.quantile_ns(0.99);
        assert!(p99 >= 1_000_000, "p99={p99}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(100);
        b.record(100_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_ns() - 50_050.0).abs() < 1.0);
    }
}
