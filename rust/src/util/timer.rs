//! Timing helpers: scoped stopwatch and a named phase recorder used by the
//! engines to attribute time to pipeline stages (map / shuffle / reduce /
//! train) in their reports.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates durations under string labels; deterministic iteration order
/// for report rendering.
#[derive(Debug, Clone, Default)]
pub struct PhaseTimer {
    phases: BTreeMap<String, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and attribute it to `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn add(&mut self, phase: &str, d: Duration) {
        *self.phases.entry(phase.to_string()).or_default() += d;
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.phases.get(phase).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.phases.values().sum()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.phases {
            self.add(k, *v);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.phases.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Render as `phase=1.234s phase2=0.002s`.
    pub fn render(&self) -> String {
        let mut parts = Vec::new();
        for (k, v) in &self.phases {
            parts.push(format!("{k}={:.3}s", v.as_secs_f64()));
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() >= Duration::from_millis(2));
        let first = sw.restart();
        assert!(first >= Duration::from_millis(2));
        assert!(sw.elapsed() < first);
    }

    #[test]
    fn phase_timer_accumulates_and_merges() {
        let mut t = PhaseTimer::new();
        t.add("map", Duration::from_millis(10));
        t.add("map", Duration::from_millis(5));
        t.add("reduce", Duration::from_millis(1));
        assert_eq!(t.get("map"), Duration::from_millis(15));
        assert_eq!(t.total(), Duration::from_millis(16));

        let mut u = PhaseTimer::new();
        u.add("map", Duration::from_millis(1));
        t.merge(&u);
        assert_eq!(t.get("map"), Duration::from_millis(16));
        assert!(t.render().contains("map="));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.get("work") > Duration::ZERO);
    }
}
