//! Persistent work-assisting worker pool (rayon is unavailable offline).
//!
//! Replaces the old `util::pool` scoped-thread helpers. The old design
//! re-spawned OS threads via `std::thread::scope` for every parallel
//! region — every hop of every generation wave paid thread start-up and
//! tear-down, and `parallel_map` funneled results through a
//! `Mutex<Vec<(idx, R)>>`. Here worker threads are **long-lived**: spawned
//! once (lazily, on first demand), they park on a condvar and are handed
//! jobs described by a raw closure pointer plus an atomic work index.
//! Chunk claiming follows the work-assisting scheduler of
//! Koenvisser/workassisting (see SNIPPETS.md): the submitting thread
//! *assists* — it claims chunks from the same atomic index as the helpers,
//! so a job with `threads == 1` never touches the pool at all, and a
//! straggling helper can never leave the submitter idle. Results of
//! [`WorkPool::map_collect`] are written in place to pre-sized output
//! slots (each index is claimed exactly once), so there is no mutex on the
//! result path and no post-hoc reordering.
//!
//! Safety model: `run` publishes a lifetime-erased `*const dyn Fn(usize)`
//! job and does not return (or unwind past its internal guard) until every
//! participating worker has bowed out of the job, so the closure and
//! everything it borrows outlive all concurrent uses. A panicking worker
//! marks the job poisoned and the submitter re-raises; a panicking
//! submitter still quiesces the helpers before unwinding.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set for the lifetime of a pool worker thread: a job closure that
    /// (transitively) submits another job runs it inline instead of
    /// deadlocking on the single job slot.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
    /// The calling thread's sticky worker-slot id (`usize::MAX` =
    /// unassigned; see [`worker_slot`] / [`pin_worker_slot`]).
    static SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Stable small slot id for the calling thread, assigned on first use
/// (pool workers claim theirs when they start; any other thread gets the
/// next free id). Contended per-thread structures — e.g. the
/// [`FrameArena`](crate::engines::common::FrameArena) freelist — shard on
/// this so the common acquire/release path never crosses threads.
pub fn worker_slot() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    SLOT.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT.fetch_add(1, Ordering::Relaxed);
        s.set(v);
        v
    })
}

/// Pin the calling thread's slot explicitly, overriding (or preempting)
/// the monotonic assignment. Short-lived helper threads that are
/// respawned every run — the wave ring's M speculators — use this to
/// keep their sharded-freelist home stable across runs: without it each
/// respawn burns a fresh id, the thread's arena shard drifts, and warm
/// own-shard pops degrade into cross-shard steals.
///
/// When core pinning is enabled ([`pin_cores_enabled`]) the slot also
/// maps to a CPU core (`slot % cores`) and the calling thread's affinity
/// is set to it, so a speculator's cache-warm state stays put across
/// respawns too. No-op on unsupported platforms.
pub fn pin_worker_slot(slot: usize) {
    SLOT.with(|s| s.set(slot));
    maybe_pin_to_core(slot);
}

/// Tri-state core-pinning override: 0 = unset (env decides), 1 = on,
/// 2 = off.
static PIN_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Programmatic opt-in/out for core pinning (the `--pin-cores` CLI /
/// `pin_cores` config key); overrides `GG_PIN_CORES`. Only threads that
/// start (or pin a slot) after the call are affected.
pub fn set_pin_cores(on: bool) {
    PIN_OVERRIDE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Whether opt-in core pinning is active: the programmatic override if
/// set, else the `GG_PIN_CORES` environment toggle (read once).
pub fn pin_cores_enabled() -> bool {
    match PIN_OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            static ENV: OnceLock<bool> = OnceLock::new();
            *ENV.get_or_init(|| {
                std::env::var("GG_PIN_CORES")
                    .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                    .unwrap_or(false)
            })
        }
    }
}

/// If pinning is enabled, pin the calling thread to core
/// `slot % available cores`; returns whether an affinity was applied.
/// Worker slots map onto cores round-robin, so each pool's workers
/// `0..k` land on distinct cores (up to the core count) and the
/// speculators' reserved high slots spread from the top residues down —
/// away from the pool workers' low residues.
pub fn maybe_pin_to_core(slot: usize) -> bool {
    if !pin_cores_enabled() {
        return false;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    pin_current_thread_to(slot % cores)
}

/// Bind the calling thread to one CPU core. Linux-only (a raw
/// `sched_setaffinity` on the calling thread — the libc crate is not
/// available offline); other platforms report `false` and run unpinned.
#[cfg(target_os = "linux")]
pub fn pin_current_thread_to(core: usize) -> bool {
    // A fixed 1024-bit cpu_set_t, the glibc default width.
    let mut mask = [0u64; 16];
    if core >= 1024 {
        return false;
    }
    mask[core / 64] |= 1u64 << (core % 64);
    extern "C" {
        // pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Unsupported platform: never pins, callers proceed unpinned.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread_to(_core: usize) -> bool {
    false
}

/// Reserved stable slot for look-ahead speculator `i`: a fixed ceiling
/// far above anything [`worker_slot`]'s monotonic counter hands out in a
/// realistic process, counted *downwards* so the slots' low bits (what
/// shard-count-modulo consumers like the frame arena actually key on)
/// sit at the top of the residue range — away from the low residues the
/// monotonic ids of pool workers and the main thread occupy.
pub fn speculator_slot(i: usize) -> usize {
    (1 << 20) - 1 - i
}

/// Number of worker threads to use by default: `GG_THREADS` env override,
/// else available parallelism, else 4. Cached in a `OnceLock` — the
/// environment is read once per process, not once per call site.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("GG_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Lifetime-erased raw pointer to the start of a buffer that parallel
/// jobs write at provably **disjoint** offsets. `Sync` so job closures
/// can share it; soundness rests on two caller obligations, stated at
/// each use site: every claim writes a distinct element range, and the
/// buffer outlives the (blocking) pool call. Centralizes the ad-hoc
/// `struct Ptr(*mut T); unsafe impl Sync` pattern that disjoint-write
/// fan-outs (row chunks, result slots, tensor scatters) all need.
pub struct RawParts<T>(pub *mut T);

// SAFETY: see the type docs — disjointness and lifetime are per-use-site
// obligations of the fan-out that shares this pointer.
unsafe impl<T: Send> Sync for RawParts<T> {}

/// Which timeline family a pool's workers record onto: the scan pool
/// ([`WorkPool::global`]) traces as `pool-worker-N`, the gather pool
/// ([`WorkPool::gather_global`]) as `gather-worker-N`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolKind {
    Gen,
    Gather,
}

/// One published job: a lifetime-erased data-parallel closure over
/// `0..n`, claimed in `chunk`-sized strides by workers `0..helpers` plus
/// the submitting thread. `label` names the job's spans on the trace
/// timeline.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    chunk: usize,
    helpers: usize,
    label: &'static str,
}

// The raw closure pointer crosses threads inside the pool mutex; the
// submit protocol guarantees it is only dereferenced while the submitting
// stack frame is alive.
unsafe impl Send for Job {}

struct PoolState {
    /// Currently published job, if any (at most one in flight).
    job: Option<Job>,
    /// Bumped per job so parked workers can tell old from new.
    epoch: u64,
    /// Participating helpers that have not yet finished the current job.
    remaining: usize,
    /// Worker threads spawned so far.
    workers: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a job (or shutdown).
    start: Condvar,
    /// Submitters park here waiting for helpers / for the slot to free.
    done: Condvar,
    /// The work-assisting claim index of the current job.
    next: AtomicUsize,
    /// True if a helper panicked inside the current job.
    poisoned: AtomicBool,
    /// Total worker threads ever spawned (monotonic; perf counter).
    spawned_total: AtomicU64,
    /// Trace-track family for this pool's workers.
    kind: PoolKind,
}

/// A persistent pool of worker threads. Most callers want the process
/// [`WorkPool::global`] instance so that steady-state parallel regions
/// perform zero thread spawns.
pub struct WorkPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkPool {
    /// Create an empty pool; workers are spawned lazily on demand.
    pub fn new() -> Self {
        Self::with_kind(PoolKind::Gen)
    }

    /// Create an empty pool whose workers trace onto the given track
    /// family.
    pub fn with_kind(kind: PoolKind) -> Self {
        Self {
            shared: Arc::new(Shared {
                state: Mutex::new(PoolState {
                    job: None,
                    epoch: 0,
                    remaining: 0,
                    workers: 0,
                    shutdown: false,
                }),
                start: Condvar::new(),
                done: Condvar::new(),
                next: AtomicUsize::new(0),
                poisoned: AtomicBool::new(false),
                spawned_total: AtomicU64::new(0),
                kind,
            }),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// The process-wide pool. Never dropped; threads persist across engine
    /// runs, waves and benchmark iterations.
    pub fn global() -> &'static WorkPool {
        static POOL: OnceLock<WorkPool> = OnceLock::new();
        POOL.get_or_init(WorkPool::new)
    }

    /// The process-wide **feature-gather** pool, disjoint from
    /// [`global`](Self::global). A pool admits one job at a time, so
    /// routing gather fan-outs through the generation pool would park
    /// them behind hop-scan jobs (and vice versa) no matter how the
    /// thread budget is split; separate pools give the two sides real
    /// concurrency, and the per-side `threads` arguments
    /// ([`crate::pipeline::split_pool_budget`]) apportion the cores.
    pub fn gather_global() -> &'static WorkPool {
        static POOL: OnceLock<WorkPool> = OnceLock::new();
        POOL.get_or_init(|| WorkPool::with_kind(PoolKind::Gather))
    }

    /// Total worker threads ever spawned by this pool (monotonic). Engine
    /// reports snapshot this around a run to prove steady-state rounds
    /// spawn nothing.
    pub fn total_spawned(&self) -> u64 {
        self.shared.spawned_total.load(Ordering::Relaxed)
    }

    /// Grow the pool to at least `want` workers; returns how many threads
    /// this call actually spawned.
    pub fn ensure_workers(&self, want: usize) -> usize {
        let mut st = self.shared.state.lock().unwrap();
        let mut spawned = 0;
        while st.workers < want {
            let id = st.workers;
            let sh = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("gg-workpool-{id}"))
                .spawn(move || worker_loop(sh, id))
                .expect("spawn pool worker");
            self.handles.lock().unwrap().push(handle);
            st.workers += 1;
            spawned += 1;
            self.shared.spawned_total.fetch_add(1, Ordering::Relaxed);
        }
        spawned
    }

    /// Apply `f` to every index in `0..n` with dynamic `chunk`-strided
    /// claiming across up to `threads` threads (the submitter plus pooled
    /// helpers). `threads <= 1` (or a single chunk of work) runs inline
    /// without touching the pool.
    pub fn run(&self, n: usize, threads: usize, chunk: usize, f: impl Fn(usize) + Sync) {
        self.run_labeled(n, threads, chunk, "parallel", f);
    }

    /// [`run`](Self::run) with a trace label: the submitter's and every
    /// participating worker's span on the timeline carries `label`.
    pub fn run_labeled(
        &self,
        n: usize,
        threads: usize,
        chunk: usize,
        label: &'static str,
        f: impl Fn(usize) + Sync,
    ) {
        let _span = crate::obs::trace::span(label);
        let chunk = chunk.max(1);
        if threads <= 1 || n <= chunk || IN_POOL_WORKER.with(|w| w.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let total_chunks = n.div_ceil(chunk);
        let helpers = (threads - 1).min(total_chunks - 1).max(1);
        // Grow first (worker count is monotonic, so the pool is still big
        // enough when the job slot frees up below).
        self.ensure_workers(helpers);
        let sh = &*self.shared;
        // Erase the closure's lifetime: the guard below keeps the job
        // published (and this frame alive) until all helpers are done.
        let obj: &(dyn Fn(usize) + Sync) = &f;
        let f_erased: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(obj) };
        {
            let mut st = sh.state.lock().unwrap();
            // One job in flight at a time; later submitters queue here.
            while st.job.is_some() {
                st = sh.done.wait(st).unwrap();
            }
            sh.next.store(0, Ordering::Relaxed);
            st.epoch += 1;
            st.remaining = helpers;
            st.job = Some(Job { f: f_erased, n, chunk, helpers, label });
            sh.start.notify_all();
        }
        let saw_poison = Cell::new(false);
        {
            // On both the normal and the unwinding path: stop further
            // claims, wait for helpers, resolve this job's poison flag
            // (under the state lock, before the slot frees for the next
            // submitter — a later job's panic must not be misattributed),
            // and clear the job slot.
            let _guard = JobGuard { sh, n, saw_poison: &saw_poison };
            // While assisting, this thread executes job closures exactly
            // like a pool worker — mark it so a closure that transitively
            // submits another job runs that job inline instead of
            // deadlocking on the single job slot (the guard resets the
            // flag on both the normal and the unwinding path).
            IN_POOL_WORKER.with(|w| w.set(true));
            // Work-assist: the submitter claims chunks like any helper.
            loop {
                let start = sh.next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            }
        }
        if saw_poison.get() {
            panic!("WorkPool: a worker panicked while executing a job");
        }
    }

    /// Parallel write over the `rows × stride` elements of `out`: rows are
    /// split into `chunk_rows`-sized ranges and `f(first_row, sub_slice)`
    /// runs once per range, each range receiving its disjoint `&mut`
    /// sub-slice. The bulk-gather fan-out primitive of the feature store:
    /// callers fill contiguous row blocks without a result collection pass.
    /// Falls back to a single inline call for small work or `threads <= 1`.
    pub fn run_row_chunks<T: Send>(
        &self,
        out: &mut [T],
        stride: usize,
        threads: usize,
        chunk_rows: usize,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        self.run_row_chunks_labeled(out, stride, threads, chunk_rows, "rows", f);
    }

    /// [`run_row_chunks`](Self::run_row_chunks) with a trace label.
    pub fn run_row_chunks_labeled<T: Send>(
        &self,
        out: &mut [T],
        stride: usize,
        threads: usize,
        chunk_rows: usize,
        label: &'static str,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        let stride = stride.max(1);
        // Load-bearing for coverage: a ragged buffer would leave its tail
        // silently unwritten, so reject it in release builds too.
        assert_eq!(out.len() % stride, 0, "out must be whole rows");
        let rows = out.len() / stride;
        let chunk_rows = chunk_rows.max(1);
        let chunks = rows.div_ceil(chunk_rows);
        if threads <= 1 || chunks <= 1 {
            f(0, out);
            return;
        }
        let base = RawParts(out.as_mut_ptr());
        let base = &base;
        self.run_labeled(chunks, threads, 1, label, |c| {
            let r0 = c * chunk_rows;
            let r1 = (r0 + chunk_rows).min(rows);
            // SAFETY: chunk row ranges are disjoint (each chunk index is
            // claimed exactly once) and `out` outlives `run`, which blocks
            // until every claim finishes.
            let sub = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r0 * stride), (r1 - r0) * stride)
            };
            f(r0, sub);
        });
    }

    /// Parallel map `0..n -> R`, results written in place to pre-sized
    /// slots (no mutex, no reordering). Order of `out[i]` matches `i`.
    pub fn map_collect<R: Send>(
        &self,
        n: usize,
        threads: usize,
        chunk: usize,
        f: impl Fn(usize) -> R + Sync,
    ) -> Vec<R> {
        self.map_collect_labeled(n, threads, chunk, "parallel", f)
    }

    /// [`map_collect`](Self::map_collect) with a trace label.
    pub fn map_collect_labeled<R: Send>(
        &self,
        n: usize,
        threads: usize,
        chunk: usize,
        label: &'static str,
        f: impl Fn(usize) -> R + Sync,
    ) -> Vec<R> {
        if threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<std::mem::MaybeUninit<R>> = Vec::with_capacity(n);
        // SAFETY: MaybeUninit needs no initialization; every slot is
        // written exactly once below before being read.
        unsafe { out.set_len(n) };
        let slots = RawParts(out.as_mut_ptr());
        let slots_ref = &slots;
        self.run_labeled(n, threads, chunk, label, |i| {
            let v = f(i);
            // SAFETY: index claimed exactly once by the work loop.
            unsafe { (*slots_ref.0.add(i)).write(v) };
        });
        // SAFETY: run() returned normally, so all n slots are initialized.
        // (If it panicked, `out` is dropped as MaybeUninit and the written
        // elements leak — acceptable on the panic path.)
        unsafe {
            let mut out = std::mem::ManuallyDrop::new(out);
            Vec::from_raw_parts(out.as_mut_ptr() as *mut R, out.len(), out.capacity())
        }
    }
}

impl Default for WorkPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Clears the published job once all helpers have bowed out; runs on the
/// submitter's normal path and its unwinding path alike.
struct JobGuard<'a> {
    sh: &'a Shared,
    n: usize,
    saw_poison: &'a Cell<bool>,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        // The submitter is done assisting (run() is only entered with the
        // flag clear, so clearing unconditionally is correct).
        IN_POOL_WORKER.with(|w| w.set(false));
        // Stop further claims (e.g. if the submitter is unwinding).
        self.sh.next.store(self.n, Ordering::Relaxed);
        let mut st = self.sh.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.sh.done.wait(st).unwrap();
        }
        // Consume this job's poison while the slot is still ours: the
        // mutex orders the panicking helper's store before our read, and
        // no other job can have published (and panicked) in between.
        self.saw_poison.set(self.sh.poisoned.swap(false, Ordering::Relaxed));
        st.job = None;
        // Wake any queued submitters waiting for the job slot.
        self.sh.done.notify_all();
    }
}

fn worker_loop(sh: Arc<Shared>, id: usize) {
    IN_POOL_WORKER.with(|w| w.set(true));
    // Opt-in locality: worker `id` of either pool sits on core
    // `id % cores` (the gen and gather pools deliberately share the
    // mapping — their thread budgets are split, not stacked).
    maybe_pin_to_core(id);
    crate::obs::trace::set_track(match sh.kind {
        PoolKind::Gen => crate::obs::trace::Track::PoolWorker(id as u16),
        PoolKind::Gather => crate::obs::trace::Track::GatherWorker(id as u16),
    });
    let mut seen = 0u64;
    let mut st = sh.state.lock().unwrap();
    loop {
        while !st.shutdown && !(st.job.is_some() && st.epoch != seen) {
            st = sh.start.wait(st).unwrap();
        }
        if st.shutdown {
            return;
        }
        let job = st.job.expect("job present");
        seen = st.epoch;
        if id >= job.helpers {
            // Not participating in this job; park again.
            continue;
        }
        drop(st);
        // SAFETY: the submitter keeps the closure alive until `remaining`
        // reaches zero, which requires this worker's decrement below.
        let f = unsafe { &*job.f };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _span = crate::obs::trace::span(job.label);
            loop {
                let start = sh.next.fetch_add(job.chunk, Ordering::Relaxed);
                if start >= job.n {
                    break;
                }
                for i in start..(start + job.chunk).min(job.n) {
                    f(i);
                }
            }
        }));
        if res.is_err() {
            sh.poisoned.store(true, Ordering::Relaxed);
            // Stop the job early; other claimants bail out at once.
            sh.next.store(job.n, Ordering::Relaxed);
        }
        st = sh.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            sh.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_once() {
        let n = 10_000;
        let counters: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        WorkPool::global().run(n, 8, 64, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_single_thread_and_empty() {
        let hits = AtomicU64::new(0);
        WorkPool::global().run(5, 1, 2, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        WorkPool::global().run(0, 4, 2, |_| panic!("should not run"));
    }

    #[test]
    fn map_collect_preserves_order() {
        let items: Vec<u64> = (0..5000).collect();
        let doubled = WorkPool::global().map_collect(items.len(), 8, 64, |i| items[i] * 2);
        assert_eq!(doubled.len(), items.len());
        for (i, v) in doubled.iter().enumerate() {
            assert_eq!(*v, 2 * i as u64);
        }
    }

    #[test]
    fn steady_state_spawns_no_threads() {
        let pool = WorkPool::new();
        pool.run(1000, 4, 8, |_| {});
        let after_first = pool.total_spawned();
        assert!(after_first >= 1, "first job should have grown the pool");
        for _ in 0..10 {
            pool.run(1000, 4, 8, |_| {});
        }
        assert_eq!(pool.total_spawned(), after_first, "steady-state jobs must not spawn");
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkPool::new();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(1000, 4, 1, |i| {
                if i == 500 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // The pool must still be usable after a poisoned job.
        let hits = AtomicU64::new(0);
        pool.run(100, 4, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn default_threads_positive_and_cached() {
        assert!(default_threads() >= 1);
        assert_eq!(default_threads(), default_threads());
    }

    #[test]
    fn worker_slot_is_stable_per_thread_and_distinct_across_threads() {
        let mine = worker_slot();
        assert_eq!(mine, worker_slot(), "slot must be sticky");
        let other = std::thread::spawn(worker_slot).join().unwrap();
        assert_ne!(mine, other, "each thread gets its own slot");
    }

    #[test]
    fn pinned_slot_overrides_monotonic_assignment() {
        // Two successive "speculator" threads pin the same reserved slot:
        // both must read it back (stability across respawns), and the
        // reserved range must not collide with monotonic ids.
        for _ in 0..2 {
            let got = std::thread::spawn(|| {
                pin_worker_slot(speculator_slot(0));
                worker_slot()
            })
            .join()
            .unwrap();
            assert_eq!(got, speculator_slot(0));
        }
        assert!(speculator_slot(0) > 1 << 19, "reserved range sits above monotonic ids");
        assert_ne!(speculator_slot(0), speculator_slot(1));
    }

    #[test]
    fn core_pinning_is_opt_in_and_applies_on_linux() {
        // Disabled (the default unless GG_PIN_CORES is exported):
        // maybe_pin_to_core must be a no-op.
        if std::env::var("GG_PIN_CORES").is_err() {
            assert!(!pin_cores_enabled());
            assert!(!maybe_pin_to_core(3));
        }
        // The raw affinity call itself, on a sacrificial thread so the
        // test harness threads stay unpinned.
        let ok = std::thread::spawn(|| pin_current_thread_to(0)).join().unwrap();
        if cfg!(target_os = "linux") {
            assert!(ok, "pinning to core 0 must succeed on linux");
        } else {
            assert!(!ok, "non-linux platforms report unpinned");
        }
        // Out-of-range core id is rejected, not UB.
        assert!(!pin_current_thread_to(1 << 20));
    }

    #[test]
    fn row_chunks_cover_every_row_once() {
        let rows = 1000;
        let stride = 7;
        let mut out = vec![0u64; rows * stride];
        WorkPool::global().run_row_chunks(&mut out, stride, 8, 16, |r0, sub| {
            for (j, row) in sub.chunks_mut(stride).enumerate() {
                for (k, v) in row.iter_mut().enumerate() {
                    *v += ((r0 + j) * stride + k) as u64 + 1;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1, "element {i} written once with its value");
        }
        // Serial fallback path (threads = 1) produces the same bytes.
        let mut serial = vec![0u64; rows * stride];
        WorkPool::global().run_row_chunks(&mut serial, stride, 1, 16, |r0, sub| {
            for (j, row) in sub.chunks_mut(stride).enumerate() {
                for (k, v) in row.iter_mut().enumerate() {
                    *v += ((r0 + j) * stride + k) as u64 + 1;
                }
            }
        });
        assert_eq!(out, serial);
    }
}
