//! FxHash-style fast hashing for hot-path maps (the `fxhash`/`rustc-hash`
//! crates are unavailable offline; std's SipHash is also randomly seeded
//! per process, which would make engine output ordering nondeterministic).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher (FNV-style stream, strong final mix).
#[derive(Default)]
pub struct FxHasher(u64);

const K: u64 = 0x100_0000_01b3;

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        crate::util::rng::mix64(self.0)
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(K);
        }
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.0 = (self.0 ^ v as u64).wrapping_mul(K);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = (self.0 ^ v).wrapping_mul(K);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

/// Hash any `Hash` value with the Fx hasher (deterministic across runs).
pub fn fxhash<T: std::hash::Hash>(v: &T) -> u64 {
    let mut h = FxHasher(0xcbf2_9ce4_8422_2325);
    v.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_and_is_deterministic() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..100 {
            m.insert(i, (i * 2) as u32);
        }
        assert_eq!(m.get(&50), Some(&100));
        assert_eq!(fxhash(&42u64), fxhash(&42u64));
        assert_ne!(fxhash(&42u64), fxhash(&43u64));
    }

    #[test]
    fn distribution_is_reasonable() {
        // Sequential keys should spread across buckets.
        let mut buckets = [0u32; 16];
        for i in 0..16_000u64 {
            buckets[(fxhash(&i) % 16) as usize] += 1;
        }
        assert!(buckets.iter().all(|&c| c > 700), "{buckets:?}");
    }
}
