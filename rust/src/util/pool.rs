//! Lightweight data-parallel helpers (rayon is unavailable offline).
//!
//! Built on `std::thread::scope` with an atomic work index, so closures can
//! borrow from the caller's stack and no persistent pool management is
//! needed. Used by the MapReduce engine's map phase and the graph
//! generators.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: `GG_THREADS` env override,
/// else available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("GG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every index in `0..n` using `threads` OS threads, dynamic
/// chunked scheduling. `f` must be `Sync` (called concurrently).
pub fn parallel_for(n: usize, threads: usize, chunk: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map over a slice, preserving order. Results are written to
/// pre-sized slots so no post-hoc sort is needed.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    // Single-thread fast path: no spawn, no mutex (§Perf — this testbed
    // exposes one core, so this is the common case).
    if threads <= 1 || n <= 1 {
        return items.iter().map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // Each thread computes into a local Vec<(idx, R)>, then results are
    // placed by index. Keeps everything safe-rust at negligible cost.
    let next = AtomicUsize::new(0);
    let chunk = (n / (threads.max(1) * 8)).max(1);
    let collected: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..threads.max(1).min(n.max(1)) {
            s.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + chunk).min(n) {
                        local.push((i, f(&items[i])));
                    }
                }
                collected.lock().unwrap().extend(local);
            });
        }
    });
    for (i, r) in collected.into_inner().unwrap() {
        out[i] = Some(r);
    }
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 10_000;
        let counters: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 8, 64, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_and_empty() {
        let hits = AtomicU64::new(0);
        parallel_for(5, 1, 2, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        parallel_for(0, 4, 2, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..5000).collect();
        let doubled = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(doubled.len(), items.len());
        for (i, v) in doubled.iter().enumerate() {
            assert_eq!(*v, 2 * i as u64);
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
