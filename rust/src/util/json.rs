//! Minimal JSON parser and writer.
//!
//! `serde`/`serde_json` are not available in this offline environment, so
//! the config system ([`crate::config`]), the AOT artifact metadata reader
//! ([`crate::train::runtime`]) and the metrics reporters use this small,
//! dependency-free implementation instead. It supports the full JSON data
//! model with the usual relaxations none (strict), and is more than fast
//! enough for configuration-sized documents.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` for deterministic
/// serialization (stable diffs in committed reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors --------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `None` on non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Nested lookup by dotted path, e.g. `"train.batch_size"`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ----------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (input is &str, so valid).
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| self.err("utf8"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(v.get_path("d.e"), Some(&Json::Null));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A 😀"));
        // And the writer escapes them back parseably.
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "{'a': 1}"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5e-1").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn builder_and_pretty() {
        let mut v = Json::obj();
        v.set("name", "graphgen+").set("workers", 8u64).set("ratio", 1.3);
        let pretty = v.to_pretty();
        assert!(pretty.contains("\"workers\": 8"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
