//! Planted-partition generator with zipf degree skew.
//!
//! Nodes are split into `c` communities; each edge keeps both endpoints in
//! one community with probability `p_in` (default 0.9). Endpoints within a
//! community are drawn zipf(1.05), giving mild hubs. Node labels are the
//! community ids, so a GCN trained on sampled subgraphs has real signal to
//! learn — this is the workload behind the end-to-end example (E7).

use crate::graph::edgelist::EdgeList;
use crate::graph::NodeId;
use crate::util::rng::{mix2, Xoshiro256};

use super::Generated;

const P_IN: f64 = 0.9;
const ZIPF_S: f64 = 1.05;

/// Generate `n` nodes in `c` communities with ~`num_edges` directed edges
/// before symmetrization.
pub fn generate(n: NodeId, num_edges: u64, c: u32, seed: u64) -> Generated {
    assert!(c >= 1 && (c as u64) <= n as u64, "need 1 <= c <= n");
    let mut rng = Xoshiro256::seed_from_u64(mix2(seed, 0x9_1a_27));
    // Community assignment: contiguous blocks, then a shuffled id map so
    // community is NOT derivable from node-id ranges (tests rely on the
    // labels array, as real pipelines would).
    let mut perm: Vec<NodeId> = (0..n).collect();
    rng.shuffle(&mut perm);
    let block = (n as u64).div_ceil(c as u64) as u32;
    let mut labels = vec![0u32; n as usize];
    // members[k] = node ids in community k
    let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); c as usize];
    for (i, &node) in perm.iter().enumerate() {
        let k = (i as u32 / block).min(c - 1);
        labels[node as usize] = k;
        members[k as usize].push(node);
    }

    let mut el = EdgeList::with_capacity(n, num_edges as usize * 2);
    let pick = |rng: &mut Xoshiro256, comm: &[NodeId]| -> NodeId {
        comm[rng.gen_zipf(comm.len() as u64, ZIPF_S) as usize]
    };
    for _ in 0..num_edges {
        if rng.gen_bool(P_IN) {
            // intra-community edge
            let k = rng.gen_range(c as u64) as usize;
            if members[k].len() < 2 {
                continue;
            }
            let (a, b) = (pick(&mut rng, &members[k]), pick(&mut rng, &members[k]));
            if a != b {
                el.push(a, b);
            }
        } else {
            // cross-community edge
            let k1 = rng.gen_range(c as u64) as usize;
            let k2 = rng.gen_range(c as u64) as usize;
            if members[k1].is_empty() || members[k2].is_empty() {
                continue;
            }
            let (a, b) = (pick(&mut rng, &members[k1]), pick(&mut rng, &members[k2]));
            if a != b {
                el.push(a, b);
            }
        }
    }
    el.symmetrize();
    Generated {
        name: format!("planted(n={n},e={num_edges},c={c},seed={seed})"),
        edges: el,
        labels: Some(labels),
        num_classes: c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_cover_all_classes() {
        let g = generate(1000, 8000, 8, 1);
        let labels = g.labels.as_ref().unwrap();
        assert_eq!(labels.len(), 1000);
        let mut seen = vec![false; 8];
        for &l in labels {
            assert!(l < 8);
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(g.num_classes, 8);
    }

    #[test]
    fn homophily_holds() {
        let g = generate(2000, 16000, 4, 9);
        let labels = g.labels.as_ref().unwrap();
        let mut same = 0u64;
        for e in &g.edges.edges {
            if labels[e.src as usize] == labels[e.dst as usize] {
                same += 1;
            }
        }
        let frac = same as f64 / g.edges.len() as f64;
        assert!(frac > 0.8, "intra-community fraction {frac} too low");
    }

    #[test]
    fn community_not_contiguous_in_ids() {
        let g = generate(256, 1024, 4, 5);
        let labels = g.labels.as_ref().unwrap();
        // First 64 ids should not all share a label (shuffled mapping).
        let first = labels[0];
        assert!(labels[..64].iter().any(|&l| l != first));
    }

    #[test]
    fn single_community_degenerates_gracefully() {
        let g = generate(100, 500, 1, 2);
        assert!(g.edges.len() > 0);
        assert!(g.labels.unwrap().iter().all(|&l| l == 0));
    }
}
