//! Barabási–Albert preferential attachment: each new node attaches to `m`
//! existing nodes with probability proportional to their current degree.
//! Classic scale-free graphs; used for generator-diversity in tests and
//! the fraud-detection example.

use crate::graph::edgelist::EdgeList;
use crate::graph::NodeId;
use crate::util::rng::{mix2, Xoshiro256};

use super::Generated;

/// Generate a BA graph with `n` nodes, `m` attachments per node.
pub fn generate(n: NodeId, m: u32, seed: u64) -> Generated {
    let m = m.max(1);
    assert!(n as u64 > m as u64, "need n > m");
    let mut rng = Xoshiro256::seed_from_u64(mix2(seed, 0xba));
    // Repeated-endpoints list: sampling uniformly from it = degree-biased.
    let mut endpoints: Vec<NodeId> = Vec::with_capacity(2 * (n as usize) * m as usize);
    let mut el = EdgeList::with_capacity(n, (n as usize) * m as usize * 2);
    // Seed clique over the first m+1 nodes.
    for i in 0..=m {
        for j in (i + 1)..=m {
            el.push(i, j);
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    for v in (m + 1)..n {
        // Vec + contains (m is small) keeps insertion order deterministic;
        // HashSet iteration order would make the generator seed-unstable.
        let mut chosen: Vec<NodeId> = Vec::with_capacity(m as usize);
        let mut guard = 0;
        while chosen.len() < m as usize && guard < 10 * m {
            let t = endpoints[rng.gen_range(endpoints.len() as u64) as usize];
            if !chosen.contains(&t) {
                chosen.push(t);
            }
            guard += 1;
        }
        for &t in &chosen {
            el.push(v, t);
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    el.symmetrize();
    Generated { name: format!("ba(n={n},m={m},seed={seed})"), edges: el, labels: None, num_classes: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_and_edge_counts() {
        let g = generate(500, 4, 11);
        assert_eq!(g.edges.num_nodes, 500);
        // Roughly n*m undirected edges → 2*n*m directed after symmetrize.
        assert!(g.edges.len() as u64 > 2 * 450 * 4);
    }

    #[test]
    fn early_nodes_become_hubs() {
        let g = generate(2000, 4, 3);
        let degs = g.edges.degrees();
        let early_max = degs[..10].iter().max().copied().unwrap();
        let late_max = degs[1990..].iter().max().copied().unwrap();
        assert!(
            early_max > 3 * late_max,
            "preferential attachment should favor early nodes ({early_max} vs {late_max})"
        );
    }
}
