//! R-MAT generator (Chakrabarti, Zhan & Faloutsos, SDM'04) with the
//! Graph500 parameters (a, b, c) = (0.57, 0.19, 0.19): recursively drop
//! each edge into a quadrant of the adjacency matrix. Produces the heavy-
//! tailed degree distribution that motivates the paper's tree-reduction
//! strategy (hot nodes).

use crate::graph::edgelist::EdgeList;
use crate::graph::NodeId;
use crate::util::rng::{mix2, Xoshiro256};
use crate::util::workpool::{default_threads, WorkPool};

use super::Generated;

const A: f64 = 0.57;
const B: f64 = 0.19;
const C: f64 = 0.19;

/// Generate an undirected (symmetrized) R-MAT graph with `n` nodes
/// (rounded up to a power of two internally) and ~`num_edges` directed
/// edges before dedup/symmetrization.
pub fn generate(n: NodeId, num_edges: u64, seed: u64) -> Generated {
    let scale = (n.max(2) as f64).log2().ceil() as u32;
    // Sample edges in parallel chunks on the persistent pool; each
    // chunk's RNG is derived from (seed, chunk) so the result is
    // independent of thread count.
    let chunk_size = 64 * 1024;
    let num_chunks = num_edges.div_ceil(chunk_size) as usize;
    let per_chunk = WorkPool::global().map_collect(num_chunks, default_threads(), 1, |ci| {
        let ci = ci as u64;
        let mut rng = Xoshiro256::seed_from_u64(mix2(seed, ci));
        let count = chunk_size.min(num_edges - ci * chunk_size);
        let mut edges = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let (mut x, mut y) = (0u64, 0u64);
            for _ in 0..scale {
                let r = rng.gen_f64();
                let (dx, dy) = if r < A {
                    (0, 0)
                } else if r < A + B {
                    (0, 1)
                } else if r < A + B + C {
                    (1, 0)
                } else {
                    (1, 1)
                };
                x = (x << 1) | dx;
                y = (y << 1) | dy;
            }
            // Fold the power-of-two id space onto [0, n).
            let src = (x % n as u64) as NodeId;
            let dst = (y % n as u64) as NodeId;
            edges.push((src, dst));
        }
        edges
    });
    let mut el = EdgeList::with_capacity(n, num_edges as usize * 2);
    for chunk in per_chunk {
        for (s, d) in chunk {
            el.push(s, d);
        }
    }
    el.symmetrize();
    Generated { name: format!("rmat(n={n},e={num_edges},seed={seed})"), edges: el, labels: None, num_classes: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_bounds() {
        let g = generate(1000, 8000, 42);
        assert_eq!(g.edges.num_nodes, 1000);
        assert!(g.edges.edges.iter().all(|e| e.src < 1000 && e.dst < 1000));
        // Symmetrized: reverse of every edge present.
        let set: std::collections::HashSet<_> = g.edges.edges.iter().copied().collect();
        assert!(g.edges.edges.iter().all(|e| set.contains(&e.reversed())));
    }

    #[test]
    fn skew_exists() {
        let g = generate(4096, 64 * 4096, 7);
        let degs = g.edges.degrees();
        let max = *degs.iter().max().unwrap() as f64;
        let mean = degs.iter().map(|&d| d as f64).sum::<f64>() / degs.len() as f64;
        // R-MAT should produce hubs well above the mean degree.
        assert!(max > 6.0 * mean, "max {max} mean {mean}: no skew?");
    }

    #[test]
    fn independent_of_thread_count() {
        // Pool chunking is keyed by chunk index, not thread; verify
        // via the GG_THREADS env being irrelevant to the hash of output.
        let a = generate(512, 4096, 3);
        let b = generate(512, 4096, 3);
        assert_eq!(a.edges.edges, b.edges.edges);
    }
}
