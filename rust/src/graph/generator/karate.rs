//! Zachary's karate club (34 nodes, 78 undirected edges) — the classic
//! *real* social graph, embedded so examples and tests exercise the full
//! pipeline on non-synthetic data without any network access. Labels are
//! the historical club split (Mr. Hi = 0 vs. Officer = 1).
//!
//! Source: W. W. Zachary, "An information flow model for conflict and
//! fission in small groups", J. Anthropological Research 33 (1977).

use crate::graph::edgelist::EdgeList;

use super::Generated;

/// 1-indexed undirected edges, as published.
const EDGES_1IDX: [(u32, u32); 78] = [
    (1, 2), (1, 3), (2, 3), (1, 4), (2, 4), (3, 4), (1, 5), (1, 6), (1, 7),
    (5, 7), (6, 7), (1, 8), (2, 8), (3, 8), (4, 8), (1, 9), (3, 9), (3, 10),
    (1, 11), (5, 11), (6, 11), (1, 12), (1, 13), (4, 13), (1, 14), (2, 14),
    (3, 14), (4, 14), (6, 17), (7, 17), (1, 18), (2, 18), (1, 20), (2, 20),
    (1, 22), (2, 22), (24, 26), (25, 26), (3, 28), (24, 28), (25, 28),
    (3, 29), (24, 30), (27, 30), (2, 31), (9, 31), (1, 32), (25, 32),
    (26, 32), (29, 32), (3, 33), (9, 33), (15, 33), (16, 33), (19, 33),
    (21, 33), (23, 33), (24, 33), (30, 33), (31, 33), (32, 33), (9, 34),
    (10, 34), (14, 34), (15, 34), (16, 34), (19, 34), (20, 34), (21, 34),
    (23, 34), (24, 34), (27, 34), (28, 34), (29, 34), (30, 34), (31, 34),
    (32, 34), (33, 34),
];

/// Mr. Hi's faction, 1-indexed (everyone else sided with the officer).
const MR_HI: [u32; 17] = [1, 2, 3, 4, 5, 6, 7, 8, 11, 12, 13, 14, 17, 18, 20, 22, 9];

pub fn generate() -> Generated {
    let mut el = EdgeList::with_capacity(34, 78 * 2);
    for &(a, b) in &EDGES_1IDX {
        el.push(a - 1, b - 1);
    }
    el.symmetrize();
    let mut labels = vec![1u32; 34];
    for &v in &MR_HI {
        labels[(v - 1) as usize] = 0;
    }
    // Node 9 (1-indexed) historically joined the officer's club despite
    // ties to Mr. Hi; keep the standard assignment.
    labels[8] = 1;
    Generated { name: "karate".to_string(), edges: el, labels: Some(labels), num_classes: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_counts() {
        let g = generate();
        assert_eq!(g.edges.num_nodes, 34);
        assert_eq!(g.edges.len(), 78 * 2); // symmetrized
        let labels = g.labels.as_ref().unwrap();
        assert_eq!(labels.iter().filter(|&&l| l == 0).count(), 16);
        assert_eq!(labels.iter().filter(|&&l| l == 1).count(), 18);
    }

    #[test]
    fn node_33_is_the_hub() {
        // 0-indexed node 33 ("node 34", the officer) has degree 17.
        let g = generate();
        let degs = g.edges.degrees();
        assert_eq!(degs[33], 17);
        assert_eq!(degs[0], 16); // Mr. Hi
    }
}
