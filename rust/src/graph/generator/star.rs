//! Star / multi-hub graphs: `hubs` central nodes each connected to every
//! other node, plus a sparse random background. The adversarial hot-node
//! workload for the E4 tree-reduction experiments — one node's neighbor
//! list dominates all work.

use crate::graph::edgelist::EdgeList;
use crate::graph::NodeId;
use crate::util::rng::{mix2, Xoshiro256};

use super::Generated;

pub fn generate(n: NodeId, hubs: u32, seed: u64) -> Generated {
    assert!(n > hubs, "need n > hubs");
    let mut rng = Xoshiro256::seed_from_u64(mix2(seed, 0x57a7));
    let mut el = EdgeList::with_capacity(n, (n as usize) * (hubs as usize + 1));
    for h in 0..hubs {
        for v in hubs..n {
            el.push(h, v);
        }
    }
    // Background ring + sparse chords so non-hub nodes have >1 neighbor.
    for v in hubs..n {
        let next = if v + 1 == n { hubs } else { v + 1 };
        el.push(v, next);
        if rng.gen_bool(0.25) {
            let w = hubs + rng.gen_range((n - hubs) as u64) as NodeId;
            if w != v {
                el.push(v, w);
            }
        }
    }
    el.symmetrize();
    Generated { name: format!("star(n={n},hubs={hubs},seed={seed})"), edges: el, labels: None, num_classes: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hubs_dominate_degree() {
        let g = generate(1000, 2, 1);
        let degs = g.edges.degrees();
        assert!(degs[0] >= 998 - 2);
        assert!(degs[1] >= 998 - 2);
        let non_hub_max = degs[2..].iter().max().copied().unwrap();
        assert!(non_hub_max < 20);
    }
}
