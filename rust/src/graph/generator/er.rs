//! Erdős–Rényi G(n, m): `num_edges` uniform random pairs. The no-skew
//! control workload for the tree-reduction ablation (E4) — tree reduction
//! should win little here, unlike on hot-node graphs.

use crate::graph::edgelist::EdgeList;
use crate::graph::NodeId;
use crate::util::rng::{mix2, Xoshiro256};

use super::Generated;

pub fn generate(n: NodeId, num_edges: u64, seed: u64) -> Generated {
    assert!(n >= 2);
    let mut rng = Xoshiro256::seed_from_u64(mix2(seed, 0xe6));
    let mut el = EdgeList::with_capacity(n, num_edges as usize * 2);
    for _ in 0..num_edges {
        let a = rng.gen_range(n as u64) as NodeId;
        let b = rng.gen_range(n as u64) as NodeId;
        if a != b {
            el.push(a, b);
        }
    }
    el.symmetrize();
    Generated { name: format!("er(n={n},e={num_edges},seed={seed})"), edges: el, labels: None, num_classes: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degrees_are_flat() {
        let g = generate(1000, 16_000, 5);
        let degs = g.edges.degrees();
        let mean = degs.iter().map(|&d| d as f64).sum::<f64>() / degs.len() as f64;
        let max = *degs.iter().max().unwrap() as f64;
        // Poisson-ish: max should stay within a small factor of the mean.
        assert!(max < 3.0 * mean, "unexpected skew: max {max} mean {mean}");
    }
}
