//! Deterministic synthetic graph generators.
//!
//! These stand in for the paper's 530 M-node / 5 B-edge production graph
//! (DESIGN.md §2): the generation engines' behaviour depends on degree
//! skew, seed count and fanout, all of which these generators control.
//!
//! * [`rmat`] — R-MAT, the classic skewed power-law-ish generator; the
//!   default workload for the E1/E2 throughput experiments (hot nodes).
//! * [`planted`] — planted-partition communities with zipf degrees and
//!   community-correlated labels, so the end-to-end GCN actually learns.
//! * [`ba`] — Barabási–Albert preferential attachment.
//! * [`er`] — Erdős–Rényi G(n, m), the no-skew control.
//! * [`star`] — adversarial hot-node graphs for the E4 tree-reduction
//!   ablation.
//! * [`karate`] — Zachary's karate club, the embedded *real* graph used by
//!   the quickstart example and tests.

pub mod ba;
pub mod er;
pub mod karate;
pub mod planted;
pub mod rmat;
pub mod star;

use super::edgelist::EdgeList;
use super::csr::Csr;

/// Uniform description of a generated workload graph.
pub struct Generated {
    pub name: String,
    pub edges: EdgeList,
    /// Ground-truth community/label per node, when the generator has one.
    pub labels: Option<Vec<u32>>,
    pub num_classes: u32,
}

impl Generated {
    pub fn csr(&self) -> Csr {
        Csr::from_edge_list(&self.edges)
    }
}

/// Parse a generator spec string used by the CLI and benches:
/// `rmat:n=65536,e=524288`, `planted:n=10000,e=80000,c=8`,
/// `star:n=1000,hubs=4`, `er:n=1000,e=8000`, `ba:n=1000,m=8`, `karate`.
pub fn from_spec(spec: &str, seed: u64) -> anyhow::Result<Generated> {
    let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
    let mut kv = std::collections::BTreeMap::new();
    for part in rest.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad generator param '{part}' in '{spec}'"))?;
        kv.insert(k.to_string(), v.to_string());
    }
    let get = |key: &str, default: u64| -> anyhow::Result<u64> {
        match kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad value for {key} in '{spec}': {e}")),
        }
    };
    match kind {
        "rmat" => {
            let n = get("n", 1 << 14)?;
            let e = get("e", n * 8)?;
            Ok(rmat::generate(n as u32, e, seed))
        }
        "planted" => {
            let n = get("n", 1 << 13)?;
            let e = get("e", n * 8)?;
            let c = get("c", 8)?;
            Ok(planted::generate(n as u32, e, c as u32, seed))
        }
        "ba" => {
            let n = get("n", 1 << 13)?;
            let m = get("m", 8)?;
            Ok(ba::generate(n as u32, m as u32, seed))
        }
        "er" => {
            let n = get("n", 1 << 13)?;
            let e = get("e", n * 8)?;
            Ok(er::generate(n as u32, e, seed))
        }
        "star" => {
            let n = get("n", 1 << 12)?;
            let hubs = get("hubs", 1)?;
            Ok(star::generate(n as u32, hubs as u32, seed))
        }
        "karate" => Ok(karate::generate()),
        other => anyhow::bail!("unknown generator '{other}' (spec '{spec}')"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_dispatches() {
        let g = from_spec("rmat:n=256,e=1024", 1).unwrap();
        assert_eq!(g.edges.num_nodes, 256);
        assert!(g.edges.len() >= 1024); // symmetrized
        let g = from_spec("karate", 0).unwrap();
        assert_eq!(g.edges.num_nodes, 34);
        assert!(from_spec("nope", 0).is_err());
        assert!(from_spec("rmat:n=abc", 0).is_err());
        assert!(from_spec("rmat:n", 0).is_err());
    }

    #[test]
    fn generators_are_deterministic() {
        for spec in ["rmat:n=128,e=512", "planted:n=128,e=512,c=4", "ba:n=128,m=4", "er:n=128,e=512", "star:n=64,hubs=2"] {
            let a = from_spec(spec, 7).unwrap();
            let b = from_spec(spec, 7).unwrap();
            assert_eq!(a.edges.edges, b.edges.edges, "{spec} not deterministic");
            let c = from_spec(spec, 8).unwrap();
            assert_ne!(a.edges.edges, c.edges.edges, "{spec} ignores seed");
        }
    }
}
