//! Procedural node features and labels.
//!
//! At the paper's scale (530 M nodes) feature matrices cannot live in
//! worker memory alongside the graph; production systems fetch them from a
//! feature store. We model that with a *procedural* store: features are a
//! deterministic function of (node id, label), generated on demand —
//! `feature(v) = centroid(label(v)) + noise(v)` — so
//!
//! * no O(|V| · D) memory is spent,
//! * every worker computes identical features without communication, and
//! * labels stay predictable-from-features, giving the GCN real signal.

use crate::util::rng::{mix2, mix3, Xoshiro256};

use super::NodeId;

/// Procedural feature/label store.
#[derive(Debug, Clone)]
pub struct FeatureStore {
    pub dim: usize,
    pub num_classes: u32,
    seed: u64,
    /// Per-node labels. For generators without ground truth we synthesize
    /// pseudo-labels by hashing (still deterministic, near-zero signal).
    labels: LabelSource,
    /// Class centroid strength relative to unit noise.
    pub signal: f32,
}

#[derive(Debug, Clone)]
enum LabelSource {
    Table(std::sync::Arc<Vec<u32>>),
    Hash,
}

impl FeatureStore {
    /// Store backed by ground-truth labels (e.g. planted partition, karate).
    pub fn with_labels(dim: usize, num_classes: u32, labels: Vec<u32>, seed: u64) -> Self {
        assert!(num_classes >= 1);
        Self {
            dim,
            num_classes,
            seed,
            labels: LabelSource::Table(std::sync::Arc::new(labels)),
            signal: 2.0,
        }
    }

    /// Store with hash pseudo-labels (for unlabeled generators; training on
    /// these runs the full pipeline but converges to the class prior).
    pub fn hashed(dim: usize, num_classes: u32, seed: u64) -> Self {
        assert!(num_classes >= 1);
        Self { dim, num_classes, seed, labels: LabelSource::Hash, signal: 2.0 }
    }

    #[inline]
    pub fn label(&self, v: NodeId) -> u32 {
        match &self.labels {
            LabelSource::Table(t) => t[v as usize],
            LabelSource::Hash => (mix2(self.seed ^ 0x1abe1, v as u64) % self.num_classes as u64) as u32,
        }
    }

    /// Write the feature vector of `v` into `out` (len == dim).
    ///
    /// Component `i` = `signal * centroid(label, i) + noise(v, i)` where
    /// centroid components are ±1 from a hash of (class, i) and noise is
    /// N(0, 1) from a per-node generator.
    pub fn write_feature(&self, v: NodeId, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        let label = self.label(v);
        let mut rng = Xoshiro256::seed_from_u64(mix3(self.seed, 0xfea7, v as u64));
        for (i, slot) in out.iter_mut().enumerate() {
            let sign = if mix3(self.seed, label as u64, i as u64) & 1 == 0 { 1.0 } else { -1.0 };
            *slot = self.signal * sign + rng.gen_normal() as f32;
        }
    }

    /// Allocating convenience wrapper around [`write_feature`](Self::write_feature).
    pub fn feature(&self, v: NodeId) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.write_feature(v, &mut out);
        out
    }

    /// Pooled batch gather: write the rows of `ids`, in order, contiguously
    /// into `out` (`ids.len() * dim` floats). Hot paths use this instead of
    /// allocating per-node [`feature`](Self::feature) calls.
    pub fn gather_into(&self, ids: &[NodeId], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.dim, "gather buffer size mismatch");
        for (i, &v) in ids.iter().enumerate() {
            self.write_feature(v, &mut out[i * self.dim..(i + 1) * self.dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_node() {
        let fs = FeatureStore::hashed(16, 4, 7);
        assert_eq!(fs.feature(42), fs.feature(42));
        assert_ne!(fs.feature(42), fs.feature(43));
        assert_eq!(fs.label(42), fs.label(42));
    }

    #[test]
    fn table_labels_pass_through() {
        let fs = FeatureStore::with_labels(8, 3, vec![2, 0, 1], 1);
        assert_eq!(fs.label(0), 2);
        assert_eq!(fs.label(2), 1);
    }

    #[test]
    fn same_class_features_correlate() {
        let labels: Vec<u32> = (0..100).map(|i| i % 2).collect();
        let fs = FeatureStore::with_labels(32, 2, labels, 3);
        // Cosine similarity within class should exceed across class.
        let cos = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            dot / (na * nb)
        };
        let (a0, a2) = (fs.feature(0), fs.feature(2)); // both class 0
        let a1 = fs.feature(1); // class 1
        assert!(cos(&a0, &a2) > cos(&a0, &a1) + 0.2);
    }

    #[test]
    fn gather_into_matches_per_node_rows() {
        let fs = FeatureStore::hashed(8, 4, 13);
        let ids = [4u32, 0, 4, 17];
        let mut bulk = vec![0.0f32; ids.len() * 8];
        fs.gather_into(&ids, &mut bulk);
        for (i, &v) in ids.iter().enumerate() {
            assert_eq!(&bulk[i * 8..(i + 1) * 8], &fs.feature(v)[..]);
        }
        // Empty gather is a no-op.
        fs.gather_into(&[], &mut []);
    }

    #[test]
    fn hashed_labels_in_range_and_mixed() {
        let fs = FeatureStore::hashed(4, 5, 11);
        let mut seen = vec![false; 5];
        for v in 0..200u32 {
            let l = fs.label(v);
            assert!(l < 5);
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
