//! Growable edge-list container — the raw form graphs are generated and
//! shuffled in before being frozen into [`crate::graph::csr::Csr`].

use super::{Edge, NodeId};

/// A list of directed edges plus the node-count bound.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    pub num_nodes: NodeId,
    pub edges: Vec<Edge>,
}

impl EdgeList {
    pub fn new(num_nodes: NodeId) -> Self {
        Self { num_nodes, edges: Vec::new() }
    }

    pub fn with_capacity(num_nodes: NodeId, cap: usize) -> Self {
        Self { num_nodes, edges: Vec::with_capacity(cap) }
    }

    pub fn push(&mut self, src: NodeId, dst: NodeId) {
        debug_assert!(src < self.num_nodes && dst < self.num_nodes);
        self.edges.push(Edge::new(src, dst));
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Sort by (src, dst) and remove duplicate edges and self-loops.
    pub fn sort_dedup(&mut self) {
        self.edges.retain(|e| e.src != e.dst);
        self.edges.sort_unstable();
        self.edges.dedup();
    }

    /// Make the list symmetric: for every (u, v) ensure (v, u) exists.
    /// Implies [`sort_dedup`](Self::sort_dedup).
    pub fn symmetrize(&mut self) {
        let mut rev: Vec<Edge> = self.edges.iter().map(|e| e.reversed()).collect();
        self.edges.append(&mut rev);
        self.sort_dedup();
    }

    /// Out-degree of every node.
    pub fn degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_nodes as usize];
        for e in &self.edges {
            d[e.src as usize] += 1;
        }
        d
    }

    /// Highest-degree nodes as (node, degree), descending — used to locate
    /// hot nodes for the tree-reduction experiments.
    pub fn top_degree_nodes(&self, k: usize) -> Vec<(NodeId, u32)> {
        let degs = self.degrees();
        let mut idx: Vec<NodeId> = (0..self.num_nodes).collect();
        idx.sort_unstable_by_key(|&n| std::cmp::Reverse(degs[n as usize]));
        idx.truncate(k);
        idx.into_iter().map(|n| (n, degs[n as usize])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_len() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        assert_eq!(el.len(), 2);
        assert!(!el.is_empty());
    }

    #[test]
    fn sort_dedup_removes_loops_and_dupes() {
        let mut el = EdgeList::new(4);
        el.push(1, 2);
        el.push(1, 2);
        el.push(3, 3); // self-loop
        el.push(0, 1);
        el.sort_dedup();
        assert_eq!(el.edges, vec![Edge::new(0, 1), Edge::new(1, 2)]);
    }

    #[test]
    fn symmetrize_adds_reverse_edges() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        el.symmetrize();
        assert_eq!(
            el.edges,
            vec![
                Edge::new(0, 1),
                Edge::new(1, 0),
                Edge::new(1, 2),
                Edge::new(2, 1)
            ]
        );
    }

    #[test]
    fn degrees_and_top_nodes() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(0, 3);
        el.push(1, 2);
        let d = el.degrees();
        assert_eq!(d, vec![3, 1, 0, 0]);
        let top = el.top_degree_nodes(2);
        assert_eq!(top[0], (0, 3));
        assert_eq!(top[1], (1, 1));
    }

    #[test]
    fn edge_canonical() {
        assert_eq!(Edge::new(5, 2).canonical(), Edge::new(2, 5));
        assert_eq!(Edge::new(2, 5).canonical(), Edge::new(2, 5));
    }
}
