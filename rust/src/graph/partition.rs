//! Graph partitioning — step (1) of the GraphGen+ workflow.
//!
//! The coordinator distributes the graph's *edges* across workers (the
//! paper is explicitly edge-centric). Three strategies are provided:
//!
//! * [`Strategy::Hash`] — owner = `hash(src) % w`. The paper's default:
//!   cheap, stateless, and every worker can compute it locally.
//! * [`Strategy::Range`] — contiguous node ranges. Minimizes cross-worker
//!   "communication" for id-clustered graphs but inherits id-order skew —
//!   the strawman the balance table fixes at the seed level.
//! * [`Strategy::EdgeBalanced`] — contiguous node ranges chosen so every
//!   partition gets ~|E|/w edges regardless of degree skew.

use super::csr::Csr;
use super::NodeId;
use crate::util::parallel_scan;
use crate::util::rng::mix2;
use crate::util::stats::Samples;
use crate::util::workpool::{default_threads, WorkPool};

/// Partitioning strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Hash,
    Range,
    EdgeBalanced,
}

impl std::str::FromStr for Strategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hash" => Ok(Strategy::Hash),
            "range" => Ok(Strategy::Range),
            "edge-balanced" | "edge_balanced" => Ok(Strategy::EdgeBalanced),
            other => Err(format!("unknown partition strategy '{other}'")),
        }
    }
}

/// One worker's share of the graph: the set of source nodes it owns.
/// Adjacency stays in the shared read-only [`Csr`]; a partition is the
/// *ownership map* (as in the paper, where each worker holds its shard of
/// the edge table).
#[derive(Debug, Clone)]
pub struct Partition {
    pub worker: usize,
    /// Source nodes owned by this worker (sorted).
    pub nodes: Vec<NodeId>,
    /// Total out-edges over owned nodes.
    pub num_edges: u64,
}

/// Output of [`partition_graph`].
#[derive(Debug, Clone)]
pub struct Partitioned {
    pub strategy: Strategy,
    pub parts: Vec<Partition>,
}

impl Partitioned {
    /// Load-imbalance factor over per-partition edge counts (max/mean).
    pub fn edge_imbalance(&self) -> f64 {
        Samples::from_iter(self.parts.iter().map(|p| p.num_edges as f64)).imbalance()
    }

    /// Worker owning node `v` (linear in #workers for range styles).
    pub fn owner_of(&self, v: NodeId, seed: u64) -> usize {
        match self.strategy {
            Strategy::Hash => (mix2(seed, v as u64) % self.parts.len() as u64) as usize,
            _ => self
                .parts
                .iter()
                .position(|p| p.nodes.binary_search(&v).is_ok())
                .expect("node in some partition"),
        }
    }
}

/// Partition `g`'s source nodes over `workers` workers.
pub fn partition_graph(g: &Csr, workers: usize, strategy: Strategy, seed: u64) -> Partitioned {
    partition_graph_par(g, workers, strategy, seed, default_threads())
}

/// [`partition_graph`] with a thread budget. The hash strategy's owner
/// map is a pure per-node function, so it parallelizes, and the
/// per-worker histogram spine is a (parallel) exclusive prefix scan —
/// output identical to the serial build at every thread count. Range and
/// edge-balanced strategies stay sequential (edge-balanced carries a
/// running-total dependency by construction).
pub fn partition_graph_par(
    g: &Csr,
    workers: usize,
    strategy: Strategy,
    seed: u64,
    threads: usize,
) -> Partitioned {
    assert!(workers >= 1);
    let n = g.num_nodes();
    let mut parts: Vec<Partition> = (0..workers)
        .map(|w| Partition { worker: w, nodes: Vec::new(), num_edges: 0 })
        .collect();
    match strategy {
        Strategy::Hash => {
            let pool = WorkPool::global();
            let owner: Vec<u32> = pool.map_collect_labeled(
                n as usize,
                threads,
                4096,
                "partition.owner",
                |v| (mix2(seed, v as u64) % workers as u64) as u32,
            );
            let mut starts = vec![0u32; workers + 1];
            for &w in &owner {
                starts[w as usize + 1] += 1;
            }
            parallel_scan::inclusive_scan(pool, threads, &mut starts);
            for (w, part) in parts.iter_mut().enumerate() {
                part.nodes.reserve_exact((starts[w + 1] - starts[w]) as usize);
            }
            // Stable scatter (ascending node order within each worker):
            // sequential, the per-worker cursors carry the dependency.
            for (v, &w) in owner.iter().enumerate() {
                let part = &mut parts[w as usize];
                part.nodes.push(v as NodeId);
                part.num_edges += g.degree(v as NodeId) as u64;
            }
        }
        Strategy::Range => {
            let block = (n as u64).div_ceil(workers as u64) as NodeId;
            for v in 0..n {
                let w = ((v / block.max(1)) as usize).min(workers - 1);
                parts[w].nodes.push(v);
                parts[w].num_edges += g.degree(v) as u64;
            }
        }
        Strategy::EdgeBalanced => {
            let target = g.num_edges().div_ceil(workers as u64).max(1);
            let mut w = 0usize;
            let mut acc = 0u64;
            for v in 0..n {
                if acc >= target && w + 1 < workers {
                    w += 1;
                    acc = 0;
                }
                parts[w].nodes.push(v);
                parts[w].num_edges += g.degree(v) as u64;
                acc += g.degree(v) as u64;
            }
        }
    }
    Partitioned { strategy, parts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn graph() -> Csr {
        generator::from_spec("rmat:n=1024,e=8192", 3).unwrap().csr()
    }

    fn assert_exact_cover(p: &Partitioned, n: NodeId) {
        let mut seen = vec![0u32; n as usize];
        for part in &p.parts {
            for &v in &part.nodes {
                seen[v as usize] += 1;
            }
            // nodes sorted
            assert!(part.nodes.windows(2).all(|w| w[0] < w[1]));
        }
        assert!(seen.iter().all(|&c| c == 1), "each node owned exactly once");
    }

    #[test]
    fn all_strategies_cover_exactly_once() {
        let g = graph();
        for s in [Strategy::Hash, Strategy::Range, Strategy::EdgeBalanced] {
            let p = partition_graph(&g, 7, s, 42);
            assert_eq!(p.parts.len(), 7);
            assert_exact_cover(&p, g.num_nodes());
            let total: u64 = p.parts.iter().map(|x| x.num_edges).sum();
            assert_eq!(total, g.num_edges());
        }
    }

    #[test]
    fn edge_balanced_beats_range_on_skew() {
        // Hot node 0 → range partitioning dumps all its edges on worker 0.
        let g = generator::from_spec("star:n=2048,hubs=1", 1).unwrap().csr();
        let range = partition_graph(&g, 8, Strategy::Range, 0);
        let balanced = partition_graph(&g, 8, Strategy::EdgeBalanced, 0);
        assert!(
            balanced.edge_imbalance() < range.edge_imbalance(),
            "edge-balanced {} should beat range {}",
            balanced.edge_imbalance(),
            range.edge_imbalance()
        );
        assert!(balanced.edge_imbalance() < 2.1);
    }

    #[test]
    fn owner_lookup_agrees_with_partition() {
        let g = graph();
        for s in [Strategy::Hash, Strategy::Range, Strategy::EdgeBalanced] {
            let p = partition_graph(&g, 5, s, 9);
            for v in (0..g.num_nodes()).step_by(97) {
                let w = p.owner_of(v, 9);
                assert!(p.parts[w].nodes.binary_search(&v).is_ok());
            }
        }
    }

    #[test]
    fn single_worker_gets_everything() {
        let g = graph();
        let p = partition_graph(&g, 1, Strategy::Hash, 0);
        assert_eq!(p.parts[0].nodes.len() as u32, g.num_nodes());
        assert!((p.edge_imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn strategy_parses() {
        assert_eq!("hash".parse::<Strategy>().unwrap(), Strategy::Hash);
        assert_eq!("edge-balanced".parse::<Strategy>().unwrap(), Strategy::EdgeBalanced);
        assert!("bogus".parse::<Strategy>().is_err());
    }
}
