//! Graph I/O: tab-separated edge-list text (interop with the usual SNAP
//! style dumps) and a compact binary format with magic + version header
//! (what the offline baseline and the CLI's `partition` command use).

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::edgelist::EdgeList;
use super::NodeId;

const MAGIC: &[u8; 8] = b"GGPLUS01";

/// Write `el` as `src\tdst\n` lines with a `# nodes: N` header comment.
pub fn save_text(el: &EdgeList, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# nodes: {}", el.num_nodes)?;
    for e in &el.edges {
        writeln!(w, "{}\t{}", e.src, e.dst)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a text edge list. Lines starting with `#` are comments; a
/// `# nodes: N` comment fixes the node count, otherwise max-id+1 is used.
pub fn load_text(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut el = EdgeList::new(0);
    let mut max_id: NodeId = 0;
    let mut declared: Option<NodeId> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if let Some(rest) = t.strip_prefix('#') {
            if let Some(n) = rest.trim().strip_prefix("nodes:") {
                declared = Some(
                    n.trim()
                        .parse()
                        .with_context(|| format!("bad nodes header at line {}", lineno + 1))?,
                );
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = (it.next(), it.next());
        match (a, b) {
            (Some(a), Some(b)) => {
                let src: NodeId =
                    a.parse().with_context(|| format!("bad src at line {}", lineno + 1))?;
                let dst: NodeId =
                    b.parse().with_context(|| format!("bad dst at line {}", lineno + 1))?;
                max_id = max_id.max(src).max(dst);
                el.edges.push(super::Edge::new(src, dst));
            }
            _ => bail!("malformed line {} in {}", lineno + 1, path.display()),
        }
    }
    el.num_nodes = declared.unwrap_or(if el.edges.is_empty() { 0 } else { max_id + 1 });
    if el.edges.iter().any(|e| e.src >= el.num_nodes || e.dst >= el.num_nodes) {
        bail!("edge endpoint >= declared node count in {}", path.display());
    }
    Ok(el)
}

/// Write the compact binary format: magic, node count, edge count, then
/// little-endian u32 pairs.
pub fn save_binary(el: &EdgeList, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(el.num_nodes as u64).to_le_bytes())?;
    w.write_all(&(el.edges.len() as u64).to_le_bytes())?;
    // Bulk-encode for speed.
    let mut buf = Vec::with_capacity(el.edges.len() * 8);
    for e in &el.edges {
        buf.extend_from_slice(&e.src.to_le_bytes());
        buf.extend_from_slice(&e.dst.to_le_bytes());
    }
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Load the binary format written by [`save_binary`].
pub fn load_binary(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a GraphGen+ binary graph (bad magic)", path.display());
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let num_nodes = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let num_edges = u64::from_le_bytes(u64buf) as usize;
    if num_nodes > NodeId::MAX as u64 {
        bail!("node count {num_nodes} exceeds u32 id space");
    }
    let mut buf = vec![0u8; num_edges * 8];
    r.read_exact(&mut buf)?;
    let mut el = EdgeList::with_capacity(num_nodes as NodeId, num_edges);
    for c in buf.chunks_exact(8) {
        let src = NodeId::from_le_bytes(c[0..4].try_into().unwrap());
        let dst = NodeId::from_le_bytes(c[4..8].try_into().unwrap());
        el.edges.push(super::Edge::new(src, dst));
    }
    if el.edges.iter().any(|e| e.src as u64 >= num_nodes || e.dst as u64 >= num_nodes) {
        bail!("corrupt graph file {}: endpoint out of range", path.display());
    }
    Ok(el)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ggtest-{}-{:?}", std::process::id(), std::thread::current().id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn text_roundtrip() {
        let g = generator::from_spec("rmat:n=128,e=512", 1).unwrap();
        let p = tmpdir().join("g.tsv");
        save_text(&g.edges, &p).unwrap();
        let loaded = load_text(&p).unwrap();
        assert_eq!(loaded.num_nodes, g.edges.num_nodes);
        assert_eq!(loaded.edges, g.edges.edges);
    }

    #[test]
    fn binary_roundtrip() {
        let g = generator::from_spec("planted:n=200,e=900,c=4", 2).unwrap();
        let p = tmpdir().join("g.bin");
        save_binary(&g.edges, &p).unwrap();
        let loaded = load_binary(&p).unwrap();
        assert_eq!(loaded.num_nodes, g.edges.num_nodes);
        assert_eq!(loaded.edges, g.edges.edges);
    }

    #[test]
    fn text_without_header_infers_nodes() {
        let p = tmpdir().join("noheader.tsv");
        std::fs::write(&p, "0\t5\n5 2\n").unwrap();
        let el = load_text(&p).unwrap();
        assert_eq!(el.num_nodes, 6);
        assert_eq!(el.edges.len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        let d = tmpdir();
        let p = d.join("bad.tsv");
        std::fs::write(&p, "0\n").unwrap();
        assert!(load_text(&p).is_err());
        let p2 = d.join("bad.bin");
        std::fs::write(&p2, b"NOTMAGIC........").unwrap();
        assert!(load_binary(&p2).is_err());
        let p3 = d.join("oob.tsv");
        std::fs::write(&p3, "# nodes: 2\n0\t9\n").unwrap();
        assert!(load_text(&p3).is_err());
    }
}
