//! Compressed sparse row (CSR) graph — the frozen, read-only adjacency
//! structure every engine samples from.

use super::edgelist::EdgeList;
use super::NodeId;
use crate::util::parallel_scan;
use crate::util::workpool::{default_threads, RawParts, WorkPool};

/// CSR adjacency: `neighbors(v)` is `adj[offsets[v] .. offsets[v+1]]`.
///
/// Neighbor lists are sorted, which the samplers rely on for deterministic
/// iteration order.
#[derive(Debug, Clone)]
pub struct Csr {
    offsets: Vec<u64>,
    adj: Vec<NodeId>,
}

impl Csr {
    /// Build from an edge list (interpreted as directed edges).
    /// Duplicates and self-loops should have been removed by the caller
    /// (`EdgeList::sort_dedup`); they are tolerated but preserved.
    pub fn from_edge_list(el: &EdgeList) -> Self {
        Self::from_edge_list_with_threads(el, default_threads())
    }

    /// [`from_edge_list`](Self::from_edge_list) with an explicit thread
    /// budget. Output is byte-identical at every thread count: the
    /// offset spine is an integer prefix scan (associative), edge
    /// placement is positional, and per-node sorting is order-free.
    pub fn from_edge_list_with_threads(el: &EdgeList, threads: usize) -> Self {
        let n = el.num_nodes as usize;
        let pool = WorkPool::global();
        let mut counts = vec![0u64; n + 1];
        for e in &el.edges {
            counts[e.src as usize + 1] += 1;
        }
        // counts[0] is 0 and counts[v+1] holds deg(v), so an inclusive
        // scan over the whole vec *is* the offset array.
        parallel_scan::inclusive_scan(pool, threads, &mut counts);
        let offsets = counts;
        let ne = el.edges.len();
        let mut adj = vec![0 as NodeId; ne];
        if el.edges.windows(2).all(|w| w[0] <= w[1]) {
            // Sorted input (the `sort_dedup` contract): edge `p` lands at
            // `adj[p]` and each node's run is already dst-ascending, so
            // the fill is a parallel copy and the sort pass vanishes.
            pool.run_row_chunks_labeled(&mut adj, 1, threads, 1 << 15, "csr.fill", |r0, sub| {
                for (i, v) in sub.iter_mut().enumerate() {
                    *v = el.edges[r0 + i].dst;
                }
            });
        } else {
            // Unsorted input: cursor scatter preserves input order per
            // node (sequential — the cursors carry a loop dependency),
            // then the per-node sorts run in parallel over disjoint runs.
            let mut cursor = offsets.clone();
            for e in &el.edges {
                let c = &mut cursor[e.src as usize];
                adj[*c as usize] = e.dst;
                *c += 1;
            }
            let base = RawParts(adj.as_mut_ptr());
            let base = &base;
            let offs = &offsets;
            pool.run_labeled(n, threads, 256, "csr.sort_adj", |v| {
                let (s, e) = (offs[v] as usize, offs[v + 1] as usize);
                if e - s > 1 {
                    // SAFETY: node runs [offsets[v], offsets[v+1]) are
                    // disjoint and `adj` outlives the blocking run.
                    unsafe { std::slice::from_raw_parts_mut(base.0.add(s), e - s) }
                        .sort_unstable();
                }
            });
        }
        Self { offsets, adj }
    }

    #[inline]
    pub fn num_nodes(&self) -> NodeId {
        (self.offsets.len() - 1) as NodeId
    }

    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.adj.len() as u64
    }

    #[inline]
    pub fn degree(&self, v: NodeId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    #[inline]
    pub fn neighbors(&self, v: NodeId) -> &[NodeId] {
        &self.adj[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Iterate all edges as (src, dst) in CSR order.
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        (0..self.num_nodes()).flat_map(move |v| self.neighbors(v).iter().map(move |&d| (v, d)))
    }

    /// Max degree and the node achieving it.
    pub fn max_degree(&self) -> (NodeId, u32) {
        let mut best = (0, 0);
        for v in 0..self.num_nodes() {
            let d = self.degree(v);
            if d > best.1 {
                best = (v, d);
            }
        }
        best
    }

    /// Mean out-degree.
    pub fn mean_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_nodes() as f64
    }

    /// Approximate in-memory footprint in bytes.
    pub fn memory_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.adj.len() * 4) as u64
    }

    /// The `k` highest-degree nodes, descending (ties by id) — the hot
    /// set used to warm the feature cache. Partial selection: O(n) to
    /// isolate the top k, then only those are sorted.
    pub fn top_degree_nodes(&self, k: usize) -> Vec<(NodeId, u32)> {
        let mut all: Vec<(NodeId, u32)> =
            (0..self.num_nodes()).map(|v| (v, self.degree(v))).collect();
        let k = k.min(all.len());
        if k == 0 {
            return Vec::new();
        }
        let by_degree_then_id =
            |a: &(NodeId, u32), b: &(NodeId, u32)| b.1.cmp(&a.1).then(a.0.cmp(&b.0));
        if k < all.len() {
            all.select_nth_unstable_by(k - 1, by_degree_then_id);
            all.truncate(k);
        }
        all.sort_unstable_by(by_degree_then_id);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        let mut el = EdgeList::new(5);
        for &(s, d) in &[(0, 1), (0, 2), (1, 2), (3, 0), (3, 4), (2, 4)] {
            el.push(s, d);
        }
        el.sort_dedup();
        Csr::from_edge_list(&el)
    }

    #[test]
    fn structure_matches_input() {
        let g = small();
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.neighbors(2), &[4]);
        assert_eq!(g.neighbors(3), &[0, 4]);
        assert_eq!(g.neighbors(4), &[] as &[NodeId]);
    }

    #[test]
    fn degrees_and_stats() {
        let g = small();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.max_degree().1, 2);
        assert!((g.mean_degree() - 6.0 / 5.0).abs() < 1e-12);
        assert!(g.memory_bytes() > 0);
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let g = small();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), 6);
        assert!(edges.contains(&(3, 4)));
        assert!(edges.contains(&(0, 1)));
    }

    #[test]
    fn neighbors_sorted_even_if_input_unsorted() {
        let mut el = EdgeList::new(3);
        el.push(0, 2);
        el.push(0, 1);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.neighbors(0), &[1, 2]);
    }

    #[test]
    fn top_degree_nodes_orders_and_truncates() {
        let g = small();
        let top = g.top_degree_nodes(2);
        // Degrees: 0→2, 3→2, 1→1, 2→1, 4→0; ties break by id.
        assert_eq!(top, vec![(0, 2), (3, 2)]);
        assert_eq!(g.top_degree_nodes(100).len(), 5);
        assert!(g.top_degree_nodes(0).is_empty());
    }

    #[test]
    fn empty_graph() {
        let el = EdgeList::new(0);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.mean_degree(), 0.0);
    }
}
