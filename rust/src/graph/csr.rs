//! Compressed sparse row (CSR) graph — the frozen, read-only adjacency
//! structure every engine samples from.
//!
//! Adjacency has two storage modes: fully resident (the default), and
//! **paged** ([`Csr::to_paged`]) — offsets stay resident while the edge
//! targets live in compressed cold-tier pages
//! ([`crate::storage::tier`]) under a CLOCK hot tier, faulted in during
//! hop scans and prefetched a wave ahead. Hot paths read through
//! [`Csr::neighbors_ref`], which borrows in resident mode and pins the
//! faulted page in paged mode; the bytes seen are identical either way.

use std::sync::Arc;

use super::edgelist::EdgeList;
use super::NodeId;
use crate::storage::tier::{PageCache, PageStore, PageStoreWriter, TierStats, PAGE_WORDS};
use crate::util::parallel_scan;
use crate::util::workpool::{default_threads, RawParts, WorkPool};

/// Edge targets, resident or cold-tier paged.
#[derive(Debug, Clone)]
enum AdjStorage {
    Resident(Vec<NodeId>),
    Paged(Arc<ColdAdj>),
}

/// Paged adjacency: neighbor runs packed node-aligned into compressed
/// pages (a run never straddles pages; a hub larger than the page
/// target gets one oversized page of its own), so one fault pins a
/// node's whole list.
#[derive(Debug)]
struct ColdAdj {
    store: PageStore,
    cache: PageCache,
    /// Page holding node `v`'s neighbor run.
    page_of: Vec<u32>,
    /// Global adjacency offset at which each page begins (maps the
    /// resident `offsets` into within-page positions).
    page_base: Vec<u64>,
}

/// A borrowed-or-pinned neighbor list (deref to `&[NodeId]`). Resident
/// graphs borrow the slice; paged graphs hold the faulted page by `Arc`
/// so a concurrent eviction cannot free it mid-scan.
pub enum NeighborsRef<'a> {
    Resident(&'a [NodeId]),
    Paged { page: Arc<Vec<u32>>, lo: usize, hi: usize },
}

impl std::ops::Deref for NeighborsRef<'_> {
    type Target = [NodeId];

    #[inline]
    fn deref(&self) -> &[NodeId] {
        match self {
            NeighborsRef::Resident(s) => s,
            NeighborsRef::Paged { page, lo, hi } => &page[*lo..*hi],
        }
    }
}

/// CSR adjacency: `neighbors(v)` is `adj[offsets[v] .. offsets[v+1]]`.
///
/// Neighbor lists are sorted, which the samplers rely on for deterministic
/// iteration order.
#[derive(Debug, Clone)]
pub struct Csr {
    offsets: Vec<u64>,
    adj: AdjStorage,
}

impl Csr {
    /// Build from an edge list (interpreted as directed edges).
    /// Duplicates and self-loops should have been removed by the caller
    /// (`EdgeList::sort_dedup`); they are tolerated but preserved.
    pub fn from_edge_list(el: &EdgeList) -> Self {
        Self::from_edge_list_with_threads(el, default_threads())
    }

    /// [`from_edge_list`](Self::from_edge_list) with an explicit thread
    /// budget. Output is byte-identical at every thread count: the
    /// offset spine is an integer prefix scan (associative), edge
    /// placement is positional, and per-node sorting is order-free.
    pub fn from_edge_list_with_threads(el: &EdgeList, threads: usize) -> Self {
        let n = el.num_nodes as usize;
        let pool = WorkPool::global();
        let mut counts = vec![0u64; n + 1];
        for e in &el.edges {
            counts[e.src as usize + 1] += 1;
        }
        // counts[0] is 0 and counts[v+1] holds deg(v), so an inclusive
        // scan over the whole vec *is* the offset array.
        parallel_scan::inclusive_scan(pool, threads, &mut counts);
        let offsets = counts;
        let ne = el.edges.len();
        let mut adj = vec![0 as NodeId; ne];
        if el.edges.windows(2).all(|w| w[0] <= w[1]) {
            // Sorted input (the `sort_dedup` contract): edge `p` lands at
            // `adj[p]` and each node's run is already dst-ascending, so
            // the fill is a parallel copy and the sort pass vanishes.
            pool.run_row_chunks_labeled(&mut adj, 1, threads, 1 << 15, "csr.fill", |r0, sub| {
                for (i, v) in sub.iter_mut().enumerate() {
                    *v = el.edges[r0 + i].dst;
                }
            });
        } else {
            // Unsorted input: cursor scatter preserves input order per
            // node (sequential — the cursors carry a loop dependency),
            // then the per-node sorts run in parallel over disjoint runs.
            let mut cursor = offsets.clone();
            for e in &el.edges {
                let c = &mut cursor[e.src as usize];
                adj[*c as usize] = e.dst;
                *c += 1;
            }
            let base = RawParts(adj.as_mut_ptr());
            let base = &base;
            let offs = &offsets;
            pool.run_labeled(n, threads, 256, "csr.sort_adj", |v| {
                let (s, e) = (offs[v] as usize, offs[v + 1] as usize);
                if e - s > 1 {
                    // SAFETY: node runs [offsets[v], offsets[v+1]) are
                    // disjoint and `adj` outlives the blocking run.
                    unsafe { std::slice::from_raw_parts_mut(base.0.add(s), e - s) }
                        .sort_unstable();
                }
            });
        }
        Self { offsets, adj: AdjStorage::Resident(adj) }
    }

    /// Re-home the edge targets in the tiered cold store: offsets stay
    /// resident, neighbor runs are packed node-aligned into compressed
    /// pages, and a CLOCK hot tier of `budget_bytes` (0 = unlimited)
    /// serves faults. The paged graph is value-identical to `self` —
    /// every `neighbors_ref` returns the same bytes — so sampling on it
    /// produces byte-identical subgraphs at a measured fault cost.
    pub fn to_paged(&self, budget_bytes: u64) -> Self {
        let n = self.num_nodes() as usize;
        let mut writer = PageStoreWriter::create().expect("create adjacency cold tier");
        let mut page_of = vec![0u32; n];
        let mut page_base: Vec<u64> = Vec::new();
        let mut cur: Vec<u32> = Vec::with_capacity(PAGE_WORDS);
        let mut cur_base = 0u64;
        for v in 0..n {
            let run = self.neighbors_ref(v as NodeId);
            if !cur.is_empty() && cur.len() + run.len() > PAGE_WORDS {
                page_base.push(cur_base);
                writer.push_words(&cur).expect("write adjacency page");
                cur_base += cur.len() as u64;
                cur.clear();
            }
            page_of[v] = page_base.len() as u32;
            cur.extend_from_slice(&run);
        }
        if n > 0 {
            page_base.push(cur_base);
            writer.push_words(&cur).expect("write adjacency page");
        }
        let store = writer.finish();
        let cache = PageCache::with_budget(budget_bytes, store.num_pages());
        Self {
            offsets: self.offsets.clone(),
            adj: AdjStorage::Paged(Arc::new(ColdAdj { store, cache, page_of, page_base })),
        }
    }

    /// Whether edge targets are cold-tier paged.
    pub fn is_paged(&self) -> bool {
        matches!(self.adj, AdjStorage::Paged(_))
    }

    /// Hot/cold tier counters (None for resident graphs).
    pub fn tier_stats(&self) -> Option<TierStats> {
        match &self.adj {
            AdjStorage::Resident(_) => None,
            AdjStorage::Paged(cold) => Some(cold.cache.stats()),
        }
    }

    /// Compressed cold-tier bytes on disk (0 for resident graphs).
    pub fn cold_bytes(&self) -> u64 {
        match &self.adj {
            AdjStorage::Resident(_) => 0,
            AdjStorage::Paged(cold) => cold.store.cold_bytes(),
        }
    }

    /// Warm the hot tier for an upcoming hop over `nodes` (the next
    /// frontier): fault every page their runs live on, fanned out over
    /// the generation pool so reads+inflates overlap. Called by the hop
    /// scan a wave ahead (speculative hop-1 runs while the previous wave
    /// reduces), which turns cold faults into hot hits on the scan
    /// itself. No-op on resident graphs.
    pub fn prefetch_pages(&self, nodes: &[NodeId], threads: usize) {
        let AdjStorage::Paged(cold) = &self.adj else { return };
        let mut pages: Vec<u32> = nodes
            .iter()
            .filter(|&&v| self.degree(v) > 0)
            .map(|&v| cold.page_of[v as usize])
            .collect();
        pages.sort_unstable();
        pages.dedup();
        if pages.is_empty() {
            return;
        }
        let _span = crate::obs::trace::span("tier.prefetch").arg("pages", pages.len() as f64);
        let threads = threads.max(1);
        if threads <= 1 || pages.len() < 4 {
            for &p in &pages {
                let _ = cold.cache.get(p, &cold.store);
            }
            return;
        }
        let pages = &pages;
        let cold = &**cold;
        WorkPool::global().run_labeled(pages.len(), threads, 1, "tier.prefetch", |i| {
            let _ = cold.cache.get(pages[i], &cold.store);
        });
    }

    #[inline]
    pub fn num_nodes(&self) -> NodeId {
        (self.offsets.len() - 1) as NodeId
    }

    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.offsets[self.offsets.len() - 1]
    }

    #[inline]
    pub fn degree(&self, v: NodeId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Borrowed neighbor slice — resident graphs only. Hot paths and
    /// anything that may see a paged graph use
    /// [`neighbors_ref`](Self::neighbors_ref) instead.
    ///
    /// # Panics
    /// On a paged graph (a borrowed slice cannot pin a faultable page).
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> &[NodeId] {
        match &self.adj {
            AdjStorage::Resident(adj) => {
                &adj[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
            }
            AdjStorage::Paged(_) => {
                panic!("neighbors() on a paged CSR — use neighbors_ref()")
            }
        }
    }

    /// Neighbor list of `v` through either storage mode: borrows the
    /// slice when resident, faults-and-pins the page when cold. The
    /// returned bytes are identical in both modes.
    #[inline]
    pub fn neighbors_ref(&self, v: NodeId) -> NeighborsRef<'_> {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        match &self.adj {
            AdjStorage::Resident(adj) => NeighborsRef::Resident(&adj[s..e]),
            AdjStorage::Paged(cold) => {
                if s == e {
                    return NeighborsRef::Resident(&[]);
                }
                let p = cold.page_of[v as usize];
                let page = cold.cache.get(p, &cold.store).expect("cold adjacency fault");
                let base = cold.page_base[p as usize] as usize;
                NeighborsRef::Paged { page, lo: s - base, hi: e - base }
            }
        }
    }

    /// Iterate all edges as (src, dst) in CSR order. Works on paged
    /// graphs too (faulting page by page) at a per-node copy cost — a
    /// cold-path API; hop scans use [`neighbors_ref`](Self::neighbors_ref).
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        (0..self.num_nodes()).flat_map(move |v| {
            let neigh: Vec<NodeId> = self.neighbors_ref(v).to_vec();
            neigh.into_iter().map(move |d| (v, d))
        })
    }

    /// Max degree and the node achieving it.
    pub fn max_degree(&self) -> (NodeId, u32) {
        let mut best = (0, 0);
        for v in 0..self.num_nodes() {
            let d = self.degree(v);
            if d > best.1 {
                best = (v, d);
            }
        }
        best
    }

    /// Mean out-degree.
    pub fn mean_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_nodes() as f64
    }

    /// Approximate in-memory footprint in bytes. For paged graphs this
    /// is the resident side only — offsets, page maps, and the hot
    /// tier's current pages; compressed on-disk bytes are reported
    /// separately by [`cold_bytes`](Self::cold_bytes).
    pub fn memory_bytes(&self) -> u64 {
        let offsets = (self.offsets.len() * 8) as u64;
        match &self.adj {
            AdjStorage::Resident(adj) => offsets + (adj.len() * 4) as u64,
            AdjStorage::Paged(cold) => {
                offsets
                    + (cold.page_of.len() * 4) as u64
                    + (cold.page_base.len() * 8) as u64
                    + cold.cache.resident_bytes()
            }
        }
    }

    /// The `k` highest-degree nodes, descending (ties by id) — the hot
    /// set used to warm the feature cache. Partial selection: O(n) to
    /// isolate the top k, then only those are sorted.
    pub fn top_degree_nodes(&self, k: usize) -> Vec<(NodeId, u32)> {
        let mut all: Vec<(NodeId, u32)> =
            (0..self.num_nodes()).map(|v| (v, self.degree(v))).collect();
        let k = k.min(all.len());
        if k == 0 {
            return Vec::new();
        }
        let by_degree_then_id =
            |a: &(NodeId, u32), b: &(NodeId, u32)| b.1.cmp(&a.1).then(a.0.cmp(&b.0));
        if k < all.len() {
            all.select_nth_unstable_by(k - 1, by_degree_then_id);
            all.truncate(k);
        }
        all.sort_unstable_by(by_degree_then_id);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        let mut el = EdgeList::new(5);
        for &(s, d) in &[(0, 1), (0, 2), (1, 2), (3, 0), (3, 4), (2, 4)] {
            el.push(s, d);
        }
        el.sort_dedup();
        Csr::from_edge_list(&el)
    }

    #[test]
    fn structure_matches_input() {
        let g = small();
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.neighbors(2), &[4]);
        assert_eq!(g.neighbors(3), &[0, 4]);
        assert_eq!(g.neighbors(4), &[] as &[NodeId]);
    }

    #[test]
    fn degrees_and_stats() {
        let g = small();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.max_degree().1, 2);
        assert!((g.mean_degree() - 6.0 / 5.0).abs() < 1e-12);
        assert!(g.memory_bytes() > 0);
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let g = small();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), 6);
        assert!(edges.contains(&(3, 4)));
        assert!(edges.contains(&(0, 1)));
    }

    #[test]
    fn neighbors_sorted_even_if_input_unsorted() {
        let mut el = EdgeList::new(3);
        el.push(0, 2);
        el.push(0, 1);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.neighbors(0), &[1, 2]);
    }

    #[test]
    fn top_degree_nodes_orders_and_truncates() {
        let g = small();
        let top = g.top_degree_nodes(2);
        // Degrees: 0→2, 3→2, 1→1, 2→1, 4→0; ties break by id.
        assert_eq!(top, vec![(0, 2), (3, 2)]);
        assert_eq!(g.top_degree_nodes(100).len(), 5);
        assert!(g.top_degree_nodes(0).is_empty());
    }

    #[test]
    fn empty_graph() {
        let el = EdgeList::new(0);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.mean_degree(), 0.0);
    }

    #[test]
    fn neighbors_ref_matches_neighbors_on_resident() {
        let g = small();
        for v in 0..g.num_nodes() {
            assert_eq!(&*g.neighbors_ref(v), g.neighbors(v));
        }
        assert!(!g.is_paged());
        assert_eq!(g.cold_bytes(), 0);
        assert!(g.tier_stats().is_none());
    }

    #[test]
    fn paged_graph_is_value_identical() {
        let g = small();
        for budget in [0u64, 1, u64::MAX] {
            let p = g.to_paged(budget);
            assert!(p.is_paged());
            assert_eq!(p.num_nodes(), g.num_nodes());
            assert_eq!(p.num_edges(), g.num_edges());
            for v in 0..g.num_nodes() {
                assert_eq!(&*p.neighbors_ref(v), g.neighbors(v), "node {v} budget {budget}");
                assert_eq!(p.degree(v), g.degree(v));
            }
            // Identical through the iterator too (and paged edges()
            // works at all).
            let a: Vec<_> = g.edges().collect();
            let b: Vec<_> = p.edges().collect();
            assert_eq!(a, b);
            assert!(p.cold_bytes() > 0);
        }
    }

    #[test]
    fn paged_large_graph_with_hub_and_tiny_budget() {
        // A hub whose run exceeds one page plus many small nodes: the
        // hub gets an oversized page of its own; a 1-byte budget clamps
        // the hot tier to a single page so every page churns.
        let n: u32 = PAGE_WORDS as u32 + 1000;
        let mut el = EdgeList::new(n);
        for d in 1..n {
            el.push(0, d); // hub degree n-1 > PAGE_WORDS
        }
        for v in 1..n {
            el.push(v, (v + 1) % n);
            el.push(v, (v * 7 + 3) % n);
        }
        el.sort_dedup();
        let g = Csr::from_edge_list(&el);
        let p = g.to_paged(1);
        for v in 0..n {
            assert_eq!(&*p.neighbors_ref(v), g.neighbors(v), "node {v}");
        }
        let s = p.tier_stats().unwrap();
        assert!(s.evictions > 0, "1-page hot tier over several pages must evict: {s:?}");
        // Re-walk: previously evicted pages re-fault to identical bytes.
        for v in 0..n {
            assert_eq!(&*p.neighbors_ref(v), g.neighbors(v), "re-fault node {v}");
        }
    }

    #[test]
    fn prefetch_warms_pages_into_hits() {
        let mut el = EdgeList::new(600);
        for v in 0..600u32 {
            for k in 1..=40u32 {
                el.push(v, (v + k) % 600);
            }
        }
        el.sort_dedup();
        let g = Csr::from_edge_list(&el).to_paged(0); // unlimited: nothing evicts
        let nodes: Vec<NodeId> = (0..600).collect();
        g.prefetch_pages(&nodes, 4);
        let faults_after_prefetch = g.tier_stats().unwrap().faults;
        assert!(faults_after_prefetch > 0);
        for v in 0..600u32 {
            let _ = g.neighbors_ref(v);
        }
        let s = g.tier_stats().unwrap();
        assert_eq!(s.faults, faults_after_prefetch, "post-prefetch scans must be all hits");
        assert!(s.hits > 0);
        // Prefetch on a resident graph is a no-op.
        let r = Csr::from_edge_list(&el);
        r.prefetch_pages(&nodes, 4);
        assert!(r.tier_stats().is_none());
    }

    #[test]
    fn empty_and_all_isolated_graphs_page_cleanly() {
        let empty = Csr::from_edge_list(&EdgeList::new(0)).to_paged(1);
        assert_eq!(empty.num_nodes(), 0);
        let isolated = Csr::from_edge_list(&EdgeList::new(9)).to_paged(1);
        for v in 0..9 {
            assert_eq!(&*isolated.neighbors_ref(v), &[] as &[NodeId]);
        }
    }
}
