//! Graph substrate: edge lists, CSR storage, synthetic generators,
//! feature/label synthesis, partitioners and on-disk formats.
//!
//! Node ids are `u32` — industry graphs need 64 bits, but at this
//! testbed's scale (≤ hundreds of millions of edges) 32 bits halves the
//! memory footprint and cache pressure of every hot loop. The public
//! types use the [`NodeId`] alias throughout so widening is mechanical.

pub mod csr;
pub mod edgelist;
pub mod features;
pub mod generator;
pub mod io;
pub mod partition;

/// Node identifier.
pub type NodeId = u32;

/// A directed edge (for undirected graphs both directions are stored).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
}

impl Edge {
    pub fn new(src: NodeId, dst: NodeId) -> Self {
        Self { src, dst }
    }

    /// The edge with endpoints swapped.
    pub fn reversed(self) -> Self {
        Edge { src: self.dst, dst: self.src }
    }

    /// Canonical orientation (src <= dst), for undirected dedup.
    pub fn canonical(self) -> Self {
        if self.src <= self.dst {
            self
        } else {
            self.reversed()
        }
    }
}
